"""End-to-end LM training driver example.

Default: a ~20M-param model for 200 steps (minutes on CPU).  The
documented full-size invocation trains a ~100M model for a few hundred
steps (hours on CPU; the same command drives a TPU slice):

  PYTHONPATH=src python examples/train_lm.py --full

which expands to

  python -m repro.launch.train --arch granite-8b --smoke \
      --layers 8 --d-model 768 --vocab 32768 --pipe 4 --ticks 2 \
      --steps 300 --batch 8 --seq 256 --lr 5e-3 --mode spectrain \
      --ckpt-dir /tmp/repro_100m --resume auto
"""
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")

SMALL = ["--arch", "granite-8b", "--smoke", "--layers", "4",
         "--d-model", "256", "--vocab", "8192", "--pipe", "4",
         "--steps", "200", "--batch", "8", "--seq", "64",
         "--lr", "1e-2", "--mode", "spectrain", "--log-every", "20"]

FULL = ["--arch", "granite-8b", "--smoke", "--layers", "8",
        "--d-model", "768", "--vocab", "32768", "--pipe", "4",
        "--ticks", "2", "--steps", "300", "--batch", "8", "--seq", "256",
        "--lr", "5e-3", "--mode", "spectrain",
        "--ckpt-dir", "/tmp/repro_100m", "--resume", "auto"]

if __name__ == "__main__":
    args = FULL if "--full" in sys.argv else SMALL
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    raise SystemExit(subprocess.call(
        [sys.executable, "-m", "repro.launch.train", *args], env=env))
