"""Continuous-batching serving example across architecture families
(attention / MLA / RWKV / hybrid).

Attention and SSM archs run the pipelined engine (serving rounds
compiled to schedule IR); the hybrid arch auto-falls back to the
whole-model SimpleEngine (--engine auto)."""
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")

if __name__ == "__main__":
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    for arch in ("granite-8b", "minicpm3-4b", "rwkv6-7b", "zamba2-1.2b"):
        print(f"=== {arch} ===")
        subprocess.check_call(
            [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
             "--pipe", "2", "--layers", "4", "--requests", "6",
             "--rate", "1.0", "--prompt-lens", "2,12",
             "--gen-lens", "1,8"],
            env=env)
