"""Batched serving example: prefill + decode with a KV cache, across
architecture families (attention / MLA / RWKV / hybrid)."""
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")

if __name__ == "__main__":
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    for arch in ("granite-8b", "minicpm3-4b", "rwkv6-7b", "zamba2-1.2b"):
        print(f"=== {arch} ===")
        subprocess.check_call(
            [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
             "--batch", "2", "--prompt-len", "16", "--gen", "16"],
            env=env)
