"""Quickstart: the paper's technique in ~40 lines of library API.

Builds a small pipelined LM, trains it with the async streaming pipeline
under SpecTrain weight prediction, and compares against vanilla stale
pipelining — the paper's core claim, reproduced in a minute on CPU.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config, smoke_config
from repro.configs.base import MeshPlan
from repro.core import pipeline_stream
from repro.data import DataConfig, SyntheticLM
from repro.models import Model


def train(mode: str, steps: int = 120):
    # a 4-layer, 4-stage pipelined llama-style model (reduced dims)
    cfg = smoke_config(get_config("granite-8b")).replace(
        n_layers=4,
        mesh_plan=MeshPlan(pipe=4, tensor=1),
        param_dtype="float32", compute_dtype="float32")
    model = Model(cfg)

    data = SyntheticLM(DataConfig(cfg.vocab_size, seq_len=16,
                                  global_batch=8, seed=0))
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       data.batch_at(0))

    state = pipeline_stream.init_state(model, jax.random.PRNGKey(0), sds,
                                       mode=mode)
    step = jax.jit(pipeline_stream.make_train_step(
        model, mode=mode, lr=0.08))

    losses = []
    for s in range(steps):
        state, metrics = step(state, data.batch_at(s))
        if float(metrics["loss_valid"]):
            losses.append(float(metrics["loss"]))
    return losses, data.optimal_loss()


if __name__ == "__main__":
    print("training a 4-stage async pipeline (PipeDream-style), 3 ways:\n")
    for mode in ("vanilla", "pipedream", "spectrain"):
        losses, floor = train(mode)
        print(f"  {mode:10s} first={losses[0]:.3f} "
              f"final={sum(losses[-20:])/20:.3f}  (bigram floor {floor:.3f})")
    print("\nSpecTrain (weight prediction, Eq. 4) recovers the loss the "
          "stale\npipeline gives up — the paper's Fig. 11 in miniature.")
