"""The paper's evaluation in one script: staleness RMSE (Fig. 8) and the
four-scheme convergence comparison (Fig. 11 / Table 1), on the
paper-exact event simulator.

Run:  PYTHONPATH=src python examples/spectrain_ablation.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.simulator import Simulator, make_mlp_staged


def data_iter(seed):
    key = jax.random.PRNGKey(seed)
    wtrue = jax.random.normal(jax.random.PRNGKey(99), (32, 10))
    while True:
        key, k1 = jax.random.split(key)
        x = jax.random.normal(k1, (64, 32))
        yield {"x": x, "y": (x @ wtrue).argmax(-1)}


if __name__ == "__main__":
    fns, params = make_mlp_staged(jax.random.PRNGKey(0), in_dim=32,
                                  width=64, depth=8, n_classes=10,
                                  n_stages=4)

    print("== Fig. 8: prediction RMSE vs stale-weight RMSE ==")
    sim = Simulator(fns, params, n_stages=4, scheme="spectrain", lr=0.08,
                    rmse_s=(1, 2, 3))
    it = data_iter(0)
    ms = [sim.step(next(it)) for _ in range(200)]
    for s in (1, 2, 3):
        p = np.mean([m[f"rmse_pred_s{s}"] for m in ms[20:]])
        st = np.mean([m[f"rmse_stale_s{s}"] for m in ms[20:]])
        print(f"  s={s}: RMSE(predicted)={p:.2e}  RMSE(stale)={st:.2e}  "
              f"-> {st/p:.2f}x better")

    print("\n== Fig. 11 / Table 1: four schemes, 4-stage pipeline ==")
    for scheme in Simulator.SCHEMES:
        sim = Simulator(fns, params, n_stages=4, scheme=scheme, lr=0.12)
        it = data_iter(0)
        losses = [sim.step(next(it))["loss"] for _ in range(300)]
        print(f"  {scheme:10s} final loss {np.mean(losses[-40:]):.4f}")
