"""Render the planner IR's schedule families as markdown.

Generates the timeline diagrams and the bubble-vs-memory table used by
``docs/SCHEDULES.md`` and EXPERIMENTS.md straight from the IR, so the
docs describe what the emitters actually emit:

    PYTHONPATH=src python examples/render_schedules.py            # diagrams
    PYTHONPATH=src python examples/render_schedules.py --table    # table only

Everything printed is derived: timelines from the event list, staleness
from update counting, bubble fraction / stash depths from the same
timeline the runtimes execute.
"""
import argparse
import sys

from repro.planner import schedule_ir as ir

# (title, builder, notes) — small instances so diagrams stay readable
DIAGRAMS = [
    ("GPipe (S=2, M=4, one round)",
     lambda: ir.gpipe(2, n_microbatches=4, n_rounds=1),
     "all forwards, all backwards, one accumulated update (u)"),
    ("1F1B / PipeDream-flush (S=2, M=4, one round)",
     lambda: ir.one_f_one_b(2, 4, n_rounds=1),
     "warm-up forwards, then fwd/bwd alternation; same bubble as GPipe, "
     "stage k stashes only S-k activations"),
    ("PipeDream-2BW (S=2, m=2, continuous)",
     lambda: ir.pipedream_2bw(2, n_microbatches=2, n_groups=3),
     "no flush: per-stage update every m microbatches, reads pinned one "
     "version back (double buffer)"),
    ("Interleaved 1F1B (S=2 devices, v=2 chunks, M=4, one round)",
     lambda: ir.interleaved_1f1b(2, 4, v=2, n_rounds=1),
     "cell f3.1 = forward of microbatch 3 on the device's chunk 1; the "
     "fill/drain ramp shrinks ~v x relative to the round's work"),
    ("Streaming tick schedule (S=2, steady state)",
     lambda: ir.streaming(2, n_ticks=8),
     "one 1F+1B wave and a per-stage update every tick - zero bubble "
     "after warm-up, paid for with staleness 2(S-1-k)"),
]

TABLE_CASES = [
    ("gpipe", lambda S, M: ir.gpipe(S, n_microbatches=M, n_rounds=2)),
    ("1f1b", lambda S, M: ir.one_f_one_b(S, M)),
    ("2bw", lambda S, M: ir.pipedream_2bw(S, n_microbatches=M)),
    ("interleaved v=2",
     lambda S, M: ir.interleaved_1f1b(S, M, v=2)),
]


def diagrams(out=sys.stdout):
    for title, build, note in DIAGRAMS:
        sched = build()
        sched.validate()
        out.write(f"### {title}\n\n{note}\n\n```\n")
        out.write(sched.render(max_ticks=22))
        # diagrams use deliberately short timelines; report the most
        # warmed-up minibatch they contain
        mb = sched.complete_minibatches()[-1]
        out.write(f"\n```\n\ns_fwd={sched.staleness_vector('forward', mb)}"
                  f"  s_bwd={sched.staleness_vector('backward', mb)}"
                  f"  bubble={sched.bubble_fraction():.3f}\n\n")


def table(S=4, M=8, out=sys.stdout):
    out.write(f"S={S} stages, M={M} microbatches per round/group "
              f"(all values derived from the IR timeline):\n\n")
    out.write("| schedule | bubble fraction | peak act stash "
              "(stage 0 / total) | weight versions | staleness "
              "s_fwd |\n")
    out.write("|---|---|---|---|---|\n")
    for name, build in TABLE_CASES:
        sched = build(S, M)
        sched.validate()
        C = sched.n_stages
        stash = [sched.peak_activation_stash(q) for q in range(C)]
        wdep = max(sched.weight_stash_depth(q) for q in range(C))
        mb = sched.steady_minibatch()
        s_fwd = sched.staleness_vector("forward", mb)
        s_desc = ("0 (sync)" if not any(s_fwd)
                  else "1 (uniform)" if set(s_fwd) == {1}
                  else str(s_fwd))
        out.write(f"| {name} | {sched.bubble_fraction():.3f} | "
                  f"{stash[0]} / {sum(stash)} | {wdep} | {s_desc} |\n")
    stream = ir.streaming(S)
    mb = stream.steady_minibatch()
    out.write(f"| stream | ~0 past warm-up | "
              f"{stream.peak_activation_stash(0)} / "
              f"{sum(stream.peak_activation_stash(q) for q in range(S))} | "
              f"1 (+ring in pipedream mode) | "
              f"{stream.staleness_vector('forward', mb)} |\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", action="store_true",
                    help="only the bubble-vs-memory comparison table")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=8)
    args = ap.parse_args(argv)
    if not args.table:
        diagrams()
    table(args.stages, args.microbatches)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
