"""Fault tolerance for long multi-pod runs.

Three layers (DESIGN.md §2.5):

1. **Checkpoint/restart** — `runtime.checkpoint` + `RestartManager`:
   crash ⇒ restore last committed step ⇒ identical trajectory (the data
   pipeline is a pure function of the step counter, so resume is exact —
   property-tested in tests/test_fault_tolerance.py).

2. **Straggler mitigation** — Chen et al. (2016)-style backup-worker
   drop: when a data replica misses its deadline, its gradient
   contribution is masked and the mean renormalized.  On a real pod this
   is a masked all-reduce; the math (and the test) is the host-level
   ``masked_gradient_mean``.

3. **Heartbeats** — `HeartbeatMonitor` tracks per-worker progress and
   flags stragglers/failures for the launcher to act on (drop vs restart
   vs elastic shrink).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# straggler math


def masked_gradient_mean(grad_shards: List[Any], alive: List[bool]):
    """Mean of per-replica gradients over the alive set (backup-worker
    semantics: slow replicas are dropped, not waited for)."""
    n = sum(alive)
    if n == 0:
        raise RuntimeError("all replicas dead")
    scale = 1.0 / n

    def combine(*leaves):
        tot = None
        for leaf, ok in zip(leaves, alive):
            if not ok:
                continue
            term = leaf.astype(jnp.float32)
            tot = term if tot is None else tot + term
        return tot * scale

    return jax.tree.map(combine, *grad_shards)


# ---------------------------------------------------------------------------
# heartbeats


@dataclass
class HeartbeatMonitor:
    """``registry`` (an ``obs.MetricsRegistry``, optional) receives one
    structured ``heartbeat_missed`` event per worker on the alive ->
    overdue transition and a ``heartbeat_recovered`` event when a
    flagged worker beats again — the launcher's audit trail for
    drop/restart/shrink decisions."""
    deadline_s: float = 30.0
    registry: Optional[Any] = None
    _last: Dict[int, float] = field(default_factory=dict)
    _step: Dict[int, int] = field(default_factory=dict)
    _flagged: set = field(default_factory=set)

    def beat(self, worker: int, step: int, now: Optional[float] = None):
        self._last[worker] = time.monotonic() if now is None else now
        self._step[worker] = step
        if worker in self._flagged:
            self._flagged.discard(worker)
            if self.registry is not None:
                self.registry.emit("heartbeat_recovered", worker=worker,
                                   step=step)

    def stragglers(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        bad = [w for w, t in self._last.items()
               if now - t > self.deadline_s]
        for w in bad:
            if w not in self._flagged:
                self._flagged.add(w)
                if self.registry is not None:
                    self.registry.emit(
                        "heartbeat_missed", worker=w,
                        last_step=self._step.get(w, -1),
                        overdue_s=now - self._last[w] - self.deadline_s)
        return bad

    def alive_mask(self, workers: int,
                   now: Optional[float] = None) -> List[bool]:
        bad = set(self.stragglers(now))
        return [w in self._last and w not in bad for w in range(workers)]


# ---------------------------------------------------------------------------
# restart manager


class RestartManager:
    """Wraps a step function with checkpoint/restart.

    ``inject_failure_at`` simulates a node loss at a given step (tests).
    """

    def __init__(self, ckpt_dir: str, *, save_every: int = 10,
                 keep: int = 3,
                 inject_failure_at: Optional[int] = None,
                 registry: Optional[Any] = None):
        from repro.runtime import checkpoint as ckpt
        self.ckpt = ckpt
        self.dir = ckpt_dir
        self.save_every = save_every
        self.keep = keep
        self.inject_failure_at = inject_failure_at
        self.registry = registry
        self._failed = False

    def _emit(self, event: str, **fields):
        if self.registry is not None:
            self.registry.emit(event, **fields)

    def maybe_restore(self, state):
        step = self.ckpt.latest_step(self.dir)
        if step is None:
            return state, 0
        state, step = self.ckpt.restore(self.dir, state)
        self._emit("restore", step=step)
        return state, step + 1

    def run(self, state, step_fn: Callable, data, start: int, steps: int):
        """Run [start, steps); on injected failure, restore + replay."""
        s = start
        while s < steps:
            if (self.inject_failure_at is not None and not self._failed
                    and s == self.inject_failure_at):
                self._failed = True
                self._emit("failure_injected", step=s)
                state, s = self.maybe_restore(state)
                continue
            batch = data.batch_at(s)
            state, metrics = step_fn(state, batch)
            if (s + 1) % self.save_every == 0:
                self.ckpt.save(self.dir, state, s, keep=self.keep)
                self._emit("checkpoint_save", step=s)
            s += 1
        return state, s
