"""Logical mesh refinement.

The physical production mesh is (data=16, model=16) per pod
(launch/mesh.py).  Each arch factors the 16-way ``model`` axis into
(pipe, tensor) with a per-arch role for ``pipe`` (pipeline stage vs
context parallelism).  This module reshapes the same devices into the
logical mesh the runtime uses.
"""
from __future__ import annotations


import numpy as np
from jax.sharding import Mesh


def refine_mesh(mesh: Mesh, pipe: int, tensor: int) -> Mesh:
    """(pod?, data, model) -> (pod?, data, pipe, tensor)."""
    names = mesh.axis_names
    devs = np.asarray(mesh.devices)
    model = devs.shape[-1]
    if pipe * tensor != model:
        raise ValueError(f"pipe*tensor={pipe * tensor} != model={model}")
    new_shape = devs.shape[:-1] + (pipe, tensor)
    new_names = tuple(names[:-1]) + ("pipe", "tensor")
    return Mesh(devs.reshape(new_shape), new_names)


def axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, np.asarray(mesh.devices).shape))
