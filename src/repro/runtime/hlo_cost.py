"""Trip-count-aware cost model over compiled HLO text.

``compiled.cost_analysis()`` on the CPU backend counts each while-loop
body ONCE (verified: scan of N steps reports 1/N of the true FLOPs), and
naive text-grep for collectives has the same flaw.  This module parses the
compiled module into computations, walks the call graph (fusion / call /
while with ``known_trip_count``), and accumulates

  * flops        — dot (2·M·N·K from operand shapes + contracting dims),
                   elementwise/convert/reduce approximations
  * bytes        — operand+result bytes at fusion boundaries (XLA-style)
  * collectives  — count / result bytes / ring-model wire bytes per kind

with the correct loop multiplicities.  This is the basis of the roofline
terms in EXPERIMENTS.md.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
                "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
                "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
                "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*"
    r"(\(.*?\)|[a-z][a-z0-9]*\[[0-9,]*\]\S*)\s+"
    r"([a-z][a-z0-9_-]*)\((.*)$")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([^\s(]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body)=%?([^\s,)]+)")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n["\s:]+(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")


def _shape_info(txt: str) -> Tuple[int, int]:
    """(total elements, total bytes) across every array shape in txt."""
    elems = 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


@dataclass
class Instr:
    name: str
    rtype: str
    opcode: str
    rest: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    table: Dict[str, str] = field(default_factory=dict)  # name -> result type


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0          # raw: every op's operands+result
    bytes_fused: float = 0.0    # TPU estimate: elementwise assumed fused
    transcendentals: float = 0.0
    coll: Dict[str, Dict[str, float]] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_fused += other.bytes_fused * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.coll.items():
            d = self.coll.setdefault(
                k, {"count": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0})
            for kk in d:
                d[kk] += v[kk] * mult

    @property
    def wire_bytes(self) -> float:
        return sum(v["wire_bytes"] for v in self.coll.values())


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _HEADER_RE.match(line)
            if m:
                cur = Computation(m.group(1))
                if line.startswith("ENTRY"):
                    cur.name = "__entry__"
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(*m.groups())
            cur.instrs.append(ins)
            cur.table[ins.name] = ins.rtype
    return comps


def _operands(rest: str) -> List[str]:
    """Names of %operands up to the closing paren of the op call."""
    out = []
    depth = 1
    for tok in re.finditer(r"[()]|%[^\s,()]+", rest):
        t = tok.group(0)
        if t == "(":
            depth += 1
        elif t == ")":
            depth -= 1
            if depth == 0:
                break
        elif depth >= 1:
            out.append(t[1:])
    return out


_ELEMENTWISE_FLOP = {
    "add": 1, "subtract": 1, "multiply": 1, "divide": 1, "negate": 1,
    "maximum": 1, "minimum": 1, "abs": 1, "compare": 1, "select": 1,
    "and": 1, "or": 1, "xor": 1, "not": 1, "clamp": 2, "floor": 1,
    "ceil": 1, "round-nearest-afz": 1, "sign": 1, "remainder": 1,
    "shift-left": 1, "shift-right-logical": 1, "shift-right-arithmetic": 1,
    "power": 1, "atan2": 1, "is-finite": 1, "popcnt": 1,
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "logistic",
                   "sine", "cosine", "cbrt", "erf", "exponential-minus-one",
                   "log-plus-one", "tan"}


def _group_size(rest: str, default: int = 2) -> int:
    g = _GROUPS_RE.search(rest)
    if g:
        return len(g.group(1).split(","))
    g2 = _GROUPS_IOTA_RE.search(rest)
    if g2:
        return int(g2.group(2))
    return default


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._memo: Dict[str, Cost] = {}

    def entry_cost(self) -> Cost:
        if "__entry__" not in self.comps:
            raise ValueError("no ENTRY computation found")
        return self.comp_cost("__entry__")

    # ------------------------------------------------------------------
    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        cost = Cost()
        self._memo[name] = cost  # cycle guard
        if comp is None:
            return cost
        for ins in comp.instrs:
            cost.add(self._instr_cost(comp, ins))
        return cost

    # ------------------------------------------------------------------
    def _instr_cost(self, comp: Computation, ins: Instr) -> Cost:
        c = Cost()
        op = ins.opcode
        _, rbytes = _shape_info(ins.rtype)
        relems, _ = _shape_info(ins.rtype)

        if op in ("parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "after-all", "partition-id", "replica-id",
                  "iota", "rng-bit-generator", "domain",
                  "opt-barrier", "add-dependency"):
            return c

        # ---- collectives -------------------------------------------------
        base = op.replace("-start", "")
        if base in COLLECTIVE_OPS:
            if op.endswith("-done"):
                return c
            n = _group_size(ins.rest)
            frac = (n - 1) / max(n, 1)
            if base == "all-gather":
                wire = rbytes * frac
            elif base == "all-reduce":
                wire = 2.0 * rbytes * frac
            elif base == "reduce-scatter":
                wire = rbytes * (n - 1)
            elif base == "all-to-all":
                wire = rbytes * frac
            else:
                wire = rbytes
            c.coll[base] = {"count": 1.0, "result_bytes": float(rbytes),
                            "wire_bytes": float(wire)}
            c.bytes += 2.0 * rbytes
            c.bytes_fused += 2.0 * rbytes
            return c

        # ---- control flow / calls -----------------------------------------
        if op == "while":
            m = _CALL_ATTR_RE.search(ins.rest)
            trips = 1
            tm = _TRIP_RE.search(ins.rest)
            if tm:
                trips = int(tm.group(1))
            if m:
                c.add(self.comp_cost(m.group(1)), mult=trips)
            return c
        if op in ("call", "fusion", "conditional", "custom-call",
                  "async-start"):
            # boundary bytes: operands + result
            ob = 0
            for o in _operands(ins.rest):
                t = comp.table.get(o)
                if t:
                    ob += _shape_info(t)[1]
            c.bytes += ob + rbytes
            c.bytes_fused += ob + rbytes
            for m in _CALL_ATTR_RE.finditer(ins.rest):
                sub = self.comp_cost(m.group(1))
                c.flops += sub.flops
                c.transcendentals += sub.transcendentals
                for k, v in sub.coll.items():
                    d = c.coll.setdefault(
                        k, {"count": 0.0, "result_bytes": 0.0,
                            "wire_bytes": 0.0})
                    for kk in d:
                        d[kk] += v[kk]
            return c

        # ---- dot ----------------------------------------------------------
        if op == "dot":
            ops = _operands(ins.rest)
            lhs_t = comp.table.get(ops[0]) if ops else None
            k = 1
            if lhs_t:
                dims_m = _SHAPE_RE.search(lhs_t)
                cd = _CDIMS_RE.search(ins.rest)
                if dims_m and cd and cd.group(1):
                    dims = [int(d) for d in dims_m.group(2).split(",")
                            ] if dims_m.group(2) else []
                    for i in (int(x) for x in cd.group(1).split(",")):
                        if i < len(dims):
                            k *= dims[i]
            c.flops += 2.0 * relems * k
            ob = sum(_shape_info(comp.table.get(o, ""))[1]
                     for o in _operands(ins.rest))
            c.bytes += ob + rbytes
            c.bytes_fused += ob + rbytes
            return c

        if op == "convolution":
            c.flops += 2.0 * relems  # no convs in this codebase; nominal
            c.bytes += 2.0 * rbytes
            c.bytes_fused += 2.0 * rbytes
            return c

        # ---- everything else: elementwise-ish -------------------------------
        if op in _TRANSCENDENTAL:
            c.transcendentals += relems
            c.flops += relems
        elif op in ("reduce", "reduce-window"):
            ops = _operands(ins.rest)
            ob = sum(_shape_info(comp.table.get(o, ""))[1]
                     for o in ops[:max(1, len(ops) // 2)])
            c.flops += _shape_info(comp.table.get(ops[0], ""))[0] if ops else 0
            c.bytes += ob + rbytes
            c.bytes_fused += ob + rbytes
            return c
        else:
            c.flops += relems * _ELEMENTWISE_FLOP.get(op, 1)
        ob = sum(_shape_info(comp.table.get(o, ""))[1]
                 for o in _operands(ins.rest))
        c.bytes += ob + rbytes
        # TPU-fusion estimate: layout/elementwise ops fuse into neighbours;
        # real HBM movers are copies and dynamic (update-)slices / gathers.
        if op in ("copy", "copy-start", "dynamic-slice",
                  "dynamic-update-slice", "gather", "scatter", "sort",
                  "select-and-scatter", "transpose"):
            c.bytes_fused += ob + rbytes
        return c


def analyze(text: str) -> Dict[str, object]:
    cost = HloCost(text).entry_cost()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "bytes_fused": cost.bytes_fused,
        "transcendentals": cost.transcendentals,
        "collectives": cost.coll,
        "wire_bytes": cost.wire_bytes,
    }
