"""Elastic scaling: reshard a training state across mesh plans.

Grow/shrink the data axis, or re-factor the model axis into a different
(pipe, tensor) split: stage-stacked parameters [S, L/S, ...] are restacked
to [S', L/S', ...] (same flattened layer order), optimizer state follows,
and in-flight pipeline rings are re-initialized (the ≤2(S−1) in-flight
microbatches are dropped — an elastic event costs one pipeline refill,
which is the industry-standard trade).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


def restack_stages(stages: Any, new_pipe: int) -> Any:
    """[S, Lps, ...] -> [S', L/S', ...] preserving flat layer order."""
    def leaf(a):
        total = a.shape[0] * a.shape[1]
        if total % new_pipe:
            raise ValueError(f"{total} layers not divisible by {new_pipe}")
        return a.reshape((new_pipe, total // new_pipe) + a.shape[2:])

    return jax.tree.map(leaf, stages)


def _flat_layers(stages: Any) -> Any:
    """[L, ...] flat layer tree from stacked stage params or the
    streaming runtime's ragged per-stage trees."""
    if isinstance(stages, (tuple, list)):
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                            *[t["layers"] for t in stages])
    return jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                        stages["layers"])


def _shared_blocks(stages: Any) -> Optional[Any]:
    if isinstance(stages, (tuple, list)):
        if "shared" not in stages[0]:
            return None
        return jax.tree.map(lambda *xs: jnp.stack(xs, 0),
                            *[t["shared"] for t in stages])
    return stages.get("shared")


def reshard_params(params: Dict[str, Any], *, new_pipe: int,
                   old_pipe: Optional[int] = None) -> Dict[str, Any]:
    """Re-factor stage params (stacked or ragged) to the canonical
    stacked layout for ``new_pipe`` stages, preserving flat layer
    order.  Stage layouts without a layer stack (e.g. enc-dec
    ``{"enc", "dec"}``) pass through untouched, as do any extra stage
    keys."""
    out = dict(params)
    raw = params["stages"]
    if not isinstance(raw, (tuple, list)) and "layers" not in raw:
        out["stages"] = dict(raw)
        return out
    flat = _flat_layers(raw)

    def leaf(a):
        if a.shape[0] % new_pipe:
            raise ValueError(
                f"{a.shape[0]} layers not divisible by {new_pipe}")
        return a.reshape((new_pipe, a.shape[0] // new_pipe) + a.shape[1:])

    stages: Dict[str, Any] = (dict(raw) if isinstance(raw, dict) else {})
    stages["layers"] = jax.tree.map(leaf, flat)
    # per-stage shared blocks (zamba2) replicate/slice to the new count
    shared = _shared_blocks(params["stages"])
    if shared is not None:
        def sleaf(a):
            reps = (new_pipe + a.shape[0] - 1) // a.shape[0]
            return jnp.tile(a, (reps,) + (1,) * (a.ndim - 1))[:new_pipe]
        stages["shared"] = jax.tree.map(sleaf, shared)
    out["stages"] = stages
    return out


def elastic_restate(model_old, model_new, state: Dict[str, Any],
                    batch_sds, *, mode: str = "spectrain",
                    ticks_per_step: int = 1, plan=None) -> Dict[str, Any]:
    """Full state transition between two Model instances (new mesh plan).

    ``plan``: optional ``repro.planner.PipelinePlan`` for the *new*
    topology.  A stream plan flows into ``pipeline_stream.make_state``
    (ragged per-stage trees per its partition); an IR-schedule plan
    (1f1b / 2bw / interleaved / gpipe) builds an IR-interpreter state
    instead, regrouping the carried-over layers into the plan's
    ``n_chunks`` chunk trees — an elastic event can therefore also move
    a job between schedule families, at the usual cost of dropping the
    in-flight microbatches (and, for 2BW, restarting the double buffer
    from the carried weights)."""
    from repro.core import pipeline_stream
    params = reshard_params(state["params"],
                            new_pipe=model_new.n_stages,
                            old_pipe=model_old.n_stages)
    ir_plan = plan is not None and \
        plan.schedule in pipeline_stream.IR_SCHEDULES
    if ir_plan:
        new_state = pipeline_stream.make_ir_state(
            model_new, params, batch_sds, plan=plan, mode=mode)
        sizes = plan.partition.sizes()
        n_chunks: Any = plan.n_chunks
    else:
        new_state = pipeline_stream.make_state(
            model_new, params, batch_sds, mode=mode,
            ticks_per_step=ticks_per_step, plan=plan)
        sizes = (plan.partition.sizes() if plan is not None
                 else (model_new.layers_per_stage,) * model_new.n_stages)
        n_chunks = None
    # momentum carries over (same restack), so prediction stays warm;
    # mirror the layout the state constructor chose for the new params
    # (ragged per-(chunk-)stage trees when model_new pipelines, stacked
    # otherwise)
    mom_stacked = reshard_params(
        {"stages": state["momentum"]["stages"]},
        new_pipe=model_new.n_stages)["stages"]
    if isinstance(new_state["params"]["stages"], (tuple, list)):
        mom_stages: Any = model_new.partition_stage_params(
            mom_stacked, sizes, n_chunks=n_chunks)
    else:
        mom_stages = mom_stacked
    new_state["momentum"] = {"outer": state["momentum"]["outer"],
                             "stages": mom_stages}
    if "stash" in new_state:
        # 2BW restarts its double buffer from the carried-over weights
        new_state["stash"] = {
            "params": jax.tree.map(jnp.array, new_state["params"]),
            "momentum": jax.tree.map(jnp.array, new_state["momentum"]),
        }
    new_state["step"] = state["step"]
    return new_state
