"""Elastic scaling: reshard a training state across mesh plans.

Grow/shrink the data axis, or re-factor the model axis into a different
(pipe, tensor) split: the flattened layer order is preserved while the
stage weights are repartitioned into the new topology's ragged
per-stage trees (any layer count over any stage count — the only hard
error is a stage that would be empty), optimizer state follows, and
in-flight pipeline rings are re-initialized (the ≤2(S−1) in-flight
microbatches are dropped — an elastic event costs one pipeline refill,
which is the industry-standard trade).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models.model import (flat_stage_layers, pack_chunk_params,
                                split_flat_stages, uniform_stage_sizes,
                                unpack_chunk_params)


def unpack_mpmd_state(state: Dict[str, Any]) -> Dict[str, Any]:
    """Packed MPMD training state -> the ragged canonical layout.

    Detected by the top-level ``chunk_sizes`` leaf the packed layout
    carries; params/momentum (and the 2BW stash, when present) unpack
    to per-chunk ragged trees so one repartition path serves every
    source layout."""
    sizes = tuple(int(s) for s in jax.device_get(state["chunk_sizes"]))

    def un(tree):
        return {"outer": tree["outer"],
                "stages": unpack_chunk_params(tree["stages"], sizes)}

    out = {k: v for k, v in state.items() if k != "chunk_sizes"}
    out["params"] = un(state["params"])
    out["momentum"] = un(state["momentum"])
    if "stash" in state:
        out["stash"] = {"params": un(state["stash"]["params"]),
                        "momentum": un(state["stash"]["momentum"])}
    return out


def restack_stages(stages: Any, new_pipe: int) -> Any:
    """[S, Lps, ...] -> [S', L/S', ...] preserving flat layer order.

    Legacy stacked-layout helper (checkpoint migration / tests); the
    live elastic path repartitions into ragged trees via
    :func:`reshard_params` instead and has no divisibility constraint.
    """
    def leaf(a):
        total = a.shape[0] * a.shape[1]
        if total % new_pipe:
            raise ValueError(f"{total} layers not divisible by {new_pipe}")
        return a.reshape((new_pipe, total // new_pipe) + a.shape[2:])

    return jax.tree.map(leaf, stages)


def _shared_blocks(stages: Any) -> Optional[Any]:
    if isinstance(stages, (tuple, list)):
        if "shared" not in stages[0]:
            return None
        return jax.tree.map(lambda *xs: jnp.stack(xs, 0),
                            *[t["shared"] for t in stages])
    return stages.get("shared")


def reshard_params(params: Dict[str, Any], *, new_pipe: int,
                   sizes: Optional[Sequence[int]] = None,
                   old_pipe: Optional[int] = None) -> Dict[str, Any]:
    """Repartition stage params (ragged or legacy stacked) into the
    ragged canonical trees for a new topology, preserving flat layer
    order.

    ``sizes``: per-stage layer counts for the new split (a planner
    ``Partition.sizes()``); defaults to the uniform split with the
    remainder on early stages.  The only hard error is an empty stage
    (more stages than layers) — no divisibility requirement.  Stage
    layouts without a layer stack (e.g. enc-dec ``{"enc", "dec"}``)
    pass through untouched, as do any extra param keys."""
    del old_pipe  # layer order is recovered from the trees themselves
    out = dict(params)
    raw = params["stages"]
    if not isinstance(raw, (tuple, list)) and "layers" not in raw:
        out["stages"] = dict(raw)
        return out
    flat_stages: Dict[str, Any] = {"layers": flat_stage_layers(raw)}
    L = jax.tree.leaves(flat_stages["layers"])[0].shape[0]
    if sizes is None:
        sizes = uniform_stage_sizes(L, new_pipe)
    sizes = tuple(int(n) for n in sizes)
    if sum(sizes) != L or min(sizes) < 1:
        raise ValueError(f"sizes {sizes} do not tile {L} layers "
                         f"(empty stages are not executable)")
    # per-stage shared blocks (zamba2) replicate/slice to the new count
    shared = _shared_blocks(raw)
    if shared is not None:
        def sleaf(a):
            r = (len(sizes) + a.shape[0] - 1) // a.shape[0]
            return jnp.tile(a, (r,) + (1,) * (a.ndim - 1))[:len(sizes)]
        flat_stages["shared"] = jax.tree.map(sleaf, shared)
    out["stages"] = split_flat_stages(flat_stages, sizes)
    return out


def elastic_restate(model_old, model_new, state: Dict[str, Any],
                    batch_sds, *, mode: str = "spectrain",
                    ticks_per_step: int = 1, plan=None,
                    registry=None, execution: Optional[str] = None,
                    mesh=None, **legacy) -> Dict[str, Any]:
    """Full state transition between two Model instances (new mesh plan).

    ``plan``: optional ``repro.planner.PipelinePlan`` for the *new*
    topology.  A stream plan flows into ``pipeline_stream.make_state``
    (ragged per-stage trees per its partition); an IR-schedule plan
    (1f1b / 2bw / interleaved / gpipe) builds an IR-interpreter state
    instead, regrouping the carried-over layers into the plan's
    ``n_chunks`` chunk trees — an elastic event can therefore also move
    a job between schedule families, at the usual cost of dropping the
    in-flight microbatches (and, for 2BW, restarting the double buffer
    from the carried weights).  Without a plan the new model's default
    (uniform, remainder-first) partition is used — ragged layer counts
    restate fine; the only hard error is a stage that would be empty.

    ``execution`` / ``mesh``: execution backend for the *new* IR state —
    ``"mpmd"`` packs the repartitioned weights and momentum into the
    stage-local layout and places them on the pipe mesh (see
    ``pipeline_stream.make_ir_state``); a packed *input* state is
    detected by its ``chunk_sizes`` leaf and unpacked first, so
    elastic events move freely between the two backends.

    ``registry``: optional ``obs.MetricsRegistry`` — the transition is
    recorded as one ``elastic_restate`` event (old/new pipe width,
    schedule, carried step).
    """
    from repro.core import pipeline_stream
    execution = pipeline_stream._resolve_execution(
        execution, legacy, "elastic_restate")
    if "chunk_sizes" in state:
        state = unpack_mpmd_state(state)
    ir_plan = plan is not None and \
        plan.schedule in pipeline_stream.IR_SCHEDULES
    if execution != "spmd" and not ir_plan:
        raise ValueError(
            f"execution={execution!r} needs an IR-schedule plan "
            f"({pipeline_stream.IR_SCHEDULES})")
    if plan is not None:
        sizes: Any = plan.partition.sizes()
    else:
        sizes = model_new.stage_sizes
    params = reshard_params(state["params"], new_pipe=model_new.n_stages,
                            sizes=sizes)
    if ir_plan:
        new_state = pipeline_stream.make_ir_state(
            model_new, params, batch_sds, plan=plan, mode=mode,
            execution=execution, mesh=mesh)
    else:
        new_state = pipeline_stream.make_state(
            model_new, params, batch_sds, mode=mode,
            ticks_per_step=ticks_per_step, plan=plan)
    # momentum carries over (same repartition), so prediction stays warm;
    # mirror the layout the state constructor chose for the new params
    # (ragged per-(chunk-)stage trees when model_new pipelines)
    mom_stages = reshard_params(
        {"stages": state["momentum"]["stages"]},
        new_pipe=model_new.n_stages, sizes=sizes)["stages"]
    if ir_plan and execution == "mpmd":
        # the packed backend mirrors the packed param layout (and its
        # placement) for the carried momentum
        packed_mom, _ = pack_chunk_params(
            list(mom_stages), plan.n_devices)
        mom_stages = jax.device_put(
            packed_mom, jax.tree.map(lambda x: x.sharding,
                                     new_state["momentum"]["stages"]))
    elif not isinstance(new_state["params"]["stages"], (tuple, list)):
        # non-pipelined stage layouts (enc-dec) pass through unchanged
        mom_stages = state["momentum"]["stages"]
    new_state["momentum"] = {"outer": state["momentum"]["outer"],
                             "stages": mom_stages}
    if "stash" in new_state:
        # 2BW restarts its double buffer from the carried-over weights
        new_state["stash"] = {
            "params": jax.tree.map(jnp.array, new_state["params"]),
            "momentum": jax.tree.map(jnp.array, new_state["momentum"]),
        }
    new_state["step"] = state["step"]
    if registry is not None:
        registry.emit(
            "elastic_restate",
            old_pipe=model_old.n_stages, new_pipe=model_new.n_stages,
            schedule=(plan.schedule if plan is not None else "stream"),
            execution=execution, step=int(state["step"]))
    return new_state
