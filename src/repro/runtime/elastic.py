"""Elastic scaling: reshard a training state across mesh plans.

Grow/shrink the data axis, or re-factor the model axis into a different
(pipe, tensor) split: stage-stacked parameters [S, L/S, ...] are restacked
to [S', L/S', ...] (same flattened layer order), optimizer state follows,
and in-flight pipeline rings are re-initialized (the ≤2(S−1) in-flight
microbatches are dropped — an elastic event costs one pipeline refill,
which is the industry-standard trade).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


def restack_stages(stages: Any, new_pipe: int) -> Any:
    """[S, Lps, ...] -> [S', L/S', ...] preserving flat layer order."""
    def leaf(a):
        total = a.shape[0] * a.shape[1]
        if total % new_pipe:
            raise ValueError(f"{total} layers not divisible by {new_pipe}")
        return a.reshape((new_pipe, total // new_pipe) + a.shape[2:])

    return jax.tree.map(leaf, stages)


def reshard_params(params: Dict[str, Any], *, new_pipe: int,
                   old_pipe: Optional[int] = None) -> Dict[str, Any]:
    out = dict(params)
    stages = dict(params["stages"])
    if "layers" in stages:
        stages["layers"] = restack_stages(
            {"x": stages["layers"]}, new_pipe)["x"]
    # per-stage shared blocks (zamba2) replicate/slice to the new count
    if "shared" in stages:
        def leaf(a):
            reps = (new_pipe + a.shape[0] - 1) // a.shape[0]
            return jnp.tile(a, (reps,) + (1,) * (a.ndim - 1))[:new_pipe]
        stages["shared"] = jax.tree.map(leaf, stages["shared"])
    out["stages"] = stages
    return out


def elastic_restate(model_old, model_new, state: Dict[str, Any],
                    batch_sds, *, mode: str = "spectrain",
                    ticks_per_step: int = 1) -> Dict[str, Any]:
    """Full state transition between two Model instances (new mesh plan)."""
    from repro.core import pipeline_stream
    params = reshard_params(state["params"],
                            new_pipe=model_new.n_stages,
                            old_pipe=model_old.n_stages)
    new_state = pipeline_stream.make_state(
        model_new, params, batch_sds, mode=mode,
        ticks_per_step=ticks_per_step)
    # momentum carries over (same restack), so prediction stays warm
    mom = dict(state["momentum"])
    mom_stages = dict(mom["stages"]) if isinstance(mom.get("stages"), dict) \
        else mom["stages"]
    new_mom = {"outer": mom["outer"],
               "stages": reshard_params({"stages": mom["stages"]},
                                        new_pipe=model_new.n_stages)["stages"]}
    new_state["momentum"] = new_mom
    new_state["step"] = state["step"]
    return new_state
