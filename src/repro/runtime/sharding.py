"""Logical-axis sharding rules (MaxText-style).

Every parameter leaf carries a tuple of logical axis names (from its
ParamSpec); every activation constraint site names logical axes.  A
per-arch rule table maps logical axes -> mesh axes; spec construction
drops any assignment that does not divide the dimension or that would
reuse a mesh axis already consumed by an earlier dim of the same leaf.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.runtime.mesh_utils import axis_sizes

AxisVal = Union[None, str, Tuple[str, ...]]


def logical_rules(cfg: ArchConfig, mesh: Mesh, *,
                  zero1: bool = True) -> Dict[str, AxisVal]:
    plan = cfg.mesh_plan
    sizes = axis_sizes(mesh)
    has_pod = "pod" in sizes
    tensor = sizes.get("tensor", 1)
    batch: AxisVal = ("pod", "data") if has_pod else ("data",)

    rules: Dict[str, AxisVal] = {
        # --- params -------------------------------------------------------
        "stage": "pipe" if plan.pipe_role == "stage" else None,
        "layer": None,
        "embed": "data" if plan.fsdp else None,
        "embed2": None,
        "heads": "tensor" if cfg.n_heads % tensor == 0 else None,
        "kv": "tensor" if (cfg.n_kv_heads % tensor == 0) else None,
        "mlp": "tensor" if cfg.d_ff % tensor == 0 else None,
        "vocab": "tensor",
        "expert": "tensor",
        "ssm": "tensor",
        # --- activations ----------------------------------------------------
        "act_batch": batch,
        "act_seq": "pipe" if plan.pipe_role == "context" else None,
        # --- decode caches ------------------------------------------
        "act_kvseq": None,
        "head_dim": None,
        "state": None,
    }
    if cfg.moe is not None and cfg.moe.num_experts % tensor != 0:
        rules["expert"] = None
    return rules


def decode_rules(cfg: ArchConfig, mesh: Mesh, *, global_batch: int
                 ) -> Dict[str, AxisVal]:
    """Rules for serve_step cells.  When the request batch cannot occupy the
    data axis (long-context B=1), shard the KV-cache sequence dim over it
    instead (context-parallel cache)."""
    rules = logical_rules(cfg, mesh)
    sizes = axis_sizes(mesh)
    d_sz = sizes.get("data", 1)
    pod = sizes.get("pod", 1)
    if global_batch % (d_sz * pod) != 0:
        rules["act_batch"] = None
        rules["act_kvseq"] = "data"
    # decode has seq len 1 — never context-shard activations
    rules["act_seq"] = None
    return rules


def _resolve(axis: Optional[str], rules: Dict[str, AxisVal]) -> AxisVal:
    if axis is None:
        return None
    return rules.get(axis)


def spec_for_leaf(axes: Sequence[Optional[str]], shape: Sequence[int],
                  rules: Dict[str, AxisVal], sizes: Dict[str, int]) -> P:
    used: set = set()
    out = []
    for ax, dim in zip(axes, shape):
        val = _resolve(ax, rules)
        if val is None:
            out.append(None)
            continue
        names = (val,) if isinstance(val, str) else tuple(val)
        names = tuple(n for n in names if n in sizes and n not in used)
        prod = int(np.prod([sizes[n] for n in names])) if names else 1
        if not names or prod == 1 or dim % prod != 0:
            out.append(None)
            continue
        used.update(names)
        out.append(names[0] if len(names) == 1 else names)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shardings_for(axes_tree: Any, sds_tree: Any, mesh: Mesh,
                  rules: Dict[str, AxisVal]):
    """NamedSharding pytree for (axes, ShapeDtypeStruct) trees."""
    sizes = axis_sizes(mesh)

    def leaf(axes, sds):
        return NamedSharding(
            mesh, spec_for_leaf(axes, sds.shape, rules, sizes))

    return jax.tree.map(leaf, axes_tree, sds_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, (str, type(None))) for a in x))


def momentum_rules(cfg: ArchConfig, rules: Dict[str, AxisVal],
                   mesh: Mesh) -> Dict[str, AxisVal]:
    """ZeRO-1: momentum additionally sharded over the data axis on the
    first shardable (so far unsharded) dim — realized by remapping the
    'embed' logical axis of optimizer-state leaves to 'data'."""
    r = dict(rules)
    if r.get("embed") is None:
        r["embed"] = "data"
    return r


# rings sharded over tensor on the embed dim by default; dryrun
# --no-ring-tp flips this (replicate rings: more memory, fewer gathers)
_RING_TP = True

_is_axes = lambda x: isinstance(x, tuple) and all(
    isinstance(a, (str, type(None))) for a in x)


def stage_submeshes(mesh: Mesh, n_stages: int):
    """Per-pipe-coordinate sub-meshes: one per pipe index, stage (or
    chunk) ``i`` folding onto sub-mesh ``i % pipe_size``.

    Sub-mesh ``k`` holds every device at pipe index ``k`` and keeps the
    remaining mesh axes, so within one stage the usual data/tensor
    sharding rules still apply — only the ``pipe`` axis is consumed by
    *placement* instead of a PartitionSpec.

    A pipe axis smaller than ``n_stages`` is accepted when it divides it
    (Megatron round-robin folding — the same ``i % S`` rule virtual
    stages already use); a mesh with no ``pipe`` axis, or one that does
    not divide the stage count, cannot place the stages and raises
    instead of silently returning nothing."""
    names = mesh.axis_names
    if "pipe" not in names:
        raise ValueError(
            f"mesh axes {dict(zip(names, mesh.devices.shape))} have no "
            f"'pipe' axis to place {n_stages} pipeline stages on")
    axis = names.index("pipe")
    pipe = mesh.devices.shape[axis]
    if n_stages % pipe:
        raise ValueError(
            f"'pipe' axis of size {pipe} cannot place {n_stages} stages: "
            f"stage count must be a multiple of the pipe size so stages "
            f"fold round-robin (stage i -> pipe index i % {pipe})")
    sub_names = tuple(n for n in names if n != "pipe")
    subs = []
    for k in range(pipe):
        devs = np.take(mesh.devices, k, axis=axis)
        if not sub_names:       # pure-pipe mesh: one device per stage
            subs.append(Mesh(devs.reshape(1), ("_stage_local",)))
        else:
            subs.append(Mesh(devs, sub_names))
    return subs


def mpmd_pipe_mesh(n_devices: int, devices=None) -> Mesh:
    """The default 1-D ``('pipe',)`` mesh the MPMD execution path runs
    over: the first ``n_devices`` local devices, one pipeline stage
    each."""
    devs = list(jax.devices() if devices is None else devices)
    if len(devs) < n_devices:
        raise ValueError(
            f"mpmd needs {n_devices} devices for the pipe axis, have "
            f"{len(devs)} (set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n_devices} to fake them on CPU)")
    return Mesh(np.asarray(devs[:n_devices]), ("pipe",))


def mpmd_state_shardings(mesh: Mesh, state_sds: Dict[str, Any]):
    """NamedShardings for the packed MPMD train state.

    Every packed ``stages`` leaf is ``[v, S, Lmax, ...]`` with chunk
    ``q`` at index ``[q // S, q % S]`` (``models.model.pack_chunk_params``)
    — ``P(None, 'pipe')`` on dim 1 therefore pins each chunk's weights,
    momentum and 2BW stash wholly to its pipe device; the outer
    (embed/head) weights, step counter and ``chunk_sizes`` vector stay
    replicated."""
    packed = NamedSharding(mesh, P(None, "pipe"))
    rep = NamedSharding(mesh, P())

    def params_like(t):
        return {"outer": jax.tree.map(lambda _: rep, t["outer"]),
                "stages": jax.tree.map(lambda _: packed, t["stages"])}

    out: Dict[str, Any] = {
        "params": params_like(state_sds["params"]),
        "momentum": params_like(state_sds["momentum"]),
        "step": rep,
    }
    if "chunk_sizes" in state_sds:
        out["chunk_sizes"] = rep
    if "stash" in state_sds:
        out["stash"] = {
            "params": params_like(state_sds["stash"]["params"]),
            "momentum": params_like(state_sds["stash"]["momentum"]),
        }
    return out


def _stage_tree_shardings(model, stages_sds, mesh_of, rules,
                          *, lead_axes=()):
    """Shardings for a tuple of ragged (chunk-)stage trees.

    ``mesh_of(i)`` picks the mesh for tree ``i`` (the full mesh for
    SPMD replication, or stage ``i % S``'s sub-mesh for explicit
    placement); ``lead_axes`` prefixes every leaf's logical axes (the
    pipedream weight ring adds a leading ring dim)."""
    n = len(stages_sds)
    stage_axes = model.ragged_stage_axes(n)
    out = []
    for i in range(n):
        mesh_i = mesh_of(i)
        sizes_i = axis_sizes(mesh_i)

        def leaf(axes, sds):
            spec = spec_for_leaf(tuple(lead_axes) + tuple(axes), sds.shape,
                                 rules, sizes_i)
            return NamedSharding(mesh_i, spec)

        out.append(jax.tree.map(leaf, stage_axes[i], stages_sds[i],
                                is_leaf=_is_axes))
    return type(stages_sds)(out)


def _state_shardings(model, state_sds: Dict[str, Any], mesh: Mesh,
                     rules: Dict[str, AxisVal], *, zero1: bool,
                     stage_mesh_of=None):
    sizes = axis_sizes(mesh)
    param_axes = model.param_axes()
    p_sds = state_sds.get("params", {})
    ragged = isinstance(p_sds.get("stages") if isinstance(p_sds, dict)
                        else None, (tuple, list))
    mesh_of = stage_mesh_of or (lambda i: mesh)
    mom_rules = momentum_rules(None, rules, mesh) if zero1 else rules

    def params_like(sds_tree, r):
        """Shardings for a {"outer", "stages"} tree (params, momentum,
        pred, 2BW stash): ragged stage trees route through the
        per-stage builder, everything else through the rule table."""
        if not ragged:
            return shardings_for(param_axes, sds_tree, mesh, r)
        return {
            "outer": shardings_for(param_axes["outer"], sds_tree["outer"],
                                   mesh, r),
            "stages": _stage_tree_shardings(model, sds_tree["stages"],
                                            mesh_of, r),
        }

    act_rules = dict(rules)
    act_rules["act_embed"] = "tensor" if _RING_TP else None
    rep = NamedSharding(mesh, P())

    def by_axes(axes, sds, r):
        return NamedSharding(mesh, spec_for_leaf(axes, sds.shape, r, sizes))

    out: Dict[str, Any] = {
        "params": params_like(state_sds["params"], rules),
        "momentum": params_like(state_sds["momentum"], mom_rules),
        "step": rep,
    }
    if "stash" in state_sds:
        # IR-interpreter 2BW double buffer: previous weight/momentum
        # version, mirroring the live trees' placement leaf-for-leaf
        out["stash"] = {
            "params": params_like(state_sds["stash"]["params"], rules),
            "momentum": params_like(state_sds["stash"]["momentum"],
                                    mom_rules),
        }
    ring_axes = {
        "fwd_buf": ("stage", "act_batch", None, "act_embed"),
        "bwd_buf": ("stage", "act_batch", None, "act_embed"),
        "stash_x": ("stage", None, "act_batch", None, "act_embed"),
    }
    for k, axes in ring_axes.items():
        if k in state_sds:
            out[k] = by_axes(axes, state_sds[k], act_rules)
    if "tick" in state_sds:
        out["tick"] = rep
    if "pred" in state_sds:
        if not ragged:
            raise ValueError(
                "fused-predict states carry ragged stage trees; a "
                "stacked 'pred' layout predates the ragged canonical "
                "form — migrate the state first")
        out["pred"] = {
            "outer": shardings_for(param_axes["outer"],
                                   state_sds["pred"]["outer"], mesh, rules),
            "stages": _stage_tree_shardings(
                model, state_sds["pred"]["stages"], mesh_of, rules),
        }
    if "batch_ring" in state_sds:
        out["batch_ring"] = jax.tree.map(
            lambda s: by_axes(
                (None, "act_batch") + (None,) * (len(s.shape) - 2),
                              s, act_rules),
            state_sds["batch_ring"])
    if "w_stash" in state_sds:
        # ragged stash leaves are [R, ...] (ring first, per stage tree)
        if not ragged:
            raise ValueError(
                "pipedream weight-stash states carry ragged stage "
                "trees; a stacked [S, R, ...] 'w_stash' predates the "
                "ragged canonical form — migrate the state first "
                "(runtime/checkpoint.py restores it bit-exactly)")
        out["w_stash"] = _stage_tree_shardings(
            model, state_sds["w_stash"], mesh_of, rules,
            lead_axes=(None,))
    return out


def stream_state_shardings(model, state_sds: Dict[str, Any], mesh: Mesh,
                           rules: Dict[str, AxisVal], *, zero1: bool = True):
    """NamedShardings for the streaming (or sync / IR-interpreter) state,
    usable as jit in/out shardings (everything lives on the full mesh).

    Handles the ragged per-stage canonical param layout (tuple of
    per-stage trees — including virtual-stage states with
    ``n_chunks = S·v`` chunk trees) plus dict-structured stage layouts
    without a stage stack (enc-dec ``{"enc", "dec"}``).  Pre-ragged
    stacked ``[S, Lps, ...]`` states are *not* shardable here — migrate
    them first (the checkpoint shim restores them bit-exactly onto a
    ragged template).  Ragged stage trees have no leading ``[S]`` dim a
    PartitionSpec could pin to ``pipe``, so inside one SPMD computation
    they shard only over the non-pipe axes (replicating across
    ``pipe``); use :func:`stage_placement_shardings` to *place* the
    materialized state stage-k-on-pipe-device-k and avoid the S×
    weight-memory cost."""
    return _state_shardings(model, state_sds, mesh, rules, zero1=zero1)


def stage_placement_shardings(model, state_sds: Dict[str, Any], mesh: Mesh,
                              rules: Dict[str, AxisVal], *,
                              zero1: bool = True):
    """Explicit per-stage placement map for a ragged state: a shardings
    pytree for ``jax.device_put`` that pins every leaf of (chunk-)stage
    tree ``i`` — params, momentum, fused-predict mirror, the 2BW double
    buffer, and the pipedream ``w_stash`` ring — onto pipe device
    ``i % S``'s sub-mesh (Megatron folding for virtual stages), sharded
    within the stage by the usual non-pipe rules.

    This is the paper's §3 placement model for differently-shaped stage
    trees: a single PartitionSpec cannot express it, so it is a
    placement *map*, not a jit sharding — rings/outer stay on the full
    mesh, stage weights live only on their stage's devices."""
    subs = stage_submeshes(mesh, model.n_stages)
    return _state_shardings(model, state_sds, mesh, rules, zero1=zero1,
                            stage_mesh_of=lambda i: subs[i % len(subs)])


def batch_specs(cfg: ArchConfig, batch_sds: Dict[str, Any], mesh: Mesh,
                rules: Dict[str, AxisVal]):
    """Shardings for a data batch: leading dim batch, second seq."""
    sizes = axis_sizes(mesh)

    def leaf(sds):
        axes = ["act_batch", "act_seq"] + [None] * (len(sds.shape) - 2)
        return NamedSharding(mesh,
                             spec_for_leaf(axes, sds.shape, rules, sizes))

    return jax.tree.map(leaf, batch_sds)


def cache_specs(cfg: ArchConfig, cache_sds: Any, mesh: Mesh,
                rules: Dict[str, AxisVal]):
    """Decode caches: [L, b, s, kv, hd] / states [L, b, h, ...].

    Heuristic: dim0 layer-stacked -> None; dim1 batch; trailing dims: shard
    the kv/head dim over tensor when divisible, seq over data for
    long-context (batch tiny) when batch cannot use it.
    """
    sizes = axis_sizes(mesh)
    d_sz = sizes.get("data", 1)
    t_sz = sizes.get("tensor", 1)
    bt = rules.get("act_batch") or ("data",)
    bt = (bt,) if isinstance(bt, str) else tuple(bt)

    def leaf(sds):
        shp = sds.shape
        spec: list = [None] * len(shp)
        if len(shp) >= 2:
            bprod = int(np.prod([sizes[n] for n in bt if n in sizes]))
            if shp[1] % bprod == 0 and bprod > 1:
                spec[1] = bt[0] if len(bt) == 1 else bt
            elif len(shp) >= 3 and shp[2] % d_sz == 0:
                spec[2] = "data"   # shard seq/cache length instead
        # shard a heads-like dim over tensor (last-2 preferred)
        for i in range(len(shp) - 1, 1, -1):
            if spec[i] is None and shp[i] % t_sz == 0 and t_sz > 1 and \
                    shp[i] >= t_sz and i >= 2:
                spec[i] = "tensor"
                break
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, cache_sds)
