"""Sharded, atomic, async checkpointing with exact-resume semantics.

Layout:  <dir>/step_<N>/  shard_<p>.npz  +  manifest.json
Commit protocol: write into ``step_<N>.tmp`` then ``os.replace`` — a
directory either exists fully or not at all, so a crash mid-write can
never corrupt the restore path (restart just picks the previous step).
Saving is double-buffered: the host snapshot (device→np) happens on the
step path, the file write on a background thread.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(state) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def save(ckpt_dir: str, state: Any, step: int, *, keep: int = 3,
         background: bool = False) -> "threading.Thread | None":
    os.makedirs(ckpt_dir, exist_ok=True)
    pairs = _flatten(state)         # device->host snapshot happens HERE
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"

    def _write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "shard_0.npz"),
                 **{k: v for k, v in pairs})
        manifest = {"step": step, "keys": [k for k, _ in pairs],
                    "nshards": 1}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(ckpt_dir, keep)

    if background:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep: int):
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name,
                                             "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, template: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int]:
    """Restore onto ``template``'s pytree structure.  If ``shardings`` is
    given (a matching pytree of NamedShardings), leaves are device_put with
    them — this is the elastic-resharding path: the checkpoint written on
    one mesh restores onto any other."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, "shard_0.npz"))
    flat = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else None)
    for i, (path, leaf) in enumerate(flat[0]):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(flat[1], leaves), step
