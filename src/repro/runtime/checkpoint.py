"""Sharded, atomic, async checkpointing with exact-resume semantics.

Layout:  <dir>/step_<N>/  shard_<p>.npz  +  manifest.json
Commit protocol: write into ``step_<N>.tmp`` then ``os.replace`` — a
directory either exists fully or not at all, so a crash mid-write can
never corrupt the restore path (restart just picks the previous step).
Saving is double-buffered: the host snapshot (device→np) happens on the
step path, the file write on a background thread.

Checkpoints are keyed by tree path, so they follow whatever layout the
state carries — today the ragged per-stage canonical layout
(``…/stages/<k>/layers/…``).  Two bit-exact migrations run at restore:

* **stacked → ragged**: a pre-ragged checkpoint (stage weights stacked
  ``[S, Lps, ...]`` under ``…/stages/layers/…``) serves the missing
  per-stage key by slicing stage ``k`` off the leading axis;
* **partition → partition**: a checkpoint written under different
  stage sizes (or stage count) serves a mismatched layer-stack key by
  concatenating its per-stage arrays to the flat ``[L, ...]`` order
  and re-slicing the template's range — a DP-partition run restores
  onto a uniform one and vice versa.  In-flight rings (``w_stash``)
  and per-stage ``shared`` blocks have no flat layer order and raise
  instead of restoring wrong.
* **packed ↔ ragged**: the MPMD backend stores every chunk's layers in
  one ``…/stages/layers/…`` leaf ``[v, S, Lmax, ...]`` (chunk q at
  ``[q//S, q%S]``, zero-padded, partition in a top-level
  ``chunk_sizes`` leaf).  Both directions route through the same flat
  layer order: a packed checkpoint strips its padding and repartitions
  onto ragged (or differently-packed) templates, and a ragged/stacked
  checkpoint packs onto an MPMD template.  ``chunk_sizes`` itself is
  plan metadata and always restores from the template's own value.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(state) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def save(ckpt_dir: str, state: Any, step: int, *, keep: int = 3,
         background: bool = False) -> "threading.Thread | None":
    os.makedirs(ckpt_dir, exist_ok=True)
    pairs = _flatten(state)         # device->host snapshot happens HERE
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"

    def _write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "shard_0.npz"),
                 **{k: v for k, v in pairs})
        manifest = {"step": step, "keys": [k for k, _ in pairs],
                    "nshards": 1}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(ckpt_dir, keep)

    if background:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep: int):
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name,
                                             "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


# `<prefix>/stages/<k>/<rest>` (ragged canonical) whose stacked
# pre-ragged spelling is `<prefix>/stages/<rest>`; also covers the
# pipedream weight ring (`w_stash/<k>/…` ← stacked `w_stash/…`,
# stage-first in both layouts)
_RAGGED_KEY_RE = re.compile(r"^(.*/|)(stages|w_stash)/(\d+)/(.+)$")

# `<prefix>/stages/layers/<rest>` — the packed MPMD layout: every
# chunk's layer stack in one `[v, S, Lmax, ...]` leaf (chunk q at
# `[q//S, q%S]`, zero-padded to Lmax), partition recorded in the
# sibling top-level `chunk_sizes` leaf.  The spelling collides with
# the pre-ragged stacked one; `chunk_sizes`'s presence in the
# checkpoint disambiguates.
_PACKED_KEY_RE = re.compile(r"^(.*/|)stages/(layers/.+)$")


def _pack_group(flat: np.ndarray, sizes, want, key: str) -> np.ndarray:
    """Serve a packed ``[v, S, Lmax, ...]`` template leaf from a
    group's flat ``[L, ...]`` layer stack — the ragged→packed restore
    migration.  Bit-exact on the occupied slots; padding is zero,
    exactly as ``pack_chunk_params`` writes it."""
    total = sum(sizes)
    if flat.shape[0] != total:
        raise ValueError(
            f"checkpoint covers {flat.shape[0]} layers for the group of "
            f"{key!r}, packed template wants {total}")
    v, S = int(want[0]), int(want[1])
    if v * S != len(sizes):
        raise ValueError(
            f"packed template {key!r} holds {v * S} chunk slots, "
            f"chunk_sizes has {len(sizes)} entries")
    if tuple(flat.shape[1:]) != tuple(want[3:]):
        raise ValueError(
            f"checkpoint layers for {key!r} have per-layer shape "
            f"{tuple(flat.shape[1:])}, template wants {tuple(want[3:])}")
    out = np.zeros(tuple(want), flat.dtype)
    lo = 0
    for q, Lq in enumerate(sizes):
        out[q // S, q % S, :Lq] = flat[lo:lo + Lq]
        lo += Lq
    return out


def _migrate_stacked_leaf(key: str, data, want_shape) -> Optional[np.ndarray]:
    """Bit-exact shim: serve a ragged per-stage key from a pre-ragged
    stacked checkpoint.  Stage ``k``'s tree is slice ``k`` of the
    stacked leaf's leading (stage) axis; returns None when the key is
    not a ragged stage key or the stacked spelling is absent."""
    m = _RAGGED_KEY_RE.match(key)
    if m is None:
        return None
    old_key = f"{m.group(1)}{m.group(2)}/{m.group(4)}"
    if old_key not in data.files:
        return None
    stacked = data[old_key]
    k = int(m.group(3))
    if k >= stacked.shape[0]:
        raise ValueError(
            f"stacked checkpoint leaf {old_key!r} has {stacked.shape[0]} "
            f"stages; cannot serve stage {k} for {key!r}")
    arr = stacked[k]
    if tuple(arr.shape) != tuple(want_shape):
        raise ValueError(
            f"stacked checkpoint leaf {old_key!r} stage {k} has shape "
            f"{arr.shape}, template wants {tuple(want_shape)} — the "
            f"migration shim only covers uniform pre-ragged layouts")
    return arr


def _template_group_sizes(flat_with_path) -> dict:
    """{(prefix, rest): {stage index: leading dim}} over the template's
    ragged stage *layer* leaves — the per-group partition the template
    wants, used to repartition a checkpoint written under different
    stage sizes."""
    groups: dict = {}
    for path, leaf in flat_with_path:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        m = _RAGGED_KEY_RE.match(key)
        if m is None or m.group(2) != "stages" or \
                not m.group(4).startswith("layers" + _SEP):
            continue
        shape = getattr(leaf, "shape", np.shape(leaf))
        groups.setdefault((m.group(1), m.group(4)),
                          {})[int(m.group(3))] = int(shape[0])
    return groups


def _repartition_slice(flat: np.ndarray, sizes: dict, k: int, want_shape,
                       key: str) -> np.ndarray:
    """Serve stage ``k``'s slice of a group's flat ``[L, ...]`` layer
    stack under the template partition ``sizes`` — bit-exact, since
    every partition is a view of the same flat layer order.

    Only layer stacks repartition (leading axis = layer); per-stage
    ``shared`` blocks and the in-flight ``w_stash`` ring have no flat
    layer order and must match shapes directly."""
    total = sum(sizes[i] for i in sorted(sizes))
    if flat.shape[0] != total:
        raise ValueError(
            f"checkpoint covers {flat.shape[0]} layers for the group of "
            f"{key!r}, template wants {total}")
    lo = sum(sizes[i] for i in sorted(sizes) if i < k)
    arr = flat[lo:lo + sizes[k]]
    if tuple(arr.shape) != tuple(want_shape):
        raise ValueError(
            f"repartitioned leaf for {key!r} has shape {arr.shape}, "
            f"template wants {tuple(want_shape)}")
    return arr


def restore(ckpt_dir: str, template: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int]:
    """Restore onto ``template``'s pytree structure.  If ``shardings`` is
    given (a matching pytree of NamedShardings), leaves are device_put with
    them — this is the elastic-resharding path: the checkpoint written on
    one mesh restores onto any other.  Pre-ragged stacked checkpoints
    migrate bit-exactly onto ragged templates (see module docstring)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, "shard_0.npz"))
    flat = jax.tree_util.tree_flatten_with_path(template)
    group_sizes = _template_group_sizes(flat[0])
    group_cache: dict = {}
    packed_ckpt = "chunk_sizes" in data.files

    def tmpl_chunk_sizes(key):
        """The packed *template*'s partition, from its own
        ``chunk_sizes`` leaf — packing metadata always comes from the
        template's plan, never the checkpoint (a repartitioned restore
        changes it)."""
        for path, leaf in flat[0]:
            k = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                          for p in path)
            if k.rsplit(_SEP, 1)[-1] == "chunk_sizes":
                if not hasattr(leaf, "__array__"):
                    raise ValueError(
                        f"restoring packed leaf {key!r} needs the "
                        f"template's concrete chunk_sizes values, got "
                        f"{type(leaf).__name__}")
                return tuple(int(s) for s in np.asarray(leaf))
        raise KeyError(
            f"packed template leaf {key!r} has no sibling chunk_sizes "
            f"leaf to define its partition")

    def ckpt_group(prefix, rest):
        """(per-stage layer counts, flat [L, ...] concat) of one leaf
        group as the checkpoint stores it — one decompress+concat pass
        per group, shared by every template leaf that repartitions
        (vec is empty / flat is None when the checkpoint has no ragged
        keys for the group)."""
        g = (prefix, rest)
        if g not in group_cache:
            parts = []
            j = 0
            while f"{prefix}stages/{j}/{rest}" in data.files:
                parts.append(data[f"{prefix}stages/{j}/{rest}"])
                j += 1
            if not parts and packed_ckpt and \
                    f"{prefix}stages/{rest}" in data.files:
                # packed MPMD spelling: [v, S, Lmax, ...] with chunk q
                # at [q//S, q%S]; strip each chunk's padding back to
                # its chunk_sizes[q] real layers — the flat layer
                # order, bit-exact
                a = data[f"{prefix}stages/{rest}"]
                sizes = tuple(int(s) for s in data["chunk_sizes"])
                v, S = int(a.shape[0]), int(a.shape[1])
                if v * S != len(sizes):
                    raise ValueError(
                        f"packed checkpoint leaf for {rest!r} holds "
                        f"{v * S} chunk slots, its chunk_sizes has "
                        f"{len(sizes)} entries")
                a2 = a.reshape((v * S,) + a.shape[2:])
                group_cache[g] = (sizes, np.concatenate(
                    [a2[q, :Lq] for q, Lq in enumerate(sizes)], axis=0))
            elif not parts and f"{prefix}stages/{rest}" in data.files:
                # pre-ragged stacked spelling: [S, Lps, ...] is the
                # same flat layer order, so it repartitions onto any
                # template sizes too (uniform templates keep taking
                # the cheaper per-stage slice via the stacked shim)
                stacked = data[f"{prefix}stages/{rest}"]
                group_cache[g] = (
                    (int(stacked.shape[1]),) * int(stacked.shape[0]),
                    stacked.reshape((-1,) + stacked.shape[2:]))
            else:
                group_cache[g] = (
                    tuple(int(p.shape[0]) for p in parts),
                    np.concatenate(parts, axis=0) if parts else None)
        return group_cache[g]

    leaves = []
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else None)
    for i, (path, leaf) in enumerate(flat[0]):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        want = tuple(getattr(leaf, "shape", np.shape(leaf)))
        arr = None
        m = _RAGGED_KEY_RE.match(key)
        if key.rsplit(_SEP, 1)[-1] == "chunk_sizes":
            # plan metadata, not learned state: the template's own
            # partition always wins (the checkpoint's describes the
            # layout it was *written* under)
            arr = np.asarray(tmpl_chunk_sizes(key), np.int32)
        elif m is not None and m.group(2) == "stages" and \
                m.group(4).startswith("layers" + _SEP):
            # repartitioning is a *group* decision: compare the full
            # stage-size vectors, never per-leaf shapes — a stage whose
            # layer count coincides between two different partitions
            # still covers different flat layers
            grp = group_sizes.get((m.group(1), m.group(4)), {})
            tmpl_vec = tuple(grp[j] for j in sorted(grp))
            c_vec, c_flat = ckpt_group(m.group(1), m.group(4))
            if c_vec and (c_vec != tmpl_vec or packed_ckpt):
                # a packed checkpoint always routes through the flat
                # concat: its stacked-look-alike spelling must not hit
                # the per-stage stacked shim below
                arr = _repartition_slice(c_flat, grp, int(m.group(3)),
                                         want, key)
        elif m is None:
            pm = _PACKED_KEY_RE.match(key)
            if pm is not None:
                c_vec, c_flat = ckpt_group(pm.group(1), pm.group(2))
                if c_flat is not None:
                    sizes = tmpl_chunk_sizes(key)
                    if not (c_vec == sizes and key in data.files and
                            tuple(data[key].shape) == want):
                        arr = _pack_group(c_flat, sizes, want, key)
                    # else: identical packing — fall through to the
                    # direct load below
        if arr is None and key in data.files:
            arr = data[key]
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"checkpoint leaf {key!r} has shape "
                    f"{tuple(arr.shape)}, template wants {want} — not a "
                    f"stage layer stack that can be repartitioned "
                    f"(in-flight rings and shared blocks do not cross "
                    f"partitions; re-init them instead)")
        if arr is None:
            arr = _migrate_stacked_leaf(key, data, want)
        if arr is None:
            raise KeyError(
                f"checkpoint {d} has no leaf {key!r} (and no stacked "
                f"or differently-partitioned spelling to migrate from)")
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(flat[1], leaves), step
