"""Sharded, atomic, async checkpointing with exact-resume semantics.

Layout:  <dir>/step_<N>/  shard_<p>.npz  +  manifest.json
Commit protocol: write into ``step_<N>.tmp`` then ``os.replace`` — a
directory either exists fully or not at all, so a crash mid-write can
never corrupt the restore path (restart just picks the previous step).
Saving is double-buffered: the host snapshot (device→np) happens on the
step path, the file write on a background thread.

Checkpoints are keyed by tree path, so they follow whatever layout the
state carries — today the ragged per-stage canonical layout
(``…/stages/<k>/layers/…``).  Two bit-exact migrations run at restore:

* **stacked → ragged**: a pre-ragged checkpoint (stage weights stacked
  ``[S, Lps, ...]`` under ``…/stages/layers/…``) serves the missing
  per-stage key by slicing stage ``k`` off the leading axis;
* **partition → partition**: a checkpoint written under different
  stage sizes (or stage count) serves a mismatched layer-stack key by
  concatenating its per-stage arrays to the flat ``[L, ...]`` order
  and re-slicing the template's range — a DP-partition run restores
  onto a uniform one and vice versa.  In-flight rings (``w_stash``)
  and per-stage ``shared`` blocks have no flat layer order and raise
  instead of restoring wrong.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(state) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def save(ckpt_dir: str, state: Any, step: int, *, keep: int = 3,
         background: bool = False) -> "threading.Thread | None":
    os.makedirs(ckpt_dir, exist_ok=True)
    pairs = _flatten(state)         # device->host snapshot happens HERE
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"

    def _write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "shard_0.npz"),
                 **{k: v for k, v in pairs})
        manifest = {"step": step, "keys": [k for k, _ in pairs],
                    "nshards": 1}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(ckpt_dir, keep)

    if background:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep: int):
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name,
                                             "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


# `<prefix>/stages/<k>/<rest>` (ragged canonical) whose stacked
# pre-ragged spelling is `<prefix>/stages/<rest>`; also covers the
# pipedream weight ring (`w_stash/<k>/…` ← stacked `w_stash/…`,
# stage-first in both layouts)
_RAGGED_KEY_RE = re.compile(r"^(.*/|)(stages|w_stash)/(\d+)/(.+)$")


def _migrate_stacked_leaf(key: str, data, want_shape) -> Optional[np.ndarray]:
    """Bit-exact shim: serve a ragged per-stage key from a pre-ragged
    stacked checkpoint.  Stage ``k``'s tree is slice ``k`` of the
    stacked leaf's leading (stage) axis; returns None when the key is
    not a ragged stage key or the stacked spelling is absent."""
    m = _RAGGED_KEY_RE.match(key)
    if m is None:
        return None
    old_key = f"{m.group(1)}{m.group(2)}/{m.group(4)}"
    if old_key not in data.files:
        return None
    stacked = data[old_key]
    k = int(m.group(3))
    if k >= stacked.shape[0]:
        raise ValueError(
            f"stacked checkpoint leaf {old_key!r} has {stacked.shape[0]} "
            f"stages; cannot serve stage {k} for {key!r}")
    arr = stacked[k]
    if tuple(arr.shape) != tuple(want_shape):
        raise ValueError(
            f"stacked checkpoint leaf {old_key!r} stage {k} has shape "
            f"{arr.shape}, template wants {tuple(want_shape)} — the "
            f"migration shim only covers uniform pre-ragged layouts")
    return arr


def _template_group_sizes(flat_with_path) -> dict:
    """{(prefix, rest): {stage index: leading dim}} over the template's
    ragged stage *layer* leaves — the per-group partition the template
    wants, used to repartition a checkpoint written under different
    stage sizes."""
    groups: dict = {}
    for path, leaf in flat_with_path:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        m = _RAGGED_KEY_RE.match(key)
        if m is None or m.group(2) != "stages" or \
                not m.group(4).startswith("layers" + _SEP):
            continue
        shape = getattr(leaf, "shape", np.shape(leaf))
        groups.setdefault((m.group(1), m.group(4)),
                          {})[int(m.group(3))] = int(shape[0])
    return groups


def _repartition_slice(flat: np.ndarray, sizes: dict, k: int, want_shape,
                       key: str) -> np.ndarray:
    """Serve stage ``k``'s slice of a group's flat ``[L, ...]`` layer
    stack under the template partition ``sizes`` — bit-exact, since
    every partition is a view of the same flat layer order.

    Only layer stacks repartition (leading axis = layer); per-stage
    ``shared`` blocks and the in-flight ``w_stash`` ring have no flat
    layer order and must match shapes directly."""
    total = sum(sizes[i] for i in sorted(sizes))
    if flat.shape[0] != total:
        raise ValueError(
            f"checkpoint covers {flat.shape[0]} layers for the group of "
            f"{key!r}, template wants {total}")
    lo = sum(sizes[i] for i in sorted(sizes) if i < k)
    arr = flat[lo:lo + sizes[k]]
    if tuple(arr.shape) != tuple(want_shape):
        raise ValueError(
            f"repartitioned leaf for {key!r} has shape {arr.shape}, "
            f"template wants {tuple(want_shape)}")
    return arr


def restore(ckpt_dir: str, template: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int]:
    """Restore onto ``template``'s pytree structure.  If ``shardings`` is
    given (a matching pytree of NamedShardings), leaves are device_put with
    them — this is the elastic-resharding path: the checkpoint written on
    one mesh restores onto any other.  Pre-ragged stacked checkpoints
    migrate bit-exactly onto ragged templates (see module docstring)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, "shard_0.npz"))
    flat = jax.tree_util.tree_flatten_with_path(template)
    group_sizes = _template_group_sizes(flat[0])
    group_cache: dict = {}

    def ckpt_group(prefix, rest):
        """(per-stage layer counts, flat [L, ...] concat) of one leaf
        group as the checkpoint stores it — one decompress+concat pass
        per group, shared by every template leaf that repartitions
        (vec is empty / flat is None when the checkpoint has no ragged
        keys for the group)."""
        g = (prefix, rest)
        if g not in group_cache:
            parts = []
            j = 0
            while f"{prefix}stages/{j}/{rest}" in data.files:
                parts.append(data[f"{prefix}stages/{j}/{rest}"])
                j += 1
            if not parts and f"{prefix}stages/{rest}" in data.files:
                # pre-ragged stacked spelling: [S, Lps, ...] is the
                # same flat layer order, so it repartitions onto any
                # template sizes too (uniform templates keep taking
                # the cheaper per-stage slice via the stacked shim)
                stacked = data[f"{prefix}stages/{rest}"]
                group_cache[g] = (
                    (int(stacked.shape[1]),) * int(stacked.shape[0]),
                    stacked.reshape((-1,) + stacked.shape[2:]))
            else:
                group_cache[g] = (
                    tuple(int(p.shape[0]) for p in parts),
                    np.concatenate(parts, axis=0) if parts else None)
        return group_cache[g]

    leaves = []
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else None)
    for i, (path, leaf) in enumerate(flat[0]):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        want = tuple(getattr(leaf, "shape", np.shape(leaf)))
        arr = None
        m = _RAGGED_KEY_RE.match(key)
        if m is not None and m.group(2) == "stages" and \
                m.group(4).startswith("layers" + _SEP):
            # repartitioning is a *group* decision: compare the full
            # stage-size vectors, never per-leaf shapes — a stage whose
            # layer count coincides between two different partitions
            # still covers different flat layers
            grp = group_sizes.get((m.group(1), m.group(4)), {})
            tmpl_vec = tuple(grp[j] for j in sorted(grp))
            c_vec, c_flat = ckpt_group(m.group(1), m.group(4))
            if c_vec and c_vec != tmpl_vec:
                arr = _repartition_slice(c_flat, grp, int(m.group(3)),
                                         want, key)
        if arr is None and key in data.files:
            arr = data[key]
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"checkpoint leaf {key!r} has shape "
                    f"{tuple(arr.shape)}, template wants {want} — not a "
                    f"stage layer stack that can be repartitioned "
                    f"(in-flight rings and shared blocks do not cross "
                    f"partitions; re-init them instead)")
        if arr is None:
            arr = _migrate_stacked_leaf(key, data, want)
        if arr is None:
            raise KeyError(
                f"checkpoint {d} has no leaf {key!r} (and no stacked "
                f"or differently-partitioned spelling to migrate from)")
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(flat[1], leaves), step
