"""Deterministic, shard-aware, resumable synthetic data pipeline.

Every batch is a pure function of (seed, step): any host can materialize
its shard independently (no coordinator), and resume-from-checkpoint is
exact by construction — the iterator state IS the step counter.

Two stream kinds:
  * ``uniform``  — i.i.d. tokens (throughput/dry-run work);
  * ``bigram``   — sampled from a fixed random bigram table, a learnable
    distribution for convergence experiments (the CIFAR/IMDb stand-in on
    this offline container; see DESIGN.md §6).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "bigram"            # bigram | uniform
    bigram_temp: float = 0.5


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.kind == "bigram":
            rng = np.random.Generator(np.random.Philox(key=cfg.seed))
            logits = rng.normal(size=(cfg.vocab_size, cfg.vocab_size))
            logits = logits / cfg.bigram_temp
            p = np.exp(logits - logits.max(-1, keepdims=True))
            self._P = (p / p.sum(-1, keepdims=True)).astype(np.float64)
            self._cum = np.cumsum(self._P, axis=-1)

    # ------------------------------------------------------------------
    def batch_at(self, step: int, *, shard: int = 0, num_shards: int = 1
                 ) -> Dict[str, np.ndarray]:
        """Batch (or one data shard of it) for a given step."""
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        bs = cfg.global_batch // num_shards
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed + 1, counter=(step * num_shards + shard)))
        if cfg.kind == "uniform":
            toks = rng.integers(0, cfg.vocab_size,
                                size=(bs, cfg.seq_len + 1), dtype=np.int64)
        else:
            toks = np.empty((bs, cfg.seq_len + 1), np.int64)
            toks[:, 0] = rng.integers(0, cfg.vocab_size, size=bs)
            u = rng.random(size=(bs, cfg.seq_len))
            for t in range(cfg.seq_len):
                # inverse-CDF sampling from the bigram row of each prefix
                rows = self._cum[toks[:, t]]
                toks[:, t + 1] = (u[:, t, None] < rows).argmax(-1)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def optimal_loss(self) -> float:
        """Entropy rate of the bigram chain (the achievable loss floor)."""
        if self.cfg.kind != "bigram":
            return float(np.log(self.cfg.vocab_size))
        P = self._P
        # stationary distribution via power iteration
        pi = np.full(P.shape[0], 1.0 / P.shape[0])
        for _ in range(200):
            pi = pi @ P
        H = -(pi[:, None] * P * np.log(np.maximum(P, 1e-12))).sum()
        return float(H)


def make_iterator(data: SyntheticLM, start_step: int = 0, *, shard: int = 0,
                  num_shards: int = 1):
    step = start_step
    while True:
        yield step, data.batch_at(step, shard=shard, num_shards=num_shards)
        step += 1
