from repro.data.pipeline import DataConfig, SyntheticLM, make_iterator  # noqa: F401
