"""Public jit'd wrappers for the Pallas kernels.

These are the TPU runtime entry points; on this CPU container they are
exercised with ``interpret=True`` against the ``ref.py`` oracles.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import mamba2_scan as m2
from repro.kernels import rwkv6_scan as r6
from repro.kernels import fused_update as fu


# ---------------------------------------------------------------------------
# timing hook: obs.MetricsRegistry.kernel_hook() plugs in here.  When no
# hook is set (the default, and the whole training hot path — these
# wrappers only run eagerly on the serve/prefill path) the wrappers are
# untouched: the timed path synchronizes via block_until_ready, which
# would serialize dispatch if left on unconditionally.

_timing_hook: Optional[Callable[[str, float], None]] = None


def set_timing_hook(hook: Optional[Callable[[str, float], None]]) -> None:
    """Install (or clear, with ``None``) a ``hook(kernel_name, microseconds)``
    called after each public kernel wrapper returns."""
    global _timing_hook
    _timing_hook = hook


def _timed(name: str, fn, *args, **kw):
    if _timing_hook is None or any(
            isinstance(a, jax.core.Tracer)
            for a in jax.tree.leaves((args, kw))):
        return fn(*args, **kw)      # no hook, or inside a jit trace
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(*args, **kw))
    _timing_hook(name, (time.perf_counter() - t0) * 1e6)
    return out


# ---------------------------------------------------------------------------
# flash attention with GQA folding + custom VJP


def _fold_gqa(q, KV):
    """[b, sq, H, d] -> [b, KV, G*sq, d] (group heads along seq)."""
    b, sq, H, d = q.shape
    G = H // KV
    q = q.reshape(b, sq, KV, G, d)
    q = jnp.moveaxis(q, 1, 3)                 # [b, KV, G, sq, d]
    return q.reshape(b, KV, G * sq, d)


def _unfold_gqa(o, H, sq):
    b, KV, gs, d = o.shape
    G = H // KV
    o = o.reshape(b, KV, G, sq, d)
    o = jnp.moveaxis(o, 3, 1)                 # [b, sq, KV, G, d]
    return o.reshape(b, sq, H, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: [b, sq, H, d]; k, v: [b, sk, KV, d] (H % KV == 0).
    Returns o: [b, sq, H, d]."""
    o, _ = _flash_fwd(q, k, v, causal, block_q, block_k, interpret)
    return o


def _flash_fwd(q, k, v, causal, block_q, block_k, interpret):
    b, sq, H, d = q.shape
    KV = k.shape[2]
    qf = _fold_gqa(q, KV)
    kf = jnp.swapaxes(k, 1, 2)                # [b, KV, sk, d]
    vf = jnp.swapaxes(v, 1, 2)
    o, lse = fa.flash_fwd(qf, kf, vf, causal=causal, block_q=block_q,
                          block_k=block_k, interpret=interpret)
    return _unfold_gqa(o, H, sq), (qf, kf, vf, o, lse)


def _flash_fwd_rule(q, k, v, causal, block_q, block_k, interpret):
    o, res = _flash_fwd(q, k, v, causal, block_q, block_k, interpret)
    return o, res


def _flash_bwd_rule(causal, block_q, block_k, interpret, res, do):
    qf, kf, vf, of, lse = res
    b, KV, gs, d = qf.shape
    H = do.shape[2]
    sq = do.shape[1]
    dof = _fold_gqa(do, KV)
    dq, dk, dv = fa.flash_bwd(qf, kf, vf, of, lse, dof, causal=causal,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return (_unfold_gqa(dq, H, sq),
            jnp.swapaxes(dk, 1, 2), jnp.swapaxes(dv, 1, 2))


flash_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)


# ---------------------------------------------------------------------------
# recurrences (inference/prefill path; training uses the jnp scan refs)


def rwkv6_scan(r, k, v, w, u, S0, *, chunk: int = 32,
               interpret: bool = False):
    """Layout [b, s, h, hd] (model-side) -> kernel layout [b, h, s, hd]."""
    return _timed("rwkv6_scan", _rwkv6_scan, r, k, v, w, u, S0,
                  chunk=chunk, interpret=interpret)


def _rwkv6_scan(r, k, v, w, u, S0, *, chunk, interpret):
    tr = lambda t: jnp.swapaxes(t, 1, 2)
    y, sT = r6.rwkv6_scan(tr(r), tr(k), tr(v), tr(w), u, S0,
                          chunk=chunk, interpret=interpret)
    return tr(y), sT


def mamba2_scan(x, dt, decay, B, C, S0, *, chunk: int = 32,
                interpret: bool = False):
    """Model-side layouts: x [b,s,h,p]; dt/decay [b,s,h]; B,C [b,s,g,n]
    (groups broadcast to heads here)."""
    return _timed("mamba2_scan", _mamba2_scan, x, dt, decay, B, C, S0,
                  chunk=chunk, interpret=interpret)


def _mamba2_scan(x, dt, decay, B, C, S0, *, chunk, interpret):
    h = x.shape[2]
    g = B.shape[2]
    rep = h // g
    tr = lambda t: jnp.swapaxes(t, 1, 2)
    Bh = tr(jnp.repeat(B, rep, axis=2))
    Ch = tr(jnp.repeat(C, rep, axis=2))
    y, sT = m2.mamba2_scan(tr(x), jnp.moveaxis(dt, 1, 2),
                           jnp.moveaxis(decay, 1, 2), Bh, Ch, S0,
                           chunk=chunk, interpret=interpret)
    return tr(y), sT


fused_update = fu.fused_update
