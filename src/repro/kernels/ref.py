"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# flash attention


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: [b,h,sq,d]; k,v: [b,hkv,sk,d] (GQA: h % hkv == 0).  fp32 softmax."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = scale or (1.0 / jnp.sqrt(d).astype(jnp.float32))
    qg = q.reshape(b, hkv, g, sq, d)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[2]), bool),
                        k.shape[2] - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v)
    return o.reshape(b, h, sq, v.shape[-1])


# ---------------------------------------------------------------------------
# rwkv6 wkv recurrence (time-major chunk-free scan)


def rwkv6_ref(r, k, v, w, u, S0):
    """r,k,v,w: [b,h,s,hd]; u: [h,hd]; S0: [b,h,hd,hd] (fp32).
    Returns (y [b,h,s,hd] fp32, S_T fp32)."""
    f32 = jnp.float32
    r, k, v, w = (t.astype(f32) for t in (r, k, v, w))
    u = u.astype(f32)

    def step(S, rkvw):
        rt, kt, vt, wt = rkvw                       # [b,h,hd]
        kv = kt[..., :, None] * vt[..., None, :]    # [b,h,hd,hd]
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[..., :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (r, k, v, w))
    S_T, ys = jax.lax.scan(step, S0.astype(f32), xs)
    return jnp.moveaxis(ys, 0, 2), S_T


# ---------------------------------------------------------------------------
# mamba2 ssd recurrence


def mamba2_ref(x, dt, decay, B, C, S0):
    """x: [b,h,s,p]; dt,decay: [b,h,s]; B,C: [b,h,s,n]; S0: [b,h,p,n] fp32.
    Returns (y [b,h,s,p] fp32, S_T)."""
    f32 = jnp.float32
    x, dt, decay, B, C = (t.astype(f32) for t in (x, dt, decay, B, C))

    def step(S, inp):
        x_t, dt_t, de_t, B_t, C_t = inp
        S = S * de_t[..., None, None] + \
            (dt_t[..., None] * x_t)[..., :, None] * B_t[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", S, C_t)
        return S, y

    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (x, dt, decay, B, C))
    S_T, ys = jax.lax.scan(step, S0.astype(f32), xs)
    return jnp.moveaxis(ys, 0, 2), S_T


# ---------------------------------------------------------------------------
# fused momentum update + SpecTrain prediction


def fused_update_ref(w, v, g, *, lr, gamma, s):
    """Momentum-SGD update (Eq. 1/2) + weight prediction (Eq. 4), fused.

    Returns (w', v', ŵ) where
      v' = γ·v + (1−γ)·g
      w' = w − η·v'
      ŵ  = w' − s·η·v'        (prediction for s steps ahead of w')
    """
    f32 = jnp.float32
    vf = gamma * v.astype(f32) + (1.0 - gamma) * g.astype(f32)
    wf = w.astype(f32) - lr * vf
    what = wf - s * lr * vf
    return wf.astype(w.dtype), vf, what.astype(w.dtype)
