"""Flash attention as a Pallas TPU kernel (fwd + bwd), VMEM-tiled.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * tiles are MXU-aligned (block_q x d and block_k x d with d padded to the
    128-lane register shape by the caller/ops.py);
  * the softmax running max / denominator / output accumulator live in VMEM
    scratch across the sequential `k` grid dimension
    (dimension_semantics: the last grid dim is "arbitrary" = sequential,
    everything else parallel);
  * GQA is folded to MHA by stacking the G query heads of a group along the
    sequence axis (positions recovered with mod-sq arithmetic), so the k/v
    blocks for a group are fetched once — the TPU analogue of shared-memory
    reuse across warps.

Oracle: repro.kernels.ref.attention_ref.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams in newer jax
_CompilerParams = (getattr(pltpu, "CompilerParams", None)
                   or pltpu.TPUCompilerParams)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# forward


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *,
                scale, causal, block_q, block_k, sq, sk, nk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)           # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)           # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    rows = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < sk
    if causal:
        qpos = jnp.remainder(rows, sq)            # GQA group-folding
        mask = mask & (qpos >= kpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=-1)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_scr[...] = corr[:, None] * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        lsum = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / lsum[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_scr[...] + jnp.log(lsum)


def flash_fwd(q, k, v, *, causal: bool = True, block_q: int = 128,
              block_k: int = 128, interpret: bool = False
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q: [b, h, sq_folded, d] (GQA pre-folded); k, v: [b, h, sk, d].

    Returns (o, lse).  ``sq_folded = G * sq`` when folding; causal masking
    recovers positions as ``row % sq`` with sq == sk."""
    b, h, sqf, d = q.shape
    sk = k.shape[2]
    sq = sk if causal else sqf
    nq = (sqf + block_q - 1) // block_q
    nk = (sk + block_k - 1) // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, sq=sq, sk=sk, nk=nk)
    o, lse = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sqf, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, sqf), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# backward: dq


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dq_ref,
               acc_scr, *, scale, causal, block_q, block_k, sq, sk, nk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)
    dl = dl_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    rows = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < sk
    if causal:
        mask = mask & (jnp.remainder(rows, sq) >= kpos)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dl[:, None]) * scale
    acc_scr[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _done():
        dq_ref[0, 0] = acc_scr[...].astype(dq_ref.dtype)


# ---------------------------------------------------------------------------
# backward: dk / dv


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *,
                scale, causal, block_q, block_k, sq, sk, nq):
    ik = pl.program_id(2)
    iq = pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)
    dl = dl_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    rows = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < sk
    if causal:
        mask = mask & (jnp.remainder(rows, sq) >= kpos)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dv_scr[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dl[:, None]) * scale
    dk_scr[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(iq == nq - 1)
    def _done():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_bwd(q, k, v, o, lse, do, *, causal: bool = True,
              block_q: int = 128, block_k: int = 128,
              interpret: bool = False):
    b, h, sqf, d = q.shape
    sk = k.shape[2]
    sq = sk if causal else sqf
    nq = (sqf + block_q - 1) // block_q
    nk = (sk + block_k - 1) // block_k
    scale = 1.0 / math.sqrt(d)
    dl = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, sq=sq, sk=sk,
                          nk=nk),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, dl)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, sq=sq, sk=sk,
                          nq=nq),
        grid=(b, h, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, h, ik, iq: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, ik, iq: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, ik, iq: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, h, ik, iq: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, ik, iq: (b, h, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, ik, iq: (b, h, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, ik, iq: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, ik, iq: (b, h, ik, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_k, d), jnp.float32),
                        pltpu.VMEM((block_k, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, dl)
    return dq, dk, dv
