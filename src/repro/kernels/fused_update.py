"""Fused momentum-SGD update + SpecTrain weight prediction (Pallas).

The paper's prediction Ŵ = W − s·η·v (Eq. 4) naively costs one extra read
of W and v plus one write of Ŵ per pipeline tick — pure HBM traffic.  This
kernel fuses Eq. 1 (momentum), Eq. 2 (update) and Eq. 4 (prediction) into
a single pass: read (w, v, g) once, write (w', v', ŵ) once.  The
prediction rides on the optimizer update for free.

Oracle: repro.kernels.ref.fused_update_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 1024


def _upd_kernel(w_ref, v_ref, g_ref, w2_ref, v2_ref, wh_ref,
                *, lr, gamma, s):
    w = w_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    v2 = gamma * v + (1.0 - gamma) * g
    w2 = w - lr * v2
    wh = w2 - s * lr * v2
    w2_ref[...] = w2.astype(w2_ref.dtype)
    v2_ref[...] = v2.astype(v2_ref.dtype)
    wh_ref[...] = wh.astype(wh_ref.dtype)


def fused_update(w, v, g, *, lr: float, gamma: float = 0.9, s: float = 0.0,
                 block: int = BLOCK, interpret: bool = False):
    """Flat-array fused update.  w: any shape; v, g same shape.
    Returns (w', v' fp32, ŵ)."""
    shape, dtype = w.shape, w.dtype
    n = w.size
    nb = (n + block - 1) // block
    pad = nb * block - n

    def flat(x, dt):
        x = x.reshape(-1).astype(dt)
        return jnp.pad(x, (0, pad)) if pad else x

    wf = flat(w, dtype)
    vf = flat(v, jnp.float32)
    gf = flat(g, g.dtype)
    kernel = functools.partial(_upd_kernel, lr=lr, gamma=gamma, s=s)
    w2, v2, wh = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))] * 3,
        out_specs=[pl.BlockSpec((block,), lambda i: (i,))] * 3,
        out_shape=[
            jax.ShapeDtypeStruct(wf.shape, dtype),
            jax.ShapeDtypeStruct(wf.shape, jnp.float32),
            jax.ShapeDtypeStruct(wf.shape, dtype),
        ],
        interpret=interpret,
    )(wf, vf, gf)
    unflat = lambda x: (x[:n] if pad else x).reshape(shape)
    return unflat(w2), unflat(v2), unflat(wh)
