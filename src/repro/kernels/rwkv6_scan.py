"""RWKV-6 WKV recurrence as a chunked Pallas TPU kernel.

The per-channel data-dependent decay recurrence

    y_t = r_t · (S_{t−1} + diag(u)·k_t v_tᵀ)
    S_t = diag(w_t)·S_{t−1} + k_t v_tᵀ

is evaluated chunk-parallel: within a chunk of C steps the pairwise term
becomes a masked [C, C] matmul after rescaling r/k by the running decay
product (r' = r⊙cw, k' = k/cp), and the cross-chunk state is carried in
VMEM scratch across the sequential chunk grid dimension — the TPU analogue
of the CUDA kernels' per-SM running state, restructured for the MXU.

Numerics: the decay products are fp32 and clamped; valid for w ∈ [~0.5, 1)
over chunk lengths ≤ 64 (the regime RWKV-6 trains in; the trained w0/lora
parameterization keeps w ≈ exp(−exp(·)) ∈ (0.6, 0.999)).

Oracle: repro.kernels.ref.rwkv6_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams in newer jax
_CompilerParams = (getattr(pltpu, "CompilerParams", None)
                   or pltpu.TPUCompilerParams)

_EPS = 1e-24


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                y_ref, sT_ref, s_scr, *, nc, chunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)        # [C, hd]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)           # [hd]
    S = s_scr[...]                             # [hd_k, hd_v]

    cp = jnp.cumprod(w, axis=0)                # inclusive products
    cw = cp / w                                # exclusive (w>0 elementwise)

    r_s = r * cw                               # decay-weighted receptance
    k_s = k / jnp.maximum(cp, _EPS)
    score = jax.lax.dot_general(r_s, k_s, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    score = jnp.where(rows > cols, score, 0.0)
    diag = jnp.sum(r * u[None, :] * k, axis=1)           # bonus term
    score = score + jnp.where(rows == cols, diag[:, None], 0.0)

    y_intra = jax.lax.dot_general(score, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_state = jax.lax.dot_general(r_s, S, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_intra + y_state).astype(y_ref.dtype)

    cp_last = cp[-1]                                      # [hd]
    k_tail = k * (cp_last[None, :] / jnp.maximum(cp, _EPS))
    S_new = cp_last[:, None] * S + jax.lax.dot_general(
        k_tail, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_scr[...] = S_new

    @pl.when(ic == nc - 1)
    def _done():
        sT_ref[0, 0] = S_new


def rwkv6_scan(r, k, v, w, u, S0, *, chunk: int = 32,
               interpret: bool = False):
    """r,k,v,w: [b, h, s, hd]; u: [h, hd]; S0: [b, h, hd, hd] fp32.
    Returns (y [b,h,s,hd] fp32-accurate in r.dtype, S_T fp32)."""
    b, h, s, hd = r.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    kernel = functools.partial(_wkv_kernel, nc=nc, chunk=chunk)
    y, sT = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, hd), lambda b, h, ic: (h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, hd), r.dtype),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u, S0)
    return y, sT
