"""Mamba-2 SSD recurrence as a chunked Pallas TPU kernel.

Scalar-per-head decay makes the chunked form a pair of masked matmuls
(the SSD "chunked dual form"): within a chunk,

    y_t = cp_t·(C_t·S_0) + Σ_{j≤t} (cp_t/cp_j)·(C_t·B_j)·(dt_j x_j)

with cp the inclusive cumulative decay product; cross-chunk state S [p, n]
is carried in VMEM scratch across the sequential chunk grid dim.

Oracle: repro.kernels.ref.mamba2_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams in newer jax
_CompilerParams = (getattr(pltpu, "CompilerParams", None)
                   or pltpu.TPUCompilerParams)

_EPS = 1e-24


def _ssd_kernel(x_ref, dt_ref, de_ref, b_ref, c_ref, s0_ref,
                y_ref, sT_ref, s_scr, *, nc, chunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)        # [C, p]
    dt = dt_ref[0, 0].astype(jnp.float32)      # [C]
    de = de_ref[0, 0].astype(jnp.float32)      # [C] decay in (0,1]
    B = b_ref[0, 0].astype(jnp.float32)        # [C, n]
    C = c_ref[0, 0].astype(jnp.float32)        # [C, n]
    S = s_scr[...]                             # [p, n]

    cp = jnp.cumprod(de, axis=0)               # inclusive [C]
    dtx = dt[:, None] * x                      # [C, p]

    score = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    ratio = cp[:, None] / jnp.maximum(cp[None, :], _EPS)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    score = jnp.where(rows >= cols, score * ratio, 0.0)

    y_intra = jax.lax.dot_general(score, dtx, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_state = cp[:, None] * jax.lax.dot_general(
        C, S, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_intra + y_state).astype(y_ref.dtype)

    cp_last = cp[-1]
    tail = (cp_last / jnp.maximum(cp, _EPS))[:, None] * dtx   # [C, p]
    S_new = cp_last * S + jax.lax.dot_general(
        tail, B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_scr[...] = S_new

    @pl.when(ic == nc - 1)
    def _done():
        sT_ref[0, 0] = S_new


def mamba2_scan(x, dt, decay, B, C, S0, *, chunk: int = 32,
                interpret: bool = False):
    """x: [b,h,s,p]; dt,decay: [b,h,s]; B,C: [b,h,s,n]; S0: [b,h,p,n] fp32.
    Returns (y [b,h,s,p], S_T fp32)."""
    b, h, s, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    kernel = functools.partial(_ssd_kernel, nc=nc, chunk=chunk)
    y, sT = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, ic: (b, h, ic)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, ic: (b, h, ic)),
            pl.BlockSpec((1, 1, chunk, n), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, decay, B, C, S0)
    return y, sT
