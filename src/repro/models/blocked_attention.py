"""Memory-efficient blocked attention in pure XLA (flash algorithm).

Never materializes the [sq, sk] score matrix: forward is an online-softmax
scan over key blocks; backward is a custom VJP with doubly-blocked
recompute (dq: q-outer/k-inner, dkv: k-outer/q-inner).  This is the XLA
twin of ``repro.kernels.flash_attention`` (the Pallas TPU kernel) and the
path the dry-run/compile cells take on big sequences.

GQA layout:  q [b, sq, H, d];  k, v [b, sk, KV, d];  H % KV == 0.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _blocks(x, bs, axis):
    n = x.shape[axis]
    nb = (n + bs - 1) // bs
    x = _pad_to(x, nb * bs, axis)
    shape = x.shape[:axis] + (nb, bs) + x.shape[axis + 1:]
    return x.reshape(shape), nb


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def blocked_attention(q, k, v, causal: bool = True, block_q: int = 512,
                      block_k: int = 1024, pos_offset: int = 0):
    o, _ = _fwd_impl(q, k, v, causal, block_q, block_k, pos_offset)
    return o


def _fwd_impl(q, k, v, causal, block_q, block_k, pos_offset):
    b, sq, H, d = q.shape
    sk, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    scale = 1.0 / math.sqrt(d)
    f32 = jnp.float32

    qb = q.reshape(b, sq, KV, G, d)
    kb_all, nk = _blocks(k, block_k, 1)          # [b, nk, bk, KV, d]
    vb_all, _ = _blocks(v, block_k, 1)
    q_pos = (jnp.arange(sq) + pos_offset)

    def body(carry, ik):
        m, lsum, acc = carry
        kb = kb_all[:, ik]                        # [b, bk, KV, d]
        vb = vb_all[:, ik]
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb).astype(f32) * scale
        kpos = ik * block_k + jnp.arange(block_k)
        mask = kpos[None, :] < sk
        if causal:
            mask = mask & (q_pos[:, None] >= kpos[None, :])
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m2 = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m2[..., None])
        corr = jnp.exp(m - m2)
        lsum = corr * lsum + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q.dtype), vb)
        acc = corr[..., None] * acc + pv.astype(f32)
        return (m2, lsum, acc), None

    m0 = jnp.full((b, KV, G, sq), NEG_INF, f32)
    l0 = jnp.zeros((b, KV, G, sq), f32)
    a0 = jnp.zeros((b, KV, G, sq, dv), f32)
    (m, lsum, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0), jnp.arange(nk))
    o = (acc / jnp.maximum(lsum, 1e-30)[..., None])
    o = jnp.moveaxis(o, -2, 1).reshape(b, sq, H, dv).astype(q.dtype)
    lse = (m + jnp.log(jnp.maximum(lsum, 1e-30)))  # [b, KV, G, sq]
    return o, lse


def _fwd_rule(q, k, v, causal, block_q, block_k, pos_offset):
    o, lse = _fwd_impl(q, k, v, causal, block_q, block_k, pos_offset)
    return o, (q, k, v, o, lse)


def _bwd_rule(causal, block_q, block_k, pos_offset, res, do):
    q, k, v, o, lse = res
    b, sq, H, d = q.shape
    sk, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    scale = 1.0 / math.sqrt(d)
    f32 = jnp.float32

    qg = q.reshape(b, sq, KV, G, d)
    dog = do.reshape(b, sq, KV, G, dv)
    og = o.reshape(b, sq, KV, G, dv)
    delta = jnp.sum(og.astype(f32) * dog.astype(f32), axis=-1)  # [b,sq,KV,G]
    delta = jnp.moveaxis(delta, 1, -1)                          # [b,KV,G,sq]

    qb_all, nq = _blocks(qg, block_q, 1)       # [b, nq, bq, KV, G, d]
    dob_all, _ = _blocks(dog, block_q, 1)
    kb_all, nk = _blocks(k, block_k, 1)
    vb_all, _ = _blocks(v, block_k, 1)
    lse_b, _ = _blocks(lse, block_q, 3)        # [b, KV, G, nq, bq]
    del_b, _ = _blocks(delta, block_q, 3)
    q_pos_all = _pad_to(jnp.arange(sq) + pos_offset, nq * block_q, 0
                        ).reshape(nq, block_q)

    def s_block(qb, kb, iq, ik):
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb).astype(f32) * scale
        kpos = ik * block_k + jnp.arange(block_k)
        mask = kpos[None, :] < sk
        if causal:
            mask = mask & (q_pos_all[iq][:, None] >= kpos[None, :])
        return jnp.where(mask[None, None, None], s, NEG_INF)

    # ---- dq: outer over q blocks, inner over k blocks ---------------------
    def dq_outer(_, iq):
        qb = qb_all[:, iq]
        dob = dob_all[:, iq]
        lse_i = lse_b[:, :, :, iq]
        del_i = del_b[:, :, :, iq]

        def inner(dqa, ik):
            kb = kb_all[:, ik]
            vb = vb_all[:, ik]
            s = s_block(qb, kb, iq, ik)
            p = jnp.exp(s - lse_i[..., None])
            dp = jnp.einsum("bqkgd,bskd->bkgqs", dob, vb).astype(f32)
            ds = p * (dp - del_i[..., None]) * scale
            dqa = dqa + jnp.einsum("bkgqs,bskd->bqkgd",
                                   ds.astype(q.dtype), kb).astype(f32)
            return dqa, None

        dq0 = jnp.zeros((b, block_q, KV, G, d), f32)
        dqb, _ = jax.lax.scan(jax.checkpoint(inner), dq0, jnp.arange(nk))
        return None, dqb

    _, dq_blocks = jax.lax.scan(dq_outer, None, jnp.arange(nq))
    dq = jnp.moveaxis(dq_blocks, 0, 1).reshape(b, nq * block_q, KV, G, d)
    dq = dq[:, :sq].reshape(b, sq, H, d).astype(q.dtype)

    # ---- dk/dv: outer over k blocks, inner over q blocks --------------------
    def dkv_outer(_, ik):
        kb = kb_all[:, ik]
        vb = vb_all[:, ik]

        def inner(carry, iq):
            dka, dva = carry
            qb = qb_all[:, iq]
            dob = dob_all[:, iq]
            lse_i = lse_b[:, :, :, iq]
            del_i = del_b[:, :, :, iq]
            s = s_block(qb, kb, iq, ik)
            p = jnp.exp(s - lse_i[..., None])
            dva = dva + jnp.einsum("bkgqs,bqkgd->bskd", p.astype(q.dtype),
                                   dob).astype(f32)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", dob, vb).astype(f32)
            ds = p * (dp - del_i[..., None]) * scale
            dka = dka + jnp.einsum("bkgqs,bqkgd->bskd", ds.astype(q.dtype),
                                   qb).astype(f32)
            return (dka, dva), None

        zk = jnp.zeros((b, block_k, KV, d), f32)
        zv = jnp.zeros((b, block_k, KV, dv), f32)
        (dkb, dvb), _ = jax.lax.scan(jax.checkpoint(inner), (zk, zv),
                                     jnp.arange(nq))
        return None, (dkb, dvb)

    _, (dk_blocks, dv_blocks) = jax.lax.scan(dkv_outer, None, jnp.arange(nk))
    dk = jnp.moveaxis(dk_blocks, 0, 1).reshape(b, nk * block_k, KV, d)
    dv = jnp.moveaxis(dv_blocks, 0, 1).reshape(b, nk * block_k, KV, dv)
    dk = dk[:, :sk].astype(k.dtype)
    dv = dv[:, :sk].astype(v.dtype)
    return dq, dk, dv


blocked_attention.defvjp(_fwd_rule, _bwd_rule)
