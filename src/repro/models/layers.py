"""Layer primitives + ParamSpec machinery.

Params are plain pytrees (nested dicts of jnp arrays).  Every module
declares its parameters as ``ParamSpec``s so that:
  * ``init_params``     materializes them with a PRNG key,
  * ``specs_to_sds``    gives ShapeDtypeStructs for allocation-free dry-runs,
  * ``specs_to_axes``   gives the logical-axis pytree driving GSPMD sharding.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# ParamSpec


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"            # normal | zeros | ones | uniform
    scale: float = 1.0              # stddev multiplier (normal) / bound
    dtype: Optional[str] = None     # None -> cfg.param_dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _fan_in(shape: Tuple[int, ...]) -> int:
    return shape[-2] if len(shape) >= 2 else max(1, shape[-1])


def init_one(spec: ParamSpec, key, default_dtype: str):
    dtype = jnp.dtype(spec.dtype or default_dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "uniform":
        return jax.random.uniform(key, spec.shape, dtype,
                                  minval=-spec.scale, maxval=spec.scale)
    std = spec.scale / math.sqrt(_fan_in(spec.shape))
    return (jax.random.normal(key, spec.shape) * std).astype(dtype)


def init_params(specs, key, default_dtype: str = "float32"):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [init_one(s, k, default_dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def specs_to_sds(specs, default_dtype: str = "float32"):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.dtype(s.dtype or default_dtype)),
        specs, is_leaf=is_spec)


def specs_to_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def stack_spec(spec: ParamSpec, n: int, axis_name: Optional[str]) -> ParamSpec:
    return ParamSpec((n,) + spec.shape, (axis_name,) + spec.axes,
                     spec.init, spec.scale, spec.dtype)


def stack_specs(specs, n: int, axis_name: Optional[str]):
    return jax.tree.map(lambda s: stack_spec(s, n, axis_name), specs,
                        is_leaf=is_spec)


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# activation sharding hooks (set by the runtime; no-op on bare CPU tests)

_ACTIVE: Dict[str, Any] = {}


class use_rules:
    """Activate logical->mesh activation-sharding rules.

    rules: {logical_axis: mesh axis | tuple | None}
    sizes: {mesh_axis: size} for divisibility checks.
    """

    def __init__(self, rules: Dict[str, Any], sizes: Dict[str, int]):
        self.ctx = {"rules": rules or {}, "sizes": sizes or {}}

    def __enter__(self):
        global _ACTIVE
        self._old = _ACTIVE
        _ACTIVE = self.ctx
        return self

    def __exit__(self, *a):
        global _ACTIVE
        _ACTIVE = self._old


def shard_act(x, *logical_axes):
    """with_sharding_constraint by logical axis names, if rules are active.

    Drops any assignment that does not divide the dim or reuses a mesh axis.
    """
    if not _ACTIVE:
        return x
    from jax.sharding import PartitionSpec as P
    rules, sizes = _ACTIVE["rules"], _ACTIVE["sizes"]
    used: set = set()
    spec = []
    for ax, dim in zip(logical_axes, x.shape):
        val = rules.get(ax) if ax else None
        if val is None:
            spec.append(None)
            continue
        names = (val,) if isinstance(val, str) else tuple(val)
        names = tuple(n for n in names if n in sizes and n not in used)
        prod = 1
        for n in names:
            prod *= sizes[n]
        if not names or prod == 1 or dim % prod != 0:
            spec.append(None)
            continue
        used.update(names)
        spec.append(names[0] if len(names) == 1 else names)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# norms


def norm_specs(cfg, kind: Optional[str] = None, dim: Optional[int] = None):
    kind = kind or cfg.norm
    d = dim or cfg.d_model
    specs = {"scale": ParamSpec((d,), ("embed",), "ones")}
    if kind == "layernorm":
        specs["bias"] = ParamSpec((d,), ("embed",), "zeros")
    return specs


def norm_apply(cfg, p, x, kind: Optional[str] = None, eps: float = 1e-5):
    kind = kind or cfg.norm
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def groupnorm_heads(x, scale, bias, n_heads: int, eps: float = 1e-5):
    """GroupNorm over head_dim groups (RWKV output norm). x: [..., d]."""
    orig = x.shape
    xf = x.astype(jnp.float32).reshape(
        orig[:-1] + (n_heads, orig[-1] // n_heads))
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(orig)
    y = y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP


def mlp_specs(cfg):
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.mlp_gated:
        return {
            "wg": ParamSpec((d, ff), ("embed", "mlp")),
            "w1": ParamSpec((d, ff), ("embed", "mlp")),
            "w2": ParamSpec((ff, d), ("mlp", "embed")),
        }
    return {
        "w1": ParamSpec((d, ff), ("embed", "mlp")),
        "w2": ParamSpec((ff, d), ("mlp", "embed")),
    }


def mlp_apply(cfg, p, x):
    dt = x.dtype
    if cfg.mlp_gated:
        h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["w1"].astype(dt))
    else:
        h = jax.nn.gelu(x @ p["w1"].astype(dt))
    h = shard_act(h, "act_batch", None, "mlp")
    return h @ p["w2"].astype(dt)


# ---------------------------------------------------------------------------
# embeddings / unembedding


def embed_specs(cfg):
    V, d = cfg.vocab_padded, cfg.d_model
    specs = {"tok": ParamSpec((V, d), ("vocab", "embed"), "normal", 1.0)}
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((d, V), ("embed", "vocab"))
    if cfg.pos_embed == "sinusoidal":
        pass  # computed, not learned
    return specs


def embed_apply(cfg, p, tokens):
    emb = jnp.take(p["tok"], tokens,
                   axis=0).astype(jnp.dtype(cfg.compute_dtype))
    emb = emb * math.sqrt(cfg.d_model)
    return shard_act(emb, "act_batch", "act_seq", None)


def unembed_apply(cfg, p, x):
    w = (p["tok"].T if cfg.tie_embeddings else p["unembed"]).astype(x.dtype)
    logits = x @ w
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return shard_act(logits, "act_batch", "act_seq", "vocab")


def sinusoidal_pos(seq: int, d: int, offset: int = 0, dtype=jnp.float32):
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE


def rope_freqs(cfg, hd: Optional[int] = None):
    hd = hd or cfg.hd
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, jnp.float32) / hd))


def apply_rope(x, positions, inv_freq):
    """x: [..., seq, heads, hd]; positions: [..., seq] (int)."""
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # [..., s, hd/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]  # broadcast over heads
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses


def softmax_xent(logits, targets, vocab_size: int, z_loss: float = 0.0):
    """Mean token cross-entropy; ignores padded vocab tail via valid mask on
    targets (targets assumed < vocab_size)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    loss = lse - gold
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)
