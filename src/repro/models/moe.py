"""Mixture-of-Experts layer: top-k routing, capacity-bounded scatter
dispatch (no O(T·E·C) dispatch einsum), shared experts (DeepSeekMoE),
load-balance aux loss.

Dispatch is GROUPED (GShard-style): tokens are split into ``num_groups``
groups (aligned with the data-parallel shards), each group routes locally
with its own capacity — no cross-shard cumsum, no gathering the global
token stream.  Experts shard over the `tensor` mesh axis (EP); the group
dim shards over `data`; GSPMD inserts the expert all-to-alls.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, shard_act

# groups used for local dispatch; aligned with the data axis of the
# production mesh.  Overridden to 1 automatically when T % groups != 0.
DISPATCH_GROUPS = 16


def moe_specs(cfg):
    mo, d, ff = cfg.moe, cfg.d_model, cfg.d_ff
    E = mo.num_experts
    mats = (("wg", "w1", "w2") if cfg.mlp_gated else ("w1", "w2"))
    specs: Dict = {
        "router": ParamSpec((d, E), ("embed", "expert"), "normal", 0.1),
    }
    for m in mats:
        shp = (E, ff, d) if m == "w2" else (E, d, ff)
        axes = ("expert", "mlp", "embed") if m == "w2" \
            else ("expert", "embed", "mlp")
        specs[m] = ParamSpec(shp, axes)
    if mo.num_shared:
        for m in mats:
            shp = (mo.num_shared, ff, d) if m == "w2" \
                else (mo.num_shared, d, ff)
            axes = (None, "mlp", "embed") if m == "w2" \
                else (None, "embed", "mlp")
            specs["shared_" + m] = ParamSpec(shp, axes)
    return specs


def _expert_ffn(cfg, w, h):
    """h: [..., E, C, d] -> same through per-expert FFN."""
    dt = h.dtype
    if cfg.mlp_gated:
        a = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", h,
                                   w["wg"].astype(dt)))
        z = a * jnp.einsum("...ecd,edf->...ecf", h, w["w1"].astype(dt))
    else:
        z = jax.nn.gelu(jnp.einsum("...ecd,edf->...ecf", h,
                                   w["w1"].astype(dt)))
    z = shard_act(z, "act_batch", "expert", None, None) if z.ndim == 4 \
        else shard_act(z, "expert", None, None)
    return jnp.einsum("...ecf,efd->...ecd", z, w["w2"].astype(dt))


def moe_apply(cfg, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [b,s,d] -> (out [b,s,d], aux_loss scalar)."""
    mo = cfg.moe
    E, k = mo.num_experts, mo.top_k
    b, s, d = x.shape
    T = b * s
    G = DISPATCH_GROUPS if T % DISPATCH_GROUPS == 0 and \
        T // DISPATCH_GROUPS >= E else 1
    Tg = T // G
    xg = x.reshape(G, Tg, d)
    xg = shard_act(xg, "act_batch", None, None)
    dt = x.dtype

    logits = (xg @ p["router"].astype(dt)).astype(jnp.float32)  # [G,Tg,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                         # [G,Tg,k]
    if mo.num_shared:  # deepseek: renormalize among selected
        gate = gate / (jnp.sum(gate, -1, keepdims=True) + 1e-9)

    # load-balance aux loss (Switch-style), computed globally
    me = jnp.mean(probs, axis=(0, 1))                           # [E]
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32),
                          axis=2), axis=(0, 1))
    aux = mo.aux_loss_coef * E * jnp.sum(me * ce)

    # ---- grouped capacity-bounded scatter dispatch -----------------------
    cap = min(int(mo.capacity_factor * Tg * k / E) + 1, Tg)
    e_flat = idx.reshape(G, Tg * k)                             # [G, Tgk]
    w_flat = gate.reshape(G, Tg * k).astype(dt)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)         # [G,Tgk,E]
    pos_in_e = jnp.sum(onehot * (jnp.cumsum(onehot, axis=1) - 1), axis=-1)
    keep = pos_in_e < cap
    dest_c = jnp.where(keep, pos_in_e, cap)                     # overflow

    tok_ids = jnp.repeat(jnp.arange(Tg), k)                     # [Tgk]

    def scatter_group(xg_g, e_g, c_g, keep_g):
        src = jnp.where(keep_g[:, None], xg_g[tok_ids], 0)
        return jnp.zeros((E, cap + 1, d), dt).at[e_g, c_g].add(
            src, mode="drop")

    buf = jax.vmap(scatter_group)(xg, e_flat, dest_c, keep)     # [G,E,C+1,d]
    buf = shard_act(buf, "act_batch", "expert", None, None)

    out_buf = _expert_ffn(cfg, p, buf[:, :, :cap])
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((G, E, 1, d), dt)], axis=2)

    def gather_group(ob_g, e_g, c_g, w_g, keep_g):
        got = ob_g[e_g, c_g] * (w_g * keep_g.astype(dt))[:, None]
        return jnp.zeros((Tg, d), dt).at[tok_ids].add(got)

    out = jax.vmap(gather_group)(out_buf, e_flat, dest_c, w_flat, keep)
    out = shard_act(out, "act_batch", None, None)

    if mo.num_shared:
        sh = {m[len("shared_"):]: p[m] for m in p if m.startswith("shared_")}
        hs = jnp.broadcast_to(xg.reshape(G * Tg, d),
                              (mo.num_shared, G * Tg, d))
        shared_out = jnp.sum(_expert_ffn(cfg, sh, hs), axis=0)  # [T, d]
        out = out.reshape(G * Tg, d) + shared_out

    return out.reshape(b, s, d), aux
