"""Transformer block assembly for every family.

A *block* = one layer (attention/SSM mixer + MLP/MoE + norms, pre-norm
residual).  Blocks expose cache/state hooks for decode.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (mlp_apply, mlp_specs, norm_apply,
                                 norm_specs, shard_act)


def block_specs(cfg, cross: bool = False) -> Dict[str, Any]:
    fam_ssm = cfg.ssm is not None
    if fam_ssm and cfg.ssm.kind == "rwkv6":
        return {
            "ln1": norm_specs(cfg),
            "tm": ssm_mod.rwkv6_tm_specs(cfg),
            "ln2": norm_specs(cfg),
            "cm": ssm_mod.rwkv6_cm_specs(cfg),
        }
    if fam_ssm and cfg.ssm.kind == "mamba2":
        # zamba2-style mamba block: norm + mamba mixer + residual (no MLP)
        return {"ln1": norm_specs(cfg), "mamba": ssm_mod.mamba2_specs(cfg)}
    specs: Dict[str, Any] = {
        "ln1": norm_specs(cfg),
        "attn": attn.attn_specs(cfg),
        "ln2": norm_specs(cfg),
    }
    if cross:
        specs["lnx"] = norm_specs(cfg)
        specs["xattn"] = attn.gqa_specs(cfg, cross=True)
    if cfg.moe is not None:
        specs["moe"] = moe_mod.moe_specs(cfg)
    else:
        specs["mlp"] = mlp_specs(cfg)
    return specs


def shared_block_specs(cfg) -> Dict[str, Any]:
    """zamba2 shared attention block: full attn + MLP."""
    return {
        "ln1": norm_specs(cfg),
        "attn": attn.gqa_specs(cfg),
        "ln2": norm_specs(cfg),
        "mlp": mlp_specs(cfg),
    }


def block_apply(cfg, p, x, *, pos_offset: int = 0, causal: bool = True,
                cache: Optional[Dict] = None, pos=None, enc_out=None,
                state: Optional[Dict] = None):
    """Returns (x, aux, new_cache, new_state)."""
    aux = jnp.zeros((), jnp.float32)
    fam_ssm = cfg.ssm is not None

    if fam_ssm and cfg.ssm.kind == "rwkv6":
        h, st_tm = ssm_mod.rwkv6_tm_apply(
            cfg, p["tm"], norm_apply(cfg, p["ln1"], x), state)
        x = x + h
        h, st_cm = ssm_mod.rwkv6_cm_apply(
            cfg, p["cm"], norm_apply(cfg, p["ln2"], x), state)
        x = x + h
        new_state = None
        if state is not None:
            new_state = {**st_tm, **st_cm}
        return x, aux, None, new_state

    if fam_ssm and cfg.ssm.kind == "mamba2":
        h, new_state = ssm_mod.mamba2_apply(
            cfg, p["mamba"], norm_apply(cfg, p["ln1"], x), state)
        return x + h, aux, None, new_state

    h, new_cache = attn.attn_apply(
        cfg, p["attn"], norm_apply(cfg, p["ln1"], x),
        pos_offset=pos_offset, causal=causal, cache=cache, pos=pos)
    x = x + h
    # sequence parallelism hook: when act_seq -> tensor, the residual
    # stream is seq-sharded between blocks and GSPMD replaces the TP
    # all-reduces with reduce-scatter + all-gather (half the wire bytes)
    x = shard_act(x, "act_batch", "act_seq", None)
    if "xattn" in p:
        assert enc_out is not None
        h, _ = attn.gqa_apply(cfg, p["xattn"],
                              norm_apply(cfg, p["lnx"], x),
                              causal=False, kv_input=enc_out)
        x = x + h
    xn = norm_apply(cfg, p["ln2"], x)
    if "moe" in p:
        h, aux = moe_mod.moe_apply(cfg, p["moe"], xn)
    else:
        h = mlp_apply(cfg, p["mlp"], xn)
    x = shard_act(x + h, "act_batch", "act_seq", None)
    return x, aux, new_cache, None


def shared_block_apply(cfg, p, x, *, pos_offset: int = 0, cache=None,
                       pos=None):
    h, new_cache = attn.gqa_apply(cfg, p["attn"],
                                  norm_apply(cfg, p["ln1"], x),
                                  pos_offset=pos_offset, causal=True,
                                  cache=cache, pos=pos)
    x = x + h
    x = x + mlp_apply(cfg, p["mlp"], norm_apply(cfg, p["ln2"], x))
    return x, new_cache
