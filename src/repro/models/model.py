"""Model assembly: param specs, reference forward, stage functions for the
pipeline runtimes, KV-cache/state decode, and dry-run input specs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (embed_apply, embed_specs, norm_apply,
                                 norm_specs, sinusoidal_pos,
                                 softmax_xent, specs_to_axes, specs_to_sds,
                                 init_params, stack_specs, unembed_apply)
from repro.models.transformer import (block_apply, block_specs,
                                      shared_block_apply, shared_block_specs)

WHISPER_ENC_FRAMES = 1500  # fixed encoder context for decode shapes


def tree_slice(tree, idx):
    return jax.tree.map(lambda a: a[idx], tree)


def tree_slice_range(tree, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def uniform_stage_sizes(n_layers: int, n_stages: int) -> Tuple[int, ...]:
    """Equal-count contiguous split, remainder spread over early stages
    (the same split :func:`repro.planner.partition.uniform` produces)."""
    if n_stages < 1 or n_layers < n_stages:
        raise ValueError(f"cannot split {n_layers} layers into "
                         f"{n_stages} stages (a stage would be empty)")
    base, rem = divmod(n_layers, n_stages)
    return tuple(base + (1 if s < rem else 0) for s in range(n_stages))


def flat_stage_layers(stages):
    """Merge stage layer params to a flat [L, ...] tree.

    Accepts the ragged canonical layout (tuple of per-stage trees,
    concatenated in stage order) and the legacy stacked
    ``[S, Lps, ...]`` dict layout (reshaped).  The single
    flat-layer-order routine — `Model.flat_layers` and
    `runtime/elastic` both delegate here."""
    if isinstance(stages, (tuple, list)):
        if len(stages) == 1:
            return stages[0]["layers"]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                            *[t["layers"] for t in stages])
    return jax.tree.map(
        lambda a: a.reshape((-1,) + a.shape[2:]), stages["layers"])


def split_flat_stages(flat_stages, sizes) -> Tuple[Any, ...]:
    """Flat ``{"layers": [L, ...](, "shared": [S, ...])}`` -> ragged
    per-stage trees for ``sizes`` (the one slicing-by-sizes routine —
    `Model.init`, `partition_stage_params` and `runtime/elastic` all
    route through it)."""
    out, lo = [], 0
    for k, n in enumerate(sizes):
        tree: Dict[str, Any] = {
            "layers": tree_slice_range(flat_stages["layers"], lo, lo + n)}
        if "shared" in flat_stages:
            tree["shared"] = tree_slice(flat_stages["shared"], k)
        out.append(tree)
        lo += n
    return tuple(out)


def pack_chunk_params(chunks, n_devices: int):
    """Ragged chunk trees -> the dense MPMD layout: every ``layers``
    leaf becomes ``[v, S, Lmax, ...]`` with chunk ``q`` at index
    ``[q // S, q % S]`` zero-padded to ``Lmax = max(sizes)`` rows.

    Sharding dim 1 with ``PartitionSpec(None, 'pipe')`` therefore pins
    chunk ``q`` wholly to pipe device ``q % S`` (Megatron round-robin
    folding) — the layout that lets one jitted program hold
    differently-sized stage trees stage-locally.  Reshaping dims 0–1 to
    ``[C, Lmax, ...]`` row-major recovers chunk order, which is flat
    layer order.  Returns ``(packed_tree, sizes)``; hybrid per-stage
    ``shared`` blocks have no layer stack to pad and are refused.
    """
    C = len(chunks)
    S = int(n_devices)
    if S < 1 or C % S:
        raise ValueError(f"{C} chunk trees do not fold onto {S} devices")
    if any("shared" in t for t in chunks):
        raise ValueError(
            "hybrid stage trees carry per-stage 'shared' blocks with no "
            "flat layer order; the packed MPMD layout does not cover them")
    sizes = tuple(int(jax.tree.leaves(t["layers"])[0].shape[0])
                  for t in chunks)
    Lmax = max(sizes)
    v = C // S

    def leaf(*xs):
        padded = [
            jnp.concatenate(
                [x, jnp.zeros((Lmax - x.shape[0],) + x.shape[1:], x.dtype)],
                0) if x.shape[0] < Lmax else x
            for x in xs]
        return jnp.stack(padded, 0).reshape((v, S, Lmax) + xs[0].shape[1:])

    packed = {"layers": jax.tree.map(leaf, *[t["layers"] for t in chunks])}
    return packed, sizes


def unpack_chunk_params(packed, sizes) -> Tuple[Any, ...]:
    """Inverse of :func:`pack_chunk_params`: dense ``[v, S, Lmax, ...]``
    leaves back to the ragged chunk trees (padding rows dropped)."""
    sizes = tuple(int(n) for n in sizes)
    C = len(sizes)

    def flat(a):
        if a.shape[0] * a.shape[1] != C:
            raise ValueError(
                f"packed leaf folds {a.shape[0] * a.shape[1]} chunks, "
                f"sizes cover {C}")
        return a.reshape((C,) + a.shape[2:])

    rows = jax.tree.map(flat, packed["layers"])
    return tuple({"layers": jax.tree.map(lambda a: a[q, :sizes[q]], rows)}
                 for q in range(C))


class Model:
    """Functional model wrapper for one ArchConfig.

    Stage parameters use the **ragged per-stage canonical layout**:
    ``params["stages"]`` is a tuple of ``n_stages`` pytrees whose
    ``layers`` leaves are ``[L_k, ...]`` with ``L_k`` from
    ``stage_sizes`` (the uniform split, remainder on early stages) —
    any ``(n_layers, n_stages)`` initializes, no divisibility required.
    The runtimes repartition these trees to a plan's sizes via
    :meth:`partition_stage_params`, which also still accepts the legacy
    stacked ``[S, Lps, ...]`` dict layout old checkpoints carry.
    """

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        plan = cfg.mesh_plan
        pipelineable = (plan.pipe_role == "stage" and plan.pipe > 1
                        and not cfg.is_encdec)
        self.n_stages = plan.pipe if pipelineable else 1
        self.stage_sizes = uniform_stage_sizes(cfg.n_layers, self.n_stages)
        self.hybrid = (cfg.ssm is not None and cfg.ssm.shared_attn_every > 0)

    @property
    def layers_per_stage(self) -> int:
        """Uniform per-stage layer count; only defined when the default
        split is uniform (legacy accessor — prefer ``stage_sizes``)."""
        if self.cfg.n_layers % self.n_stages:
            raise ValueError(
                f"{self.cfg.name}: {self.cfg.n_layers} layers over "
                f"{self.n_stages} stages is ragged "
                f"(sizes {self.stage_sizes}); use stage_sizes")
        return self.cfg.n_layers // self.n_stages

    # ------------------------------------------------------------------ specs
    def _outer_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        outer: Dict[str, Any] = {
            "embed": embed_specs(cfg),
            "ln_f": norm_specs(cfg),
        }
        if cfg.is_encdec:
            outer["ln_f_enc"] = norm_specs(cfg)
        return outer

    def param_specs(self) -> Dict[str, Any]:
        """Specs in the canonical layout: ragged per-stage tuple for
        pipelined stacks (``layers`` leaves ``[L_k, ...]``, one
        ``shared`` block per stage for hybrid models)."""
        cfg = self.cfg
        outer = self._outer_specs()
        if cfg.is_encdec:
            stages = {
                "enc": stack_specs(block_specs(cfg), cfg.n_enc_layers,
                                   "layer"),
                "dec": stack_specs(block_specs(cfg, cross=True),
                                   cfg.n_layers, "layer"),
            }
            return {"outer": outer, "stages": stages}
        layer = block_specs(cfg)
        stages = []
        for n in self.stage_sizes:
            tree: Dict[str, Any] = {"layers": stack_specs(layer, n, "layer")}
            if self.hybrid:
                tree["shared"] = shared_block_specs(cfg)
            stages.append(tree)
        return {"outer": outer, "stages": tuple(stages)}

    def _flat_param_specs(self) -> Dict[str, Any]:
        """Spec tree used for initialization: all layers in one
        ``[n_layers, ...]`` stack (hybrid shared blocks ``[S, ...]``).

        This is RNG-compatible with the pre-ragged stacked layout — a
        ``[S, Lps, ...]`` and an ``[L, ...]`` draw of the same spec leaf
        consume the same key and produce the same bits in layer order —
        so ragged canonical init stays bit-identical to historical
        (golden-pinned) initializations wherever the split is uniform.
        """
        cfg = self.cfg
        if cfg.is_encdec:
            return self.param_specs()
        stages: Dict[str, Any] = {
            "layers": stack_specs(block_specs(cfg), cfg.n_layers, "layer")}
        if self.hybrid:
            stages["shared"] = stack_specs(shared_block_specs(cfg),
                                           self.n_stages, "stage")
        return {"outer": self._outer_specs(), "stages": stages}

    def init(self, key):
        params = init_params(self._flat_param_specs(), key,
                             self.cfg.param_dtype)
        if self.cfg.is_encdec:
            return params
        return {"outer": params["outer"],
                "stages": split_flat_stages(params["stages"],
                                            self.stage_sizes)}

    def param_sds(self):
        return specs_to_sds(self.param_specs(), self.cfg.param_dtype)

    def param_axes(self):
        return specs_to_axes(self.param_specs())

    # ------------------------------------------------------------ stage apply
    def _layer_body(self, *, pos_offset: int = 0):
        cfg = self.cfg

        def body(carry, layer_p):
            x, aux = carry
            x, a, _, _ = block_apply(cfg, layer_p, x, pos_offset=pos_offset)
            return (x, aux + a), None

        if cfg.remat == "full":
            body = jax.checkpoint(body)
        elif cfg.remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots)
        return body

    def stage_apply(self, stage_params, carry, *, pos_offset: int = 0):
        """One pipeline stage: its blocks (+ hybrid shared block).

        The layer count is read off the param tree's leading axis, so the
        same code executes uniform stages and ragged (plan-partitioned)
        stages.  carry = (x [b,s,d], aux scalar)."""
        cfg = self.cfg
        body = self._layer_body(pos_offset=pos_offset)
        layers = stage_params["layers"]
        if not self.hybrid:
            carry, _ = jax.lax.scan(body, carry, layers)
            return carry
        k = cfg.ssm.shared_attn_every
        n = jax.tree.leaves(layers)[0].shape[0]
        lo = 0
        while lo < n:
            hi = min(lo + k, n)
            carry, _ = jax.lax.scan(body, carry,
                                    tree_slice_range(layers, lo, hi))
            if hi < n or hi == n and lo + k == n:
                x, aux = carry
                x, _ = shared_block_apply(cfg, stage_params["shared"], x,
                                          pos_offset=pos_offset)
                carry = (x, aux)
            lo = hi
        return carry

    # ------------------------------------------------------- embed/head
    def embed(self, outer, batch):
        cfg = self.cfg
        if cfg.is_encdec:
            raise RuntimeError("use forward() for enc-dec")
        x = embed_apply(cfg, outer["embed"], batch["tokens"])
        if cfg.frontend == "vision" and "patches" in batch:
            patches = batch["patches"].astype(x.dtype)
            x = jax.lax.dynamic_update_slice(x, patches, (0, 0, 0))
        if cfg.pos_embed == "sinusoidal":
            x = x + sinusoidal_pos(x.shape[1], cfg.d_model, dtype=x.dtype)
        return x

    def head_loss(self, outer, x, targets):
        cfg = self.cfg
        x = norm_apply(cfg, outer["ln_f"], x)
        logits = unembed_apply(cfg, outer["embed"], x)
        return softmax_xent(logits, targets, cfg.vocab_size)

    def logits(self, outer, x):
        cfg = self.cfg
        x = norm_apply(cfg, outer["ln_f"], x)
        return unembed_apply(cfg, outer["embed"], x)

    # -------------------------------------------------- reference fwd
    def hidden(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Final hidden states (pre-head).  Returns (x, aux_loss)."""
        cfg = self.cfg
        outer, stages = params["outer"], params["stages"]
        if cfg.is_encdec:
            return self._hidden_encdec(params, batch)
        x = self.embed(outer, batch)
        carry = (x, jnp.zeros((), jnp.float32))
        for s in range(self.n_stages):
            sp = (stages[s] if isinstance(stages, (tuple, list))
                  else tree_slice(stages, s))
            carry = self.stage_apply(sp, carry)
        return carry

    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full (non-pipelined) forward.  Returns (logits, aux_loss)."""
        x, aux = self.hidden(params, batch)
        return self.logits(params["outer"], x), aux

    def prefill_logits(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Serving prefill: last-position logits only."""
        x, aux = self.hidden(params, batch)
        return self.logits(params["outer"], x[:, -1:]), aux

    def encode(self, params, batch):
        """Encoder stack -> enc_out (enc-dec archs)."""
        cfg = self.cfg
        outer, stages = params["outer"], params["stages"]
        dt = jnp.dtype(cfg.compute_dtype)
        if cfg.frontend == "audio":
            enc_x = batch["frames"].astype(dt)
        else:
            enc_x = embed_apply(cfg, outer["embed"], batch["src_tokens"])
        enc_x = enc_x + sinusoidal_pos(enc_x.shape[1], cfg.d_model, dtype=dt)
        body = self._layer_body()

        def enc_body(carry, lp):
            (x, aux), _ = body(carry, lp)
            return (x, aux), None
        (enc_x, _), _ = jax.lax.scan(
            enc_body, (enc_x, jnp.zeros((), jnp.float32)), stages["enc"])
        return norm_apply(cfg, outer["ln_f_enc"], enc_x)

    def encdec_prefill_cache(self, params, batch, max_seq: int):
        """Run the encoder and precompute per-decoder-layer cross K/V."""
        cfg = self.cfg
        stages = params["stages"]
        enc_out = self.encode(params, batch)
        b, e_len = enc_out.shape[0], enc_out.shape[1]
        KV, hd = cfg.n_kv_heads, cfg.hd
        dt = enc_out.dtype

        def body(_, lp):
            ck = (enc_out @ lp["xattn"]["wk"].astype(dt)
                  ).reshape(b, e_len, KV, hd)
            cv = (enc_out @ lp["xattn"]["wv"].astype(dt)
                  ).reshape(b, e_len, KV, hd)
            return None, (ck, cv)
        _, (cks, cvs) = jax.lax.scan(body, None, stages["dec"])
        L = cfg.n_layers
        z = lambda *s: jnp.zeros(s, dt)
        return {
            "self": {"k": z(L, b, max_seq, KV, hd),
                     "v": z(L, b, max_seq, KV, hd)},
            "cross": {"k": cks, "v": cvs},
        }

    def _hidden_encdec(self, params, batch):
        cfg = self.cfg
        outer, stages = params["outer"], params["stages"]
        dt = jnp.dtype(cfg.compute_dtype)
        enc_out = self.encode(params, batch)
        aux = jnp.zeros((), jnp.float32)

        x = embed_apply(cfg, outer["embed"], batch["tokens"])
        x = x + sinusoidal_pos(x.shape[1], cfg.d_model, dtype=dt)

        def dec_body(carry, lp):
            x, aux = carry
            x, a, _, _ = block_apply(cfg, lp, x, enc_out=enc_out)
            return (x, aux + a), None
        if cfg.remat == "full":
            dec_body = jax.checkpoint(dec_body)
        (x, aux), _ = jax.lax.scan(dec_body, (x, aux), stages["dec"])
        return x, aux

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        return softmax_xent(logits, batch["targets"],
                            self.cfg.vocab_size) + aux

    # ------------------------------------------------------------------ decode
    def flat_layers(self, stages):
        """See :func:`flat_stage_layers` (ragged or legacy stacked)."""
        return flat_stage_layers(stages)

    @staticmethod
    def _stage_shared(stages, k):
        """Stage k's tied shared block in either layout (None if absent)."""
        if isinstance(stages, (tuple, list)):
            return stages[k].get("shared")
        if "shared" in stages:
            return tree_slice(stages["shared"], k)
        return None

    # --------------------------------------------------------- ragged stages
    def partition_stage_params(self, stages, sizes, *, n_chunks=None):
        """Regroup stage params into per-stage trees for ``sizes``.

        ``stages`` is either the ragged canonical tuple (any partition)
        or the legacy stacked layout (leaves [S, Lps, ...]); ``sizes``
        is a per-stage layer-count vector (a planner
        ``Partition.sizes()``), summing to ``cfg.n_layers``.  Returns a
        tuple of ``len(sizes)`` stage trees whose ``layers`` leaves are
        [sizes[k], ...] — the ragged layout the streaming runtime
        executes, realizing non-uniform (DP) plans.  A ragged input
        whose sizes already match is returned as-is.

        ``n_chunks``: expected tree count when it is not the model's
        device-stage count — interleaved/virtual-stage plans split the
        same layers into ``n_stages · v`` chunk-stages, each its own
        tree (device d then holds the chunk trees d, d+S, … — see
        :meth:`device_chunk_params`).  Hybrid models pin one shared
        block per *device*: chunking would hand sibling chunks copies
        of that tied block which per-chunk gradient updates then fork,
        so virtual stages are refused for hybrid models.
        """
        want = n_chunks if n_chunks is not None else self.n_stages
        ragged_in = isinstance(stages, (tuple, list))
        has_shared = ("shared" in stages[0]) if ragged_in else \
            ("shared" in stages)
        if sum(sizes) != self.cfg.n_layers:
            raise ValueError(f"partition sizes {tuple(sizes)} do not cover "
                             f"{self.cfg.n_layers} layers")
        if len(sizes) != want:
            raise ValueError(f"{len(sizes)} partition stages for "
                             f"{want} (chunk-)stages")
        if n_chunks is not None and n_chunks % self.n_stages:
            raise ValueError(f"{n_chunks} chunks do not fold onto "
                             f"{self.n_stages} devices")
        if want > self.n_stages and has_shared:
            raise ValueError(
                f"virtual stages ({want} chunks on {self.n_stages} "
                f"devices) are unsupported for hybrid models: the "
                f"per-device shared block is tied across a device's "
                f"chunks and independent chunk updates would fork it")
        if min(sizes) < 1:
            raise ValueError(f"empty stage in partition sizes {tuple(sizes)}")
        if ragged_in and has_shared and len(stages) != want:
            raise ValueError(
                f"cannot repartition {len(stages)} hybrid stage trees "
                f"into {want}: shared blocks are tied per stage")
        if ragged_in:
            got = tuple(jax.tree.leaves(t["layers"])[0].shape[0]
                        for t in stages)
            if got == tuple(sizes):
                return tuple(stages)
        out = split_flat_stages({"layers": self.flat_layers(stages)}, sizes)
        if has_shared:
            # shared blocks stay with their stage index (tied per
            # stage, no flat layer order): ragged input passes trees
            # through, stacked input slices the [S, ...] stack
            out = tuple(
                {**t, "shared": (stages[k]["shared"] if ragged_in
                                 else tree_slice(stages["shared"], k))}
                for k, t in enumerate(out))
        return out

    def device_chunk_params(self, chunk_trees, n_devices=None):
        """Group chunk-stage trees by hosting device.

        ``chunk_trees`` is :meth:`partition_stage_params` output with
        ``C = n_devices · v`` trees; device ``d`` hosts chunk-stages
        ``d, d+S, …`` (Megatron round-robin placement), so the result is
        a tuple of ``n_devices`` tuples of ``v`` trees — the layout a
        real multi-device deployment materializes per device.
        """
        S = n_devices if n_devices is not None else self.n_stages
        C = len(chunk_trees)
        if S < 1 or C % S:
            raise ValueError(f"{C} chunk trees do not fold onto {S} devices")
        v = C // S
        return tuple(tuple(chunk_trees[c * S + d] for c in range(v))
                     for d in range(S))

    def stack_stage_params(self, stage_trees):
        """Inverse of :meth:`partition_stage_params` for uniform sizes:
        per-stage trees back to the canonical stacked [S, Lps, ...]
        layout (requires equal layer counts)."""
        sizes = {jax.tree.leaves(t["layers"])[0].shape[0]
                 for t in stage_trees}
        if len(sizes) != 1:
            raise ValueError("cannot stack ragged stages "
                             f"(sizes {sorted(sizes)}); uniform only")
        out: Dict[str, Any] = {"layers": jax.tree.map(
            lambda *xs: jnp.stack(xs, 0), *[t["layers"]
                                            for t in stage_trees])}
        if "shared" in stage_trees[0]:
            out["shared"] = jax.tree.map(
                lambda *xs: jnp.stack(xs, 0), *[t["shared"]
                                                for t in stage_trees])
        return out

    def ragged_stage_axes(self, n_stages: int):
        """Logical-axis pytree matching :meth:`partition_stage_params`
        output: one per-stage axes tree repeated ``n_stages`` times
        ('layer' names each stage tree's leading dim; there is no
        'stage' axis — placement of the differently-shaped stage trees
        is per-stage/MPMD, expressed by
        ``runtime.sharding.stage_placement_shardings`` rather than a
        PartitionSpec)."""
        one = self.param_axes()["stages"][0]
        return tuple(one for _ in range(n_stages))

    def init_cache(self, batch: int, max_seq: int, *,
                   stage_sizes: Optional[Sequence[int]] = None):
        """``stage_sizes``: the partition of the params that will be
        decoded (defaults to the model's canonical split).  Only hybrid
        models depend on it — their shared-attention cache has one slot
        per *full* ``shared_attn_every`` segment of each stage, so a
        plan-partitioned hybrid tree needs a cache built for the same
        partition."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        L = cfg.n_layers
        if cfg.is_encdec:
            KV, hd = cfg.n_kv_heads, cfg.hd
            E = WHISPER_ENC_FRAMES
            z = lambda *s: jnp.zeros(s, dt)
            return {
                "self": {"k": z(L, batch, max_seq, KV, hd),
                         "v": z(L, batch, max_seq, KV, hd)},
                "cross": {"k": z(L, batch, E, KV, hd),
                          "v": z(L, batch, E, KV, hd)},
            }
        if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
            one = ssm_mod.rwkv6_init_state(cfg, batch, dt)
            return {"layers": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (L,) + a.shape), one)}
        if cfg.ssm is not None:
            one = ssm_mod.mamba2_init_state(cfg, batch, dt)
            cache = {"layers": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (L,) + a.shape), one)}
            if self.hybrid:
                kv = attn_mod.gqa_init_cache(cfg, batch, max_seq, dt)
                # exactly the slots decode consumes: stage_apply /
                # _decode_hybrid apply a stage's shared block once per
                # *full* k-layer segment, i.e. floor(L_s / k) times (a
                # stage shorter than k never applies it); keep >= 1
                # slot so the cache tree stays constructible — decode
                # then returns it untouched
                sizes = (self.stage_sizes if stage_sizes is None
                         else tuple(stage_sizes))
                n_shared = max(1, sum(
                    n // cfg.ssm.shared_attn_every for n in sizes))
                cache["shared"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (n_shared,) + a.shape), kv)
            return cache
        one = attn_mod.attn_init_cache(cfg, batch, max_seq, dt)
        return {"layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape), one)}

    def decode_step(self, params, cache, token, pos):
        """token [b,1] int32, pos scalar -> (logits [b,1,V'], cache)."""
        cfg = self.cfg
        outer, stages = params["outer"], params["stages"]
        x = embed_apply(cfg, outer["embed"], token)
        if cfg.pos_embed == "sinusoidal":
            d = cfg.d_model
            ang = (pos.astype(jnp.float32) /
                   jnp.power(10000.0, jnp.arange(0, d, 2, jnp.float32) / d))
            pe = jnp.zeros((d,), jnp.float32).at[0::2].set(jnp.sin(ang))
            pe = pe.at[1::2].set(jnp.cos(ang))
            x = x + pe.astype(x.dtype)

        if cfg.is_encdec:
            return self._decode_encdec(params, cache, x, pos)

        if cfg.ssm is not None and not self.hybrid:
            def body(x, inp):
                lp, st = inp
                x, _, _, new_st = block_apply(cfg, lp, x, state=st)
                return x, new_st
            x, new_states = jax.lax.scan(
                body, x, (self.flat_layers(stages), cache["layers"]))
            return self.logits(outer, x), {"layers": new_states}

        if self.hybrid:
            return self._decode_hybrid(params, cache, x, pos)

        def body(x, inp):
            lp, lc = inp
            x, _, new_c, _ = block_apply(cfg, lp, x, cache=lc, pos=pos)
            return x, new_c
        x, new_cache = jax.lax.scan(
            body, x, (self.flat_layers(stages), cache["layers"]))
        return self.logits(outer, x), {"layers": new_cache}

    def stage_sizes_of(self, stages) -> Tuple[int, ...]:
        """The per-stage layer counts a stage-param tree actually
        carries (the model's default for the legacy stacked layout)."""
        if isinstance(stages, (tuple, list)):
            return tuple(jax.tree.leaves(t["layers"])[0].shape[0]
                         for t in stages)
        return tuple(self.stage_sizes)

    def _decode_hybrid(self, params, cache, x, pos):
        cfg = self.cfg
        outer, stages = params["outer"], params["stages"]
        k = cfg.ssm.shared_attn_every
        flat = self.flat_layers(stages)
        new_ssm, new_shared = [], []
        shared_idx = 0
        lo_g = 0
        # segment by the tree's ACTUAL partition, exactly like
        # stage_apply does in training — a plan-partitioned hybrid tree
        # must decode with the same shared-block positions it trained
        # with (the cache must be built for the same partition; see
        # init_cache's stage_sizes parameter)
        for s, L_s in enumerate(self.stage_sizes_of(stages)):
            lo = 0
            while lo < L_s:
                hi = min(lo + k, L_s)

                def body(x, inp):
                    lp, st = inp
                    x, _, _, new_st = block_apply(cfg, lp, x, state=st)
                    return x, new_st
                seg = (tree_slice_range(flat, lo_g + lo, lo_g + hi),
                       tree_slice_range(cache["layers"], lo_g + lo, lo_g + hi))
                x, st = jax.lax.scan(body, x, seg)
                new_ssm.append(st)
                if hi < L_s or lo + k == L_s:
                    sc = tree_slice(cache["shared"], shared_idx)
                    x, nc = shared_block_apply(
                        cfg, self._stage_shared(stages, s), x, pos=pos,
                        cache=sc)
                    new_shared.append(nc)
                    shared_idx += 1
                lo = hi
            lo_g += L_s
        cat = lambda *ts: jnp.concatenate(ts, 0)
        new_cache = {
            "layers": jax.tree.map(cat, *new_ssm),
            "shared": jax.tree.map(lambda *ts: jnp.stack(ts, 0), *new_shared)
            if new_shared else cache["shared"],
        }
        return self.logits(outer, x), new_cache

    def _decode_encdec(self, params, cache, x, pos):
        cfg = self.cfg
        outer, stages = params["outer"], params["stages"]

        def body(x, inp):
            lp, sc, ck, cv = inp
            xn = norm_apply(cfg, lp["ln1"], x)
            h, new_sc = attn_mod.gqa_apply(cfg, lp["attn"], xn,
                                           cache=sc, pos=pos)
            x = x + h
            # cross-attn against precomputed enc K/V
            xq = norm_apply(cfg, lp["lnx"], x)
            dt = x.dtype
            b = x.shape[0]
            H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
            q = (xq @ lp["xattn"]["wq"].astype(dt)).reshape(b, 1, H, hd)
            from repro.models.attention import _attend
            o = _attend(cfg, q, ck.astype(dt), cv.astype(dt), causal=False,
                        q_pos=jnp.zeros((1,), jnp.int32), k_len=ck.shape[1])
            x = x + o.reshape(b, 1, H * hd) @ lp["xattn"]["wo"].astype(dt)
            from repro.models.layers import mlp_apply
            x = x + mlp_apply(cfg, lp["mlp"],
                              norm_apply(cfg, lp["ln2"], x))
            return x, new_sc

        x, new_self = jax.lax.scan(
            body, x, (stages["dec"], cache["self"],
                      cache["cross"]["k"], cache["cross"]["v"]))
        return self.logits(outer, x), {"self": new_self,
                                       "cross": cache["cross"]}

    def prefill(self, params, batch, max_seq: int):
        """Full forward building a decode cache (attention archs)."""
        cfg = self.cfg
        outer, stages = params["outer"], params["stages"]
        if cfg.is_encdec or self.hybrid or cfg.ssm is not None:
            # handled by specialised paths / tests use decode from scratch
            logits, aux = self.forward(params, batch)
            return logits, None
        x = self.embed(outer, batch)
        s = x.shape[1]

        def body(carry, lp):
            x, aux = carry
            x, a, new_c, _ = block_apply(cfg, lp, x, cache={})
            return (x, aux + a), new_c
        (x, aux), caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), self.flat_layers(stages))
        full = self.init_cache(x.shape[0], max_seq)
        placed = jax.tree.map(
            lambda buf, got: jax.lax.dynamic_update_slice(
                buf, got.astype(buf.dtype), (0,) * buf.ndim),
            full["layers"], caches)
        return self.logits(outer, x), {"layers": placed}

    # ------------------------------------------------------- pipelined serve
    def decode_embed(self, outer, tokens, pos):
        """Embed decode tokens with per-position encodings.

        ``tokens`` is [b, s] int32; ``pos`` is int32 *broadcastable to*
        ``tokens.shape`` (the decode wave passes [R, 1] per-request
        positions, a prefill lane [1, P] = ``arange(P)``).  Elementwise
        this is exactly :meth:`decode_step`'s embed + sinusoidal term,
        so pipelined serving stays bitwise-identical to whole-model
        decoding."""
        cfg = self.cfg
        x = embed_apply(cfg, outer["embed"], tokens)
        if cfg.pos_embed == "sinusoidal":
            d = cfg.d_model
            p = jnp.asarray(pos, jnp.float32)
            ang = (p[..., None] /
                   jnp.power(10000.0, jnp.arange(0, d, 2, jnp.float32) / d))
            pe = jnp.zeros(p.shape + (d,), jnp.float32)
            pe = pe.at[..., 0::2].set(jnp.sin(ang))
            pe = pe.at[..., 1::2].set(jnp.cos(ang))
            x = x + pe.astype(x.dtype)
        return x

    def stage_decode(self, stage_params, stage_cache, x, pos):
        """One chunk-stage's single-token decode: x [b, 1, d], scalar
        ``pos`` -> (x [b, 1, d], new stage cache).  ``stage_params`` is
        one :meth:`partition_stage_params` chunk tree, ``stage_cache``
        the matching slice of an :meth:`init_cache` tree.  Scanning the
        stage's layers with :meth:`decode_step`'s per-layer bodies keeps
        the per-(layer, token) op sequence — and therefore the emitted
        tokens — bitwise-identical to whole-model decoding."""
        cfg = self.cfg
        if cfg.is_encdec or self.hybrid:
            kind = "encoder-decoder" if cfg.is_encdec else "hybrid"
            raise NotImplementedError(
                f"stage_decode does not support {kind} models "
                f"({cfg.name}): their decode state is not a per-layer "
                f"scan (cross-attention / tied shared blocks); serve "
                f"them with launch/serve.py's whole-model SimpleEngine")
        layers = stage_params["layers"]
        if cfg.ssm is not None:
            def body(x, inp):
                lp, st = inp
                x, _, _, new_st = block_apply(cfg, lp, x, state=st)
                return x, new_st
            x, new_states = jax.lax.scan(
                body, x, (layers, stage_cache["layers"]))
            return x, {"layers": new_states}

        def body(x, inp):
            lp, lc = inp
            x, _, new_c, _ = block_apply(cfg, lp, x, cache=lc, pos=pos)
            return x, new_c
        x, new_cache = jax.lax.scan(
            body, x, (layers, stage_cache["layers"]))
        return x, {"layers": new_cache}

    def stage_prefill(self, stage_params, stage_cache, x_seq, n_valid):
        """One chunk-stage's whole-prompt prefill in a single call:
        x_seq [1, P, d] -> (y_seq [1, P, d], new stage cache).

        Scans :meth:`stage_decode` over positions 0..P-1 inside one
        XLA computation (one Python dispatch per *chunk*, not per
        token); positions >= ``n_valid`` compute on padding but their
        cache updates are masked out, so the final cache equals a
        token-by-token prefill of exactly the first ``n_valid`` tokens
        from ``stage_cache`` — pass a fresh init slice to keep a
        recycled KV page from leaking its previous request's state."""
        P = x_seq.shape[1]

        def body(cache, i):
            x = jax.lax.dynamic_slice_in_dim(x_seq, i, 1, 1)
            y, new_c = self.stage_decode(stage_params, cache, x, i)
            keep = i < n_valid
            new_c = jax.tree.map(
                lambda o, n: jnp.where(keep, n.astype(o.dtype), o),
                cache, new_c)
            return new_c, y

        new_cache, ys = jax.lax.scan(
            body, stage_cache, jnp.arange(P, dtype=jnp.int32))
        return jnp.swapaxes(ys[:, :, 0, :], 0, 1), new_cache


# ===========================================================================
# cache logical axes (for decode-cell sharding)
# ===========================================================================


def cache_axes(model: "Model"):
    """Logical-axis pytree mirroring ``init_cache`` output structure."""
    cfg = model.cfg
    gqa_ax = {"k": ("layer", "act_batch", "act_kvseq", "kv", "head_dim"),
              "v": ("layer", "act_batch", "act_kvseq", "kv", "head_dim")}
    if cfg.is_encdec:
        return {"self": gqa_ax, "cross": gqa_ax}
    if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        return {"layers": {
            "x_tm": ("layer", "act_batch", "heads"),
            "x_cm": ("layer", "act_batch", "heads"),
            "S": ("layer", "act_batch", "heads", "head_dim", "head_dim"),
        }}
    if cfg.ssm is not None:
        ax = {"layers": {
            "conv_x": ("layer", "act_batch", None, "ssm"),
            "conv_bc": ("layer", "act_batch", None, None),
            "S": ("layer", "act_batch", "heads", "head_dim", "state"),
        }}
        if model.hybrid:
            ax["shared"] = gqa_ax
        return ax
    if cfg.mla is not None:
        return {"layers": {
            "c_kv": ("layer", "act_batch", "act_kvseq", None),
            "k_rope": ("layer", "act_batch", "act_kvseq", None),
        }}
    return {"layers": gqa_ax}


# ===========================================================================
# dry-run input specs
# ===========================================================================


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    model = Model(cfg)
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    cdt = jnp.dtype(cfg.compute_dtype)
    tok = lambda *s: jax.ShapeDtypeStruct(s, i32)

    if shape.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {"tokens": tok(B, S)}
        if shape.kind == "train":
            batch["targets"] = tok(B, S)
        if cfg.frontend == "audio":
            batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cdt)
        if cfg.frontend == "vision":
            batch["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_patches, cfg.d_model), cdt)
        return {"batch": batch}

    # decode: one token against a seq_len cache
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    return {"cache": cache, "token": tok(B, 1),
            "pos": jax.ShapeDtypeStruct((), i32)}
