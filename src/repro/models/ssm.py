"""Attention-free sequence mixers: RWKV-6 ("Finch") and Mamba-2 (SSD).

Both expose:  specs / apply (full sequence, differentiable lax.scan) /
init_state / decode_step semantics via the same ``apply`` with ``state``.
The Pallas kernels in ``repro.kernels`` implement the same math chunked for
TPU; ``ref.py`` oracles call back into these.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (ParamSpec, groupnorm_heads, shard_act)

State = Dict[str, Any]

# ===========================================================================
# RWKV-6 time-mix + channel-mix
# ===========================================================================

_RWKV_LORA_MIX = 32
_RWKV_LORA_DECAY = 64


def rwkv6_tm_specs(cfg):
    d = cfg.d_model
    return {
        "mu_x": ParamSpec((d,), ("embed",), "uniform", 0.5),
        "mus": ParamSpec((5, d), (None, "embed"), "uniform", 0.5),
        "mix_A": ParamSpec((d, 5 * _RWKV_LORA_MIX), ("embed", None)),
        "mix_B": ParamSpec((5, _RWKV_LORA_MIX, d), (None, None, "embed")),
        "w0": ParamSpec((d,), ("embed",), "uniform", 1.0),
        "dw_A": ParamSpec((d, _RWKV_LORA_DECAY), ("embed", None)),
        "dw_B": ParamSpec((_RWKV_LORA_DECAY, d), (None, "embed")),
        "u": ParamSpec((d,), ("heads",), "uniform", 0.5),
        "wr": ParamSpec((d, d), ("embed", "heads")),
        "wk": ParamSpec((d, d), ("embed", "heads")),
        "wv": ParamSpec((d, d), ("embed", "heads")),
        "wg": ParamSpec((d, d), ("embed", "heads")),
        "wo": ParamSpec((d, d), ("heads", "embed")),
        "gn_scale": ParamSpec((d,), ("heads",), "ones"),
        "gn_bias": ParamSpec((d,), ("heads",), "zeros"),
    }


def rwkv6_cm_specs(cfg):
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "mu_ck": ParamSpec((d,), ("embed",), "uniform", 0.5),
        "mu_cr": ParamSpec((d,), ("embed",), "uniform", 0.5),
        "wck": ParamSpec((d, ff), ("embed", "mlp")),
        "wcv": ParamSpec((ff, d), ("mlp", "embed")),
        "wcr": ParamSpec((d, d), ("embed", "embed2")),
    }


def _token_shift(x, prev):
    """prev: [b,d] last token of previous chunk (zeros at stream start)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def rwkv6_wkv_ref(r, k, v, w, u, S0):
    """The WKV6 recurrence (pure scan oracle, fp32).

    r,k,v,w: [b,s,h,hd]; u: [h,hd]; S0: [b,h,hd,hd] (key x value).
    Returns y [b,s,h,hd], S_T.
    """
    f32 = jnp.float32
    r, k, v, w = (t.astype(f32) for t in (r, k, v, w))
    u = u.astype(f32)

    def step(S, rkvw):
        rt, kt, vt, wt = rkvw  # [b,h,hd]
        kv = kt[..., :, None] * vt[..., None, :]          # [b,h,hd,hd]
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[..., :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    S_T, ys = jax.lax.scan(step, S0.astype(f32), xs)
    return jnp.moveaxis(ys, 0, 1), S_T


def rwkv6_wkv_chunked(r, k, v, w, u, S0, *, chunk: int = 32):
    """Chunk-parallel WKV6 (the Pallas kernel's math in XLA, fully
    differentiable).  Replaces the O(s)-sequential scan with O(s/chunk)
    sequential steps of MXU-friendly [C,C] matmuls — the hillclimb fix for
    the scan-bound rwkv6/zamba2 training cells.

    r,k,v,w: [b,s,h,hd]; u: [h,hd]; S0: [b,h,hd,hd].  Returns (y, S_T).
    """
    f32 = jnp.float32
    b, s, h, hd = r.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rc, kc, vc, wc = (t.astype(f32).reshape(b, nc, chunk, h, hd)
                      for t in (r, k, v, w))
    uf = u.astype(f32)
    rows = jnp.arange(chunk)[:, None]
    cols = jnp.arange(chunk)[None, :]

    def body(S, inp):
        r_, k_, v_, w_ = inp                     # [b,C,h,hd]
        cp = jnp.cumprod(w_, axis=1)
        cw = cp / w_
        r_s = r_ * cw
        k_s = k_ / jnp.maximum(cp, 1e-24)
        score = jnp.einsum("bihd,bjhd->bhij", r_s, k_s)
        score = jnp.where((rows > cols)[None, None], score, 0.0)
        diag = jnp.einsum("bihd,hd,bihd->bhi", r_, uf, k_)
        score = score + jnp.where((rows == cols)[None, None],
                                  diag[..., :, None], 0.0)
        y = jnp.einsum("bhij,bjhd->bihd", score, v_)
        y = y + jnp.einsum("bihd,bhde->bihe", r_s, S)
        cpl = cp[:, -1]                          # [b,h,hd]
        k_tail = k_ * (cpl[:, None] / jnp.maximum(cp, 1e-24))
        S = cpl[..., :, None] * S + jnp.einsum("bjhd,bjhe->bhde", k_tail, v_)
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rc, kc, vc, wc))
    S_T, ys = jax.lax.scan(body, S0.astype(f32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, hd)
    return y, S_T


def mamba2_ssd_chunked(xh, dt, decay, B, C, S0, *, chunk: int = 32):
    """Chunk-parallel SSD (Mamba-2 dual form) in XLA, differentiable.

    xh: [b,s,nh,hd]; dt,decay: [b,s,nh]; B,C: [b,s,g,ds]; S0 [b,nh,hd,ds].
    """
    f32 = jnp.float32
    b, s, nh, hd = xh.shape
    g = B.shape[2]
    rep = nh // g
    Bh = jnp.repeat(B, rep, axis=2).astype(f32)
    Ch = jnp.repeat(C, rep, axis=2).astype(f32)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    resh = lambda t, tail: t.astype(f32).reshape((b, nc, chunk) + tail)
    xc = resh(xh, (nh, hd))
    dc = resh(dt, (nh,))
    ec = resh(decay, (nh,))
    Bc = resh(Bh, (nh, B.shape[-1]))
    Cc = resh(Ch, (nh, B.shape[-1]))
    rows = jnp.arange(chunk)[:, None]
    cols = jnp.arange(chunk)[None, :]

    def body(S, inp):
        x_, dt_, de_, B_, C_ = inp
        cp = jnp.cumprod(de_, axis=1)            # [b,C,h]
        dtx = dt_[..., None] * x_                # [b,C,h,hd]
        score = jnp.einsum("bihn,bjhn->bhij", C_, B_)
        cph = cp.transpose(0, 2, 1)              # [b,h,C]
        ratio = cph[:, :, :, None] / jnp.maximum(cph[:, :, None, :], 1e-24)
        score = jnp.where((rows >= cols)[None, None], score * ratio, 0.0)
        y = jnp.einsum("bhij,bjhp->bihp", score, dtx)
        y = y + cp[..., None] * jnp.einsum("bihn,bhpn->bihp", C_, S)
        cpl = cp[:, -1]                          # [b,h]
        tail = (cpl[:, None] / jnp.maximum(cp, 1e-24))[..., None] * dtx
        S = cpl[..., None, None] * S + jnp.einsum("bjhp,bjhn->bhpn",
                                                  tail, B_)
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xc, dc, ec, Bc, Cc))
    S_T, ys = jax.lax.scan(body, S0.astype(f32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, nh, hd)
    return y, S_T


# Chunked-path toggles (hillclimb: enable for long-sequence training).
# Off by default: the chunk-product rescaling underflows fp32 for extreme
# decays (w < ~0.15 over a 32-chunk), the same stability envelope as
# production GLA/RWKV kernels, which solve it with log-space chunk-local
# renormalization — done inside the Pallas kernel on TPU; the XLA twin
# here keeps the plain form and is gated to measured/benchmark paths.
USE_CHUNKED = False
CHUNKED_MIN_SEQ = 256
CHUNK = 32


def rwkv6_tm_apply(cfg, p, x, state: Optional[State] = None,
                   wkv_fn=None) -> Tuple[jnp.ndarray, Optional[State]]:
    """x: [b,s,d] (already normed).  state carries (x_prev, S)."""
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    b, s, _ = x.shape
    dt = x.dtype
    prev = state["x_tm"] if state is not None else jnp.zeros((b, d), dt)
    xp = _token_shift(x, prev)
    sx = xp - x
    xxx = x + sx * p["mu_x"].astype(dt)
    zmix = jnp.tanh(xxx @ p["mix_A"].astype(dt)).reshape(
        b, s, 5, _RWKV_LORA_MIX)
    mix = jnp.einsum("bsfk,fkd->bsfd", zmix, p["mix_B"].astype(dt))
    comp = x[:, :, None, :] + sx[:, :, None, :] * (
        p["mus"].astype(dt)[None, None] + mix)
    xw, xk, xv, xr, xg = [comp[:, :, i] for i in range(5)]

    logw = p["w0"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["dw_A"].astype(dt)) @ p["dw_B"].astype(dt)
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw))                            # [b,s,d] in (0,1)

    r = (xr @ p["wr"].astype(dt)).reshape(b, s, H, hd)
    k = (xk @ p["wk"].astype(dt)).reshape(b, s, H, hd)
    v = (xv @ p["wv"].astype(dt)).reshape(b, s, H, hd)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))
    r = shard_act(r, "act_batch", None, "heads", None)
    k = shard_act(k, "act_batch", None, "heads", None)
    v = shard_act(v, "act_batch", None, "heads", None)
    wh = w.reshape(b, s, H, hd)
    u = p["u"].astype(jnp.float32).reshape(H, hd)

    S0 = (state["S"] if state is not None
          else jnp.zeros((b, H, hd, hd), jnp.float32))
    fn = wkv_fn
    if fn is None:
        if (USE_CHUNKED and state is None and s >= CHUNKED_MIN_SEQ
                and s % CHUNK == 0):
            fn = lambda *a: rwkv6_wkv_chunked(*a, chunk=CHUNK)
        else:
            fn = rwkv6_wkv_ref
    y, S_T = fn(r, k, v, wh, u, S0)
    y = y.reshape(b, s, d).astype(dt)
    y = groupnorm_heads(y, p["gn_scale"], p["gn_bias"], H)
    out = (y * g) @ p["wo"].astype(dt)
    new_state = None
    if state is not None:
        new_state = {"x_tm": x[:, -1, :], "S": S_T}
    return out, new_state


def rwkv6_cm_apply(cfg, p, x, state: Optional[State] = None):
    dt = x.dtype
    b = x.shape[0]
    prev = state["x_cm"] if state is not None else jnp.zeros(
        (b, cfg.d_model), dt)
    xp = _token_shift(x, prev)
    sx = xp - x
    xk = x + sx * p["mu_ck"].astype(dt)
    xr = x + sx * p["mu_cr"].astype(dt)
    h = jnp.square(jax.nn.relu(xk @ p["wck"].astype(dt)))
    h = shard_act(h, "act_batch", None, "mlp")
    out = jax.nn.sigmoid(xr @ p["wcr"].astype(dt)) * (h @ p["wcv"].astype(dt))
    new_state = {"x_cm": x[:, -1, :]} if state is not None else None
    return out, new_state


def rwkv6_init_state(cfg, batch: int, dtype):
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    return {"x_tm": jnp.zeros((batch, d), dtype),
            "x_cm": jnp.zeros((batch, d), dtype),
            "S": jnp.zeros((batch, H, hd, hd), jnp.float32)}


# ===========================================================================
# Mamba-2 (SSD)
# ===========================================================================


def mamba2_specs(cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    bc = 2 * s.n_groups * s.d_state
    return {
        "w_zx": ParamSpec((d, 2 * d_in), ("embed", "ssm")),
        "w_bc": ParamSpec((d, bc), ("embed", None)),
        "w_dt": ParamSpec((d, nh), ("embed", "heads")),
        "conv_x_w": ParamSpec((s.conv_kernel, d_in), (None, "ssm")),
        "conv_x_b": ParamSpec((d_in,), ("ssm",), "zeros"),
        "conv_bc_w": ParamSpec((s.conv_kernel, bc), (None, None)),
        "conv_bc_b": ParamSpec((bc,), (None,), "zeros"),
        "A_log": ParamSpec((nh,), ("heads",), "uniform", 1.0),
        "D": ParamSpec((nh,), ("heads",), "ones"),
        "dt_bias": ParamSpec((nh,), ("heads",), "uniform", 1.0),
        "norm_scale": ParamSpec((d_in,), ("ssm",), "ones"),
        "w_out": ParamSpec((d_in, d), ("ssm", "embed")),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv.  x: [b,s,c]; w: [k,c].  conv_state: [b,k-1,c]."""
    kk = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], kk - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, j:j + x.shape[1], :] * w[j][None, None, :]
            for j in range(kk))
    new_state = xp[:, -(kk - 1):, :] if conv_state is not None else None
    return y + b[None, None, :], new_state


def mamba2_ssd_ref(xh, dt, decay, B, C, S0):
    """SSD recurrence oracle (fp32 scan).

    xh: [b,s,nh,hd]; dt,decay: [b,s,nh]; B,C: [b,s,g,ds]; S0: [b,nh,hd,ds].
    """
    f32 = jnp.float32
    nh = xh.shape[2]
    g = B.shape[2]
    rep = nh // g
    Bh = jnp.repeat(B, rep, axis=2).astype(f32)   # [b,s,nh,ds]
    Ch = jnp.repeat(C, rep, axis=2).astype(f32)
    xh, dt, decay = (t.astype(f32) for t in (xh, dt, decay))

    def step(S, inp):
        x_t, dt_t, de_t, B_t, C_t = inp
        S = S * de_t[..., None, None] + (
            (dt_t[..., None] * x_t)[..., :, None] * B_t[..., None, :])
        y = jnp.einsum("bhps,bhs->bhp", S, C_t)
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xh, dt, decay, Bh, Ch))
    S_T, ys = jax.lax.scan(step, S0.astype(f32), xs)
    return jnp.moveaxis(ys, 0, 1), S_T


def mamba2_apply(cfg, p, x, state: Optional[State] = None,
                 ssd_fn=None) -> Tuple[jnp.ndarray, Optional[State]]:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    hd = s.head_dim
    b, sl, _ = x.shape
    dt_ = x.dtype

    zx = x @ p["w_zx"].astype(dt_)
    z, xr = jnp.split(zx, 2, axis=-1)
    bc = x @ p["w_bc"].astype(dt_)
    delta = jax.nn.softplus(
        (x @ p["w_dt"].astype(dt_)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                  # [b,s,nh]

    cs_x = state["conv_x"] if state is not None else None
    cs_bc = state["conv_bc"] if state is not None else None
    xr, new_cs_x = _causal_conv(xr, p["conv_x_w"].astype(dt_),
                                p["conv_x_b"].astype(dt_), cs_x)
    bc, new_cs_bc = _causal_conv(bc, p["conv_bc_w"].astype(dt_),
                                 p["conv_bc_b"].astype(dt_), cs_bc)
    xr = jax.nn.silu(xr)
    bc = jax.nn.silu(bc)
    B, C = jnp.split(bc, 2, axis=-1)
    B = B.reshape(b, sl, s.n_groups, s.d_state)
    C = C.reshape(b, sl, s.n_groups, s.d_state)

    a = -jnp.exp(p["A_log"].astype(jnp.float32))             # (nh,)
    decay = jnp.exp(a[None, None, :] * delta)                # [b,s,nh]
    xh = xr.reshape(b, sl, nh, hd)
    xh = shard_act(xh, "act_batch", None, "heads", None)

    S0 = (state["S"] if state is not None
          else jnp.zeros((b, nh, hd, s.d_state), jnp.float32))
    fn = ssd_fn
    if fn is None:
        if (USE_CHUNKED and state is None and sl >= CHUNKED_MIN_SEQ
                and sl % CHUNK == 0):
            fn = lambda *a: mamba2_ssd_chunked(*a, chunk=CHUNK)
        else:
            fn = mamba2_ssd_ref
    y, S_T = fn(xh, delta, decay, B, C, S0)
    y = (y + p["D"].astype(jnp.float32)[None, None, :, None]
         * xh.astype(jnp.float32))
    y = y.reshape(b, sl, d_in).astype(dt_)

    # gated RMSNorm
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + 1e-5)
         * p["norm_scale"].astype(jnp.float32)).astype(dt_)
    out = y @ p["w_out"].astype(dt_)

    new_state = None
    if state is not None:
        new_state = {"conv_x": new_cs_x, "conv_bc": new_cs_bc, "S": S_T}
    return out, new_state


def mamba2_init_state(cfg, batch: int, dtype):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    bc = 2 * s.n_groups * s.d_state
    return {
        "conv_x": jnp.zeros((batch, s.conv_kernel - 1, d_in), dtype),
        "conv_bc": jnp.zeros((batch, s.conv_kernel - 1, bc), dtype),
        "S": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }
