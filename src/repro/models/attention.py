"""Attention variants: GQA/MQA/MHA, MLA (DeepSeek-V2/MiniCPM3), cross-attn.

All support three entry modes:
  * full sequence (train / prefill, causal or bidirectional)
  * prefill -> returns a KV cache
  * single-token decode against a KV cache
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.blocked_attention import blocked_attention
from repro.models.layers import (ParamSpec, apply_rope, norm_apply,
                                 norm_specs, rope_freqs, shard_act)

Cache = Dict[str, Any]

# Above this many score elements per (batch, head), attention runs through
# the blocked (flash) path instead of materializing [sq, sk] scores.
BLOCK_THRESHOLD = 2 ** 21
BLOCK_Q, BLOCK_K = 512, 1024


def _use_blocked(sq: int, sk: int) -> bool:
    return sq > 1 and sq * sk >= BLOCK_THRESHOLD


# ---------------------------------------------------------------------------
# GQA


def gqa_specs(cfg, cross: bool = False):
    d, hd = cfg.d_model, cfg.hd
    H, KV = cfg.n_heads, cfg.n_kv_heads
    return {
        "wq": ParamSpec((d, H * hd), ("embed", "heads")),
        "wk": ParamSpec((d, KV * hd), ("embed", "kv")),
        "wv": ParamSpec((d, KV * hd), ("embed", "kv")),
        "wo": ParamSpec((H * hd, d), ("heads", "embed")),
    }


def _attend(cfg, q, k, v, *, causal: bool, q_pos, k_len: int,
            k_valid_len=None):
    """q: [b,sq,H,hd] k/v: [b,sk,KV,hd].  q_pos: [sq] absolute positions.
    k_valid_len: optional scalar; keys >= it are masked (decode cache)."""
    H, KV = q.shape[2], k.shape[2]
    G = H // KV
    b, sq = q.shape[0], q.shape[1]
    sk = k.shape[1]
    qg = q.reshape(b, sq, KV, G, q.shape[-1])
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(q.shape[-1])
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = q_pos[:, None] >= kpos[None, :]
    if k_valid_len is not None:
        mask = mask & (kpos[None, :] < k_valid_len)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, sq, H, q.shape[-1])


def gqa_apply(cfg, p, x, *, pos_offset: int = 0, causal: bool = True,
              cache: Optional[Cache] = None, pos=None,
              kv_input=None) -> Tuple[jnp.ndarray, Optional[Cache]]:
    """x: [b,s,d].  If ``cache`` given and s==1 -> decode step at ``pos``.
    ``kv_input``: source for k/v (cross-attention)."""
    dt = x.dtype
    b, s, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = x if kv_input is None else kv_input
    q = (x @ p["wq"].astype(dt)).reshape(b, s, H, hd)
    k = (src @ p["wk"].astype(dt)).reshape(b, src.shape[1], KV, hd)
    v = (src @ p["wv"].astype(dt)).reshape(b, src.shape[1], KV, hd)
    q = shard_act(q, "act_batch", None, "heads", None)
    k = shard_act(k, "act_batch", None, "kv", None)
    v = shard_act(v, "act_batch", None, "kv", None)

    if cfg.pos_embed == "rope" and kv_input is None:
        inv = rope_freqs(cfg)
        if pos is None:
            q_pos = jnp.arange(s) + pos_offset
        else:
            q_pos = jnp.asarray(pos).reshape((1,))
        q = apply_rope(q, q_pos[None, :], inv)
        if cache is None or kv_input is not None or s > 1:
            k = apply_rope(
                k, (jnp.arange(src.shape[1]) + pos_offset)[None, :], inv)
        else:
            k = apply_rope(k, q_pos[None, :], inv)
    else:
        q_pos = (jnp.arange(s) + pos_offset) if pos is None \
            else jnp.asarray(pos).reshape((1,))

    new_cache = None
    if cache is not None:
        if s == 1 and cache.get("k") is not None and kv_input is None:
            # decode: insert at pos
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
            new_cache = {"k": ck, "v": cv}
            out = _attend(cfg, q, ck.astype(dt), cv.astype(dt), causal=False,
                          q_pos=q_pos, k_len=ck.shape[1], k_valid_len=pos + 1)
            return out.reshape(b, s, H * hd) @ p["wo"].astype(dt), new_cache
        new_cache = {"k": k, "v": v}  # prefill fills the cache

    if _use_blocked(q.shape[1], k.shape[1]):
        out = blocked_attention(q, k, v, causal and kv_input is None,
                                BLOCK_Q, BLOCK_K,
                                pos_offset if pos is None else 0)
    else:
        out = _attend(cfg, q, k, v, causal=causal and kv_input is None,
                      q_pos=q_pos, k_len=k.shape[1])
    out = out.reshape(b, s, H * hd)
    out = shard_act(out, "act_batch", "act_seq", "heads")
    return out @ p["wo"].astype(dt), new_cache


def gqa_init_cache(cfg, batch: int, max_seq: int, dtype):
    KV, hd = cfg.n_kv_heads, cfg.hd
    return {"k": jnp.zeros((batch, max_seq, KV, hd), dtype),
            "v": jnp.zeros((batch, max_seq, KV, hd), dtype)}


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention


def mla_specs(cfg):
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": ParamSpec((d, m.q_lora_rank), ("embed", None)),
        "q_norm": norm_specs(cfg, "rmsnorm", m.q_lora_rank),
        "w_uq": ParamSpec((m.q_lora_rank, H * qk_hd), (None, "heads")),
        "w_dkv": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim),
                           ("embed", None)),
        "kv_norm": norm_specs(cfg, "rmsnorm", m.kv_lora_rank),
        "w_ukv": ParamSpec((m.kv_lora_rank,
                            H * (m.qk_nope_head_dim + m.v_head_dim)),
                           (None, "heads")),
        "wo": ParamSpec((H * m.v_head_dim, d), ("heads", "embed")),
    }


def _mla_qk(cfg, p, x, c_kv, k_rope, q_pos, k_pos):
    """Returns q_nope,q_rope,k_nope,v with rope applied."""
    m, H = cfg.mla, cfg.n_heads
    dt = x.dtype
    b, s = x.shape[0], x.shape[1]
    sk = c_kv.shape[1]
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = norm_apply(cfg, p["q_norm"], x @ p["w_dq"].astype(dt), "rmsnorm")
    q = (q @ p["w_uq"].astype(dt)).reshape(b, s, H, qk_hd)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    kv = norm_apply(cfg, p["kv_norm"], c_kv, "rmsnorm")
    kv = (kv @ p["w_ukv"].astype(dt)).reshape(
        b, sk, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    inv = rope_freqs(cfg, m.qk_rope_head_dim)
    q_rope = apply_rope(q_rope, q_pos[None, :], inv)
    k_rope = apply_rope(k_rope[:, :, None, :], k_pos[None, :], inv)
    return q_nope, q_rope, k_nope, k_rope, v


def mla_apply(cfg, p, x, *, pos_offset: int = 0, causal: bool = True,
              cache: Optional[Cache] = None, pos=None):
    m, H = cfg.mla, cfg.n_heads
    dt = x.dtype
    b, s, d = x.shape
    dkv = x @ p["w_dkv"].astype(dt)
    c_kv, k_rope_raw = jnp.split(dkv, [m.kv_lora_rank], axis=-1)

    if cache is not None and s == 1:
        pos = jnp.asarray(pos)
        c_all = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, pos, 0))
        kr_all = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope_raw.astype(cache["k_rope"].dtype),
            (0, pos, 0))
        q_pos = pos.reshape((1,))
        k_pos = jnp.arange(c_all.shape[1])
        q_nope, q_rope, k_nope, k_rope, v = _mla_qk(
            cfg, p, x, c_all.astype(dt), kr_all.astype(dt), q_pos, k_pos)
        scores = (jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope)
                  + jnp.einsum("bqhd,bsod->bhqs", q_rope, k_rope))
        scores = scores.astype(jnp.float32) / math.sqrt(
            m.qk_nope_head_dim + m.qk_rope_head_dim)
        mask = (k_pos[None, :] <= pos)
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, -1).astype(dt)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, v).reshape(
            b, s, H * m.v_head_dim)
        return out @ p["wo"].astype(dt), {"c_kv": c_all, "k_rope": kr_all}

    q_pos = jnp.arange(s) + pos_offset
    k_pos = q_pos
    q_nope, q_rope, k_nope, k_rope, v = _mla_qk(
        cfg, p, x, c_kv, k_rope_raw, q_pos, k_pos)
    if _use_blocked(s, s):
        # fold the decoupled-rope term into one dot: concat nope|rope dims
        q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_cat = jnp.concatenate(
            [k_nope, jnp.broadcast_to(
                k_rope, k_nope.shape[:3] + (k_rope.shape[-1],))], axis=-1)
        out = blocked_attention(q_cat, k_cat, v, causal,
                                BLOCK_Q, BLOCK_K, pos_offset)
        out = out.reshape(b, s, H * m.v_head_dim)
        out = shard_act(out, "act_batch", "act_seq", "heads")
        return out @ p["wo"].astype(dt), (
            {"c_kv": c_kv, "k_rope": k_rope_raw}
            if cache is not None else None)
    scores = (jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope)
              + jnp.einsum("bqhd,bsod->bhqs", q_rope, k_rope))
    scores = scores.astype(jnp.float32) / math.sqrt(
        m.qk_nope_head_dim + m.qk_rope_head_dim)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, -1).astype(dt)
    out = jnp.einsum("bhqs,bshd->bqhd", probs,
                     v).reshape(b, s, H * m.v_head_dim)
    out = shard_act(out, "act_batch", "act_seq", "heads")
    new_cache = ({"c_kv": c_kv, "k_rope": k_rope_raw}
                 if cache is not None else None)
    return out @ p["wo"].astype(dt), new_cache


def mla_init_cache(cfg, batch: int, max_seq: int, dtype):
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dtype)}


# ---------------------------------------------------------------------------
# dispatch


def attn_specs(cfg, cross: bool = False):
    if cfg.mla is not None and not cross:
        return mla_specs(cfg)
    return gqa_specs(cfg, cross)


def attn_apply(cfg, p, x, **kw):
    if cfg.mla is not None and kw.get("kv_input") is None:
        kw.pop("kv_input", None)
        return mla_apply(cfg, p, x, **kw)
    return gqa_apply(cfg, p, x, **kw)


def attn_init_cache(cfg, batch: int, max_seq: int, dtype):
    if cfg.mla is not None:
        return mla_init_cache(cfg, batch, max_seq, dtype)
    return gqa_init_cache(cfg, batch, max_seq, dtype)
