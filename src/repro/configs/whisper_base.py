"""whisper-base [audio]: enc-dec, conv frontend stubbed to frame embeddings.

6L encoder + 6L decoder, d_model=512, 8H (kv=8), d_ff=2048, vocab=51865.
[arXiv:2212.04356]
"""
from repro.configs.base import ArchConfig, MeshPlan, register


@register("whisper-base")
def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-base", family="audio", source="arXiv:2212.04356",
        n_layers=6, n_enc_layers=6,
        d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
        d_ff=2048, vocab_size=51865,
        mlp_gated=False, norm="layernorm", pos_embed="sinusoidal",
        frontend="audio", tie_embeddings=True,
        # too small to pipeline: model axis = 8-way TP x 2-way context par.
        mesh_plan=MeshPlan(pipe=2, tensor=8, pipe_role="context",
                           num_microbatches=4),
        supports_long_context=False,
    )
