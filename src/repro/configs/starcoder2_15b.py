"""starcoder2-15b [dense]: GQA + RoPE code model.

40L, d_model=6144, 48H (GQA kv=4), d_ff=24576 (non-gated), vocab=49152.
[arXiv:2402.19173]
"""
from repro.configs.base import ArchConfig, MeshPlan, register


@register("starcoder2-15b")
def config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-15b", family="dense", source="arXiv:2402.19173",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
        d_ff=24576, vocab_size=49152,
        mlp_gated=False, norm="layernorm", pos_embed="rope",
        mesh_plan=MeshPlan(pipe=4, tensor=4, num_microbatches=8),
        supports_long_context=False,
    )
