"""The paper's own six benchmark models (§4.1).

SNN / Transformer / Residual-LSTM are trainable configs used by the
convergence + RMSE reproductions.  The three CNNs are represented as
byte-level models (exact parameter & inter-stage activation sizes) for the
Fig. 3/4 communication-volume study — see DESIGN.md §6.
"""
from dataclasses import dataclass
from typing import Tuple

from repro.configs.base import ArchConfig, MeshPlan, register


@register("snn-paper")
def snn() -> ArchConfig:
    """SNN (Klambauer et al. 2017): 32 FC layers x 2048 hidden units."""
    return ArchConfig(
        name="snn-paper", family="fcn", source="paper §4.1",
        n_layers=32, d_model=2048, n_heads=1, n_kv_heads=1, head_dim=2048,
        d_ff=2048, vocab_size=3072,  # cifar10: 32*32*3 input, 10 classes
        mlp_gated=False, norm="layernorm", pos_embed="none",
        mesh_plan=MeshPlan(pipe=4, tensor=4, num_microbatches=8),
    )


@register("transformer-paper")
def transformer() -> ArchConfig:
    """Transformer (Vaswani 2017) as used by the paper: 6 enc + 6 dec blocks,
    8 heads, 512 hidden; IMDb sentiment, inputs truncated to 20 words."""
    return ArchConfig(
        name="transformer-paper", family="encdec", source="paper §4.1",
        n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        head_dim=64, d_ff=2048, vocab_size=30000,
        mlp_gated=False, norm="layernorm", pos_embed="sinusoidal",
        mesh_plan=MeshPlan(pipe=2, tensor=8, pipe_role="context",
                           num_microbatches=4),
    )


@register("residual-lstm-paper")
def residual_lstm() -> ArchConfig:
    """Residual LSTM (Kim et al. 2017): 8 LSTM layers, 512 emb/out, 1024 mem.

    Implemented in models/rnn.py; config reuses the ssm slot semantics
    (recurrent family) but with its own apply path.
    """
    return ArchConfig(
        name="residual-lstm-paper", family="rnn", source="paper §4.1",
        n_layers=8, d_model=512, n_heads=1, n_kv_heads=1, head_dim=512,
        d_ff=1024, vocab_size=30000,
        mlp_gated=False, norm="layernorm", pos_embed="none",
        mesh_plan=MeshPlan(pipe=4, tensor=4, num_microbatches=8),
    )


# ---------------------------------------------------------------------------
# CNN byte models for the Fig.3 / Fig.4 communication study


@dataclass(frozen=True)
class CNNByteModel:
    name: str
    params: int                    # total weights
    # bytes of intermediate activations crossing a 4-way pipeline cut,
    # per sample (forward); backward doubles it.
    stage_cut_activations: Tuple[int, ...]  # per cut, elements per sample


CNN_MODELS = (
    # VGG16: 138M params; cuts after conv blocks 2/3/4: 128x56x56 etc.
    CNNByteModel("vgg16", 138_357_544,
                 (128 * 56 * 56, 256 * 28 * 28, 512 * 14 * 14)),
    # ResNet-152: 60.2M params; cuts between res stages
    CNNByteModel("resnet152", 60_192_808,
                 (256 * 56 * 56, 512 * 28 * 28, 1024 * 14 * 14)),
    # Inception v4: 42.7M params; cuts between inception stacks
    CNNByteModel("inception_v4", 42_679_816,
                 (384 * 35 * 35, 1024 * 17 * 17, 1536 * 8 * 8)),
)
