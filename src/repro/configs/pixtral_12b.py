"""pixtral-12b [vlm]: pixtral-ViT frontend (stub) + mistral-nemo backbone.

40L, d_model=5120, 32H (GQA kv=8, head_dim=128), d_ff=14336, vocab=131072.
[hf:mistralai/Pixtral-12B-2409]
"""
from repro.configs.base import ArchConfig, MeshPlan, register


@register("pixtral-12b")
def config() -> ArchConfig:
    return ArchConfig(
        name="pixtral-12b", family="vlm",
        source="hf:mistralai/Pixtral-12B-2409",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=131072,
        mlp_gated=True, norm="rmsnorm", pos_embed="rope", rope_theta=1e6,
        frontend="vision", frontend_patches=256,
        mesh_plan=MeshPlan(pipe=4, tensor=4, num_microbatches=8),
        supports_long_context=False,
    )
