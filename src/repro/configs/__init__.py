from repro.configs.base import (  # noqa: F401
    ArchConfig, MeshPlan, MLAConfig, MoEConfig, SSMConfig, ShapeConfig,
    SHAPES, get_config, list_archs, register, shape_applicable, smoke_config,
)

# import all arch modules so the registry is always populated
from repro.configs import (  # noqa: F401
    whisper_base, pixtral_12b, granite_8b, granite_20b, starcoder2_15b,
    minicpm3_4b, grok_1_314b, deepseek_moe_16b, rwkv6_7b, zamba2_1_2b,
    paper_models,
)
