"""grok-1-314b [moe]: 8 experts top-2.

64L, d_model=6144, 48H (GQA kv=8), d_ff=32768 per expert, vocab=131072.
[hf:xai-org/grok-1]
"""
from repro.configs.base import ArchConfig, MeshPlan, MoEConfig, register


@register("grok-1-314b")
def config() -> ArchConfig:
    return ArchConfig(
        name="grok-1-314b", family="moe", source="hf:xai-org/grok-1",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=32768, vocab_size=131072,
        mlp_gated=True, norm="rmsnorm", pos_embed="rope",
        logit_softcap=30.0,
        moe=MoEConfig(num_experts=8, num_shared=0, top_k=2,
                      capacity_factor=1.25),
        # 314B params: must FSDP over the data axis as well.
        mesh_plan=MeshPlan(pipe=2, tensor=8, fsdp=True, num_microbatches=8),
        supports_long_context=False,
    )
