"""granite-20b [dense]: gpt-bigcode-arch code model, MQA.

52L, d_model=6144, 48H (GQA kv=1 = MQA), d_ff=24576 (non-gated), vocab=49152.
[arXiv:2405.04324]
"""
from repro.configs.base import ArchConfig, MeshPlan, register


@register("granite-20b")
def config() -> ArchConfig:
    return ArchConfig(
        name="granite-20b", family="dense", source="arXiv:2405.04324",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
        d_ff=24576, vocab_size=49152,
        mlp_gated=False, norm="layernorm", pos_embed="rope",
        mesh_plan=MeshPlan(pipe=4, tensor=4, num_microbatches=8),
        supports_long_context=False,
    )
