"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention blocks.

38L, d_model=2048, shared attn 32H (kv=32), d_ff=8192, ssm_state=64,
vocab=32000.  [arXiv:2411.15242]
"""
from repro.configs.base import ArchConfig, MeshPlan, SSMConfig, register


@register("zamba2-1.2b")
def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b", family="hybrid", source="arXiv:2411.15242",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab_size=32000,
        mlp_gated=False, norm="rmsnorm", pos_embed="rope",
        ssm=SSMConfig(kind="mamba2", d_state=64, head_dim=64, expand=2,
                      conv_kernel=4, n_groups=1, shared_attn_every=10),
        tie_embeddings=True,
        mesh_plan=MeshPlan(pipe=2, tensor=8, num_microbatches=4),
        supports_long_context=True,
    )
