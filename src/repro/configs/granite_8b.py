"""granite-8b [dense]: llama-arch code model.

36L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=49152.
[arXiv:2405.04324]
"""
from repro.configs.base import ArchConfig, MeshPlan, register


@register("granite-8b")
def config() -> ArchConfig:
    return ArchConfig(
        name="granite-8b", family="dense", source="arXiv:2405.04324",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=49152,
        mlp_gated=True, norm="rmsnorm", pos_embed="rope",
        mesh_plan=MeshPlan(pipe=4, tensor=4, num_microbatches=8),
        supports_long_context=False,
    )
