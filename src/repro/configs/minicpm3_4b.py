"""minicpm3-4b [dense]: Multi-head Latent Attention (MLA).

62L, d_model=2560, 40H (kv=40 latent-compressed), d_ff=6400, vocab=73448.
[hf:openbmb/MiniCPM3-4B]
"""
from repro.configs.base import ArchConfig, MeshPlan, MLAConfig, register


@register("minicpm3-4b")
def config() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b", family="dense", source="hf:openbmb/MiniCPM3-4B",
        n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=6400, vocab_size=73448,
        mlp_gated=True, norm="rmsnorm", pos_embed="rope",
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                      qk_nope_head_dim=64, qk_rope_head_dim=32,
                      v_head_dim=64),
        tie_embeddings=True,
        mesh_plan=MeshPlan(pipe=2, tensor=8, num_microbatches=4),
        supports_long_context=False,
    )
