"""rwkv6-7b [ssm]: RWKV-6 "Finch" — attention-free, data-dependent decay.

32L, d_model=4096 (64 heads of 64), d_ff=14336, vocab=65536.
[arXiv:2404.05892]
"""
from repro.configs.base import ArchConfig, MeshPlan, SSMConfig, register


@register("rwkv6-7b")
def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-7b", family="ssm", source="arXiv:2404.05892",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
        d_ff=14336, vocab_size=65536,
        norm="layernorm", pos_embed="none",
        ssm=SSMConfig(kind="rwkv6", head_dim=64),
        mesh_plan=MeshPlan(pipe=4, tensor=4, num_microbatches=8),
        supports_long_context=True,
    )
