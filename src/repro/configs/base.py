"""Architecture / run configuration system.

Every assigned architecture is a frozen ``ArchConfig`` built by one
``src/repro/configs/<id>.py`` module.  Configs are pure data: models,
sharding rules, pipeline plans and the dry-run all read from here.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# helpers


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# sub-configs


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts
    num_shared: int = 0             # always-on shared experts (DeepSeekMoE)
    top_k: int = 2
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    router_jitter: float = 0.0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"            # "mamba2" | "rwkv6"
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2                 # d_inner = expand * d_model
    conv_kernel: int = 4            # mamba2 short conv
    n_groups: int = 1               # mamba2 B/C groups
    # zamba2 hybrid: indices (within a stage) where the shared attention
    # block fires.  Empty for pure SSM models.
    shared_attn_every: int = 0      # fire shared block every k ssm layers


@dataclass(frozen=True)
class MeshPlan:
    """How the physical `model` mesh axis (size 16) factors logically.

    pipe * tensor must equal the model-axis size.  ``pipe_role`` says what
    the `pipe` sub-axis is used for: "stage" (pipeline parallelism) or
    "context" (sequence/context parallelism, used when the model is too
    small to pipeline, e.g. whisper-base).
    """
    pipe: int = 4
    tensor: int = 4
    pipe_role: str = "stage"        # "stage" | "context"
    fsdp: bool = False              # shard params over the data axis too
    # streaming pipeline: microbatches in flight == pipe stages; the sync
    # pipeline uses num_microbatches >= pipe.
    num_microbatches: int = 8


@dataclass(frozen=True)
class ArchConfig:
    # identity ------------------------------------------------------------
    name: str = "unnamed"
    family: str = "dense"           # dense|moe|ssm|hybrid|encdec|vlm|audio
    source: str = ""

    # transformer dims ------------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0               # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    mlp_gated: bool = True          # SwiGLU (3 mats) vs GELU (2 mats)
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    pos_embed: str = "rope"         # rope | sinusoidal | none
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    logit_softcap: float = 0.0      # grok-style tanh soft-capping (0 = off)

    # enc-dec (whisper) ------------------------------------------------------
    n_enc_layers: int = 0           # >0 => encoder-decoder
    enc_seq_ratio: float = 1.0      # encoder seq = ratio * seq_len

    # modality frontend stub -------------------------------------------------
    frontend: str = "none"          # none | audio | vision
    frontend_patches: int = 256     # vision: #positions replaced by patches

    # optional modules -------------------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # numerics ---------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"             # full | dots | none

    # distribution ------------------------------------------------------------
    mesh_plan: MeshPlan = field(default_factory=MeshPlan)
    # which shapes this arch supports (long_500k only for sub-quadratic)
    supports_long_context: bool = False

    # ----------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab_size, 1024)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def attn_free(self) -> bool:
        return self.ssm is not None and (self.ssm.shared_attn_every == 0)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------ params
    def param_count(self) -> int:
        """Analytic parameter count (used by tests & comm-volume bench)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.hd
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd

        def attn_params(dm: int) -> int:
            return dm * n_q + 2 * dm * n_kv + n_q * dm

        def mlp_params() -> int:
            mats = 3 if self.mlp_gated else 2
            return mats * d * ff

        if self.mla is not None:
            m = self.mla
            qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * self.n_heads * qk_hd
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            attn = attn_params(d)

        if self.ssm is not None and self.ssm.kind == "rwkv6":
            tm = 5 * d * d                  # r,k,v,g,o projections
            tm += 2 * d * (5 * 32)          # ddlerp mix loras
            tm += 2 * d * 64                # decay lora
            cm = d * ff + ff * d + d * d    # channel mix: k, v, r
            per_layer = tm + cm
            total = self.n_layers * per_layer
        elif self.ssm is not None:  # mamba2 (possibly hybrid)
            s = self.ssm
            d_in = s.expand * d
            n_h = d_in // s.head_dim
            in_p = d * (2 * d_in + 2 * s.n_groups * s.d_state + n_h)
            out_p = d_in * d
            conv = (d_in + 2 * s.n_groups * s.d_state) * s.conv_kernel
            per_layer = in_p + out_p + conv + n_h * 3  # A/D/dt_bias per head
            total = self.n_layers * per_layer
            if s.shared_attn_every:
                shared_blocks = self.mesh_plan.pipe  # one per stage
                total += shared_blocks * (attn_params(d) + mlp_params())
        elif self.moe is not None:
            mo = self.moe
            expert = (3 if self.mlp_gated else 2) * d * ff
            per_layer = attn + (mo.num_experts + mo.num_shared) * expert \
                + d * mo.num_experts
            total = self.n_layers * per_layer
        else:
            per_layer = attn + mlp_params()
            total = self.n_layers * per_layer

        if self.is_encdec:
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            enc = self.n_enc_layers * (attn + mlp_params())
            dec = self.n_layers * (2 * attn + mlp_params())
            total = enc + dec

        emb = V * d * (1 if self.tie_embeddings else 2)
        return int(total + emb)

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only top-k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        expert = (3 if self.mlp_gated else 2) * self.d_model * self.d_ff
        inactive = self.n_layers * (mo.num_experts - mo.top_k) * expert
        return self.param_count() - int(inactive)


# ---------------------------------------------------------------------------
# input shapes (assigned to every LM arch)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs; returns (ok, reason-if-skipped)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 500k dense decode skipped "
                       "per brief (needs sub-quadratic attention)")
    return True, ""


# ---------------------------------------------------------------------------
# registry

_REGISTRY: Dict[str, Any] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # late import of the module defining it
        import importlib
        mod = name.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> Tuple[str, ...]:
    # the ten assigned architectures
    return (
        "whisper-base", "pixtral-12b", "granite-8b", "granite-20b",
        "starcoder2-15b", "minicpm3-4b", "grok-1-314b", "deepseek-moe-16b",
        "rwkv6-7b", "zamba2-1.2b",
    )


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw: Dict[str, Any] = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=min(4, cfg.n_kv_heads),
        head_dim=16, d_ff=128, vocab_size=256,
        mesh_plan=dataclasses.replace(cfg.mesh_plan, pipe=1, tensor=1,
                                      num_microbatches=2, fsdp=False),
        remat="none",
    )
    if cfg.is_encdec:
        kw["n_enc_layers"] = 2
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2,
            num_shared=min(1, cfg.moe.num_shared))
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16,
            shared_attn_every=(2 if cfg.ssm.shared_attn_every else 0))
    return cfg.replace(**kw)
