"""deepseek-moe-16b [moe]: fine-grained 64 routed top-6 + 2 shared experts.

28L, d_model=2048, 16H (kv=16 = MHA), d_ff=1408 per expert, vocab=102400.
[arXiv:2401.06066]
"""
from repro.configs.base import ArchConfig, MeshPlan, MoEConfig, register


@register("deepseek-moe-16b")
def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b", family="moe", source="arXiv:2401.06066",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1408, vocab_size=102400,
        mlp_gated=True, norm="rmsnorm", pos_embed="rope",
        moe=MoEConfig(num_experts=64, num_shared=2, top_k=6,
                      capacity_factor=1.25),
        mesh_plan=MeshPlan(pipe=2, tensor=8, num_microbatches=4),
        supports_long_context=False,
    )
