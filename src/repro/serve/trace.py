"""Seeded request traces for serving benchmarks and tests.

Arrivals are Poisson in *round* units: inter-arrival gaps are drawn
from an exponential with mean ``1/rate`` and accumulated, so the same
``(n_requests, rate, seed)`` triple always produces the same trace —
the determinism tests and the CI serve-smoke job depend on that.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass(frozen=True)
class Request:
    """One inference request: ``prompt`` token ids arrive at round
    ``arrival``; the engine emits exactly ``gen_len`` tokens (greedy),
    the first from the prefill itself."""
    rid: int
    arrival: int
    prompt: Tuple[int, ...]
    gen_len: int


def poisson_trace(n_requests: int = 32, *, rate: float = 1.0,
                  seed: int = 0, prompt_lens: Tuple[int, int] = (2, 12),
                  gen_lens: Tuple[int, int] = (1, 8),
                  vocab: int = 256) -> List[Request]:
    """A seeded Poisson arrival trace with mixed prompt/gen lengths.

    ``rate`` is requests per round; ``prompt_lens`` / ``gen_lens`` are
    inclusive ranges.  Token ids are uniform over ``[0, vocab)``."""
    if n_requests < 1:
        raise ValueError(f"need n_requests >= 1, got {n_requests}")
    if rate <= 0:
        raise ValueError(f"need rate > 0, got {rate}")
    rng = np.random.default_rng(seed)
    out: List[Request] = []
    t = 0.0
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        p = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        g = int(rng.integers(gen_lens[0], gen_lens[1] + 1))
        prompt = tuple(int(x) for x in rng.integers(0, vocab, p))
        out.append(Request(rid=rid, arrival=int(t), prompt=prompt,
                           gen_len=g))
    return out
