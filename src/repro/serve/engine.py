"""The pipelined serving engine: schedule-IR rounds over paged KV.

One serving round executes a compiled artifact from
``planner/schedule_ir`` — the :class:`ServeTable` interpreted by a
``lax.scan``/``lax.switch`` loop (scan backend, SPMD) or the
:class:`ServeStreams` run tick-by-tick inside one ``shard_map`` over
the ``pipe`` mesh axis with both hidden payloads crossing the stage
cuts via ``ppermute`` (mpmd backend) — exactly the execution model of
the PR 5/PR 7 training interpreters, minus the backward half.

KV state is paged per stage: chunk ``q`` owns a buffer of
``n_pages + 1`` pages (the last is the trash page idle slots compute
into), each page one request's cache slice for that chunk's layers,
``page_seq`` positions deep.  A request occupies the *same* page index
on every stage (see ``scheduler``), which is what makes the elastic
repartition in :meth:`ServeEngine.restate` a concat-and-resplit along
the layer axis.

Both backends share the same per-chunk compute (:func:`_decode_chunk`
/ :func:`_prefill_chunk` over ``Model.stage_decode`` /
``Model.stage_prefill``), so their emitted tokens are
bitwise-identical by construction; prefill runs a whole prompt chunk
per dispatch (one XLA call per chunk, not per token), from a *fresh*
init page so a recycled page never leaks its previous request's state.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.planner import schedule_ir as sir
from repro.serve.scheduler import ContinuousBatcher, admissible

SERVE_BACKENDS = ("scan", "mpmd")


def _unsupported_arch(model, what: str) -> NotImplementedError:
    kind = "encoder-decoder" if model.cfg.is_encdec else "hybrid"
    return NotImplementedError(
        f"{what} does not support {kind} models ({model.cfg.name}): "
        f"their decode state is not a per-layer scan the stage split "
        f"can page; serve them with SimpleEngine (launch/serve.py "
        f"--engine simple)")


# ===========================================================================
# paged KV caches
# ===========================================================================


def _split_layer_tree(layers, sizes: Sequence[int]):
    """Slice a full-depth cache ``layers`` tree into per-chunk trees
    along the leading layer axis."""
    out, lo = [], 0
    for L in sizes:
        out.append(jax.tree.map(lambda a: a[lo:lo + L], layers))
        lo += L
    return out


def chunk_page_caches(model, sizes: Sequence[int], n_pages: int,
                      page_seq: int):
    """Build per-chunk paged KV buffers and the matching fresh init
    slices.

    Returns ``(caches, init_pages)``: ``caches[q]`` is a
    ``{"layers": ...}`` tree whose leaves are
    ``[n_pages + 1, sizes[q], 1, ...]`` — a leading page axis over
    chunk ``q``'s slice of ``Model.init_cache(1, page_seq)``, every
    page (including the trash page, index ``n_pages``) starting at the
    init state; ``init_pages[q]`` is the unpaged ``[sizes[q], 1, ...]``
    init slice prefill restarts from."""
    full = model.init_cache(1, page_seq, stage_sizes=tuple(sizes))
    slices = _split_layer_tree(full["layers"], sizes)
    caches = tuple(
        {"layers": jax.tree.map(
            lambda a: jnp.zeros((n_pages + 1,) + a.shape, a.dtype) + a,
            sl)}
        for sl in slices)
    init_pages = tuple({"layers": sl} for sl in slices)
    return caches, init_pages


def _decode_chunk(model, stage_params, cache_q, x, pos, pages):
    """One chunk of the decode wave: gather each request's page, run
    the stage's single-token decode (vmapped — attention needs a
    scalar position per request), scatter the pages back.

    x [R, 1, d], pos [R], pages [R] -> (y [R, 1, d], new cache)."""
    gathered = jax.tree.map(lambda a: a[pages], cache_q)

    def one(req_cache, xr, pr):
        y, nc = model.stage_decode(stage_params, req_cache, xr[None], pr)
        return y[0], nc

    ys, new = jax.vmap(one)(gathered, x, pos)
    new_cache = jax.tree.map(
        lambda leaf, n: leaf.at[pages].set(n.astype(leaf.dtype)),
        cache_q, new)
    return ys, new_cache


def _prefill_chunk(model, stage_params, init_page, cache_q, x_seq,
                   n_valid, page):
    """One chunk of a prefill lane: run the whole prompt through the
    stage in one masked scan, starting from the *fresh* init page (so
    a recycled page cannot leak its previous request's state), and
    scatter the result into the lane's page.

    x_seq [1, P, d] -> (y_seq [1, P, d], new cache)."""
    y_seq, new_page = model.stage_prefill(stage_params, init_page,
                                          x_seq, n_valid)
    new_cache = jax.tree.map(
        lambda leaf, n: leaf.at[page].set(n.astype(leaf.dtype)),
        cache_q, new_page)
    return y_seq, new_cache


# ===========================================================================
# scan backend: interpret the ServeTable (SPMD twin of the PR 5 loop)
# ===========================================================================


def make_scan_round(model, table: sir.ServeTable, init_pages):
    """Jittable round body interpreting ``table`` row by row:
    ``lax.scan`` over the dense rows, ``lax.switch`` into one arm per
    (opcode, chunk) branch, hidden states flowing through the two
    register-allocated slot pools.  Donate the caches argument when
    jitting."""
    C, F = table.n_chunks, table.max_prefill
    nd, npf = max(table.n_dec_slots, 1), max(table.n_pf_slots, 1)
    rows = jnp.asarray(np.asarray(table.rows))
    vocab = model.cfg.vocab_size
    dt = jnp.dtype(model.cfg.compute_dtype)

    def round_fn(chunks, outer, caches, dec_tokens, dec_pos, dec_pages,
                 pf_tokens, pf_len, pf_pages):
        R = dec_tokens.shape[0]
        P = pf_tokens.shape[1]
        d = model.cfg.d_model

        def with_chunk(caches, q, new_c):
            return tuple(new_c if i == q else c
                         for i, c in enumerate(caches))

        def mk_dec(q):
            def br(carry, row):
                dec_pool, pf_pool, caches, dec_next, pf_next = carry
                if q == 0:
                    x = model.decode_embed(outer, dec_tokens[:, None],
                                           dec_pos[:, None])
                else:
                    x = jax.lax.dynamic_index_in_dim(
                        dec_pool, row[sir.SCOL_A], 0, keepdims=False)
                y, new_c = _decode_chunk(model, chunks[q], caches[q],
                                         x, dec_pos, dec_pages)
                caches = with_chunk(caches, q, new_c)
                if q == C - 1:
                    dec_next = jnp.argmax(
                        model.logits(outer, y)[:, 0, :vocab],
                        -1).astype(jnp.int32)
                else:
                    dec_pool = jax.lax.dynamic_update_index_in_dim(
                        dec_pool, y.astype(dt), row[sir.SCOL_B], 0)
                return (dec_pool, pf_pool, caches, dec_next, pf_next)
            return br

        def mk_pf(q):
            def br(carry, row):
                dec_pool, pf_pool, caches, dec_next, pf_next = carry
                j = row[sir.SCOL_MB]
                n_valid = jax.lax.dynamic_index_in_dim(
                    pf_len, j, 0, keepdims=False)
                page = jax.lax.dynamic_index_in_dim(
                    pf_pages, j, 0, keepdims=False)
                if q == 0:
                    toks = jax.lax.dynamic_index_in_dim(
                        pf_tokens, j, 0, keepdims=False)
                    x = model.decode_embed(
                        outer, toks[None, :],
                        jnp.arange(P, dtype=jnp.int32)[None, :])
                else:
                    x = jax.lax.dynamic_index_in_dim(
                        pf_pool, row[sir.SCOL_A], 0, keepdims=False)
                y_seq, new_c = _prefill_chunk(
                    model, chunks[q], init_pages[q], caches[q], x,
                    n_valid, page)
                caches = with_chunk(caches, q, new_c)
                if q == C - 1:
                    idx = jnp.clip(n_valid - 1, 0, P - 1)
                    h = jax.lax.dynamic_slice_in_dim(y_seq, idx, 1, 1)
                    tok = jnp.argmax(
                        model.logits(outer, h)[0, 0, :vocab]
                    ).astype(jnp.int32)
                    pf_next = jax.lax.dynamic_update_index_in_dim(
                        pf_next, tok, j, 0)
                else:
                    pf_pool = jax.lax.dynamic_update_index_in_dim(
                        pf_pool, y_seq.astype(dt), row[sir.SCOL_B], 0)
                return (dec_pool, pf_pool, caches, dec_next, pf_next)
            return br

        arms = [mk_dec(q) if kind == sir.DECODE else mk_pf(q)
                for kind, q in table.branches]

        def step(carry, row):
            return jax.lax.switch(row[sir.SCOL_BRANCH], arms, carry,
                                  row), None

        carry = (jnp.zeros((nd, R, 1, d), dt),
                 jnp.zeros((npf, 1, P, d), dt),
                 caches,
                 jnp.zeros((R,), jnp.int32),
                 jnp.zeros((max(F, 1),), jnp.int32))
        carry, _ = jax.lax.scan(step, carry, rows)
        return carry[3], carry[4], carry[2]

    return round_fn


# ===========================================================================
# mpmd backend: run the ServeStreams inside shard_map (PR 7's twin)
# ===========================================================================


def pack_serve_caches(caches, sizes: Sequence[int]):
    """Per-chunk paged caches -> the dense stage-local layout: every
    leaf ``[n_pages + 1, L_q, ...]`` zero-padded to ``Lmax`` layers and
    stacked to ``[S, n_pages + 1, Lmax, ...]``; sharding dim 0 with
    ``PartitionSpec('pipe')`` pins chunk ``q``'s pages to device
    ``q``."""
    Lmax = max(sizes)

    def leaf(*xs):
        padded = []
        for x in xs:
            if x.shape[1] < Lmax:
                pad = [(0, 0)] * x.ndim
                pad[1] = (0, Lmax - x.shape[1])
                x = jnp.pad(x, pad)
            padded.append(x)
        return jnp.stack(padded, 0)

    return {"layers": jax.tree.map(leaf,
                                   *[c["layers"] for c in caches])}


def unpack_serve_caches(packed, sizes: Sequence[int]):
    """Inverse of :func:`pack_serve_caches` (padding layers dropped)."""
    return tuple(
        {"layers": jax.tree.map(lambda a: a[q, :, :sizes[q]],
                                packed["layers"])}
        for q in range(len(sizes)))


def make_mpmd_round(model, streams: sir.ServeStreams, init_pages,
                    sizes: Sequence[int], mesh):
    """Round body for the MPMD backend: one ``shard_map`` over the
    ``pipe`` axis; each device scans its own tick stream, both payload
    rings (decode [R, 1, d] and prefill [1, P, d] hiddens) run a
    ``ppermute`` every tick, incoming payloads park in the row's
    receive slot (-1 -> the trash slot).  Emitted tokens surface on
    the last device; index ``[S - 1]`` of the pipe-stacked outputs."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P_

    C, F, S = streams.n_chunks, streams.max_prefill, streams.n_devices
    nd, npf = streams.n_dec_slots, streams.n_pf_slots
    Lmax = max(sizes)
    rows = jnp.asarray(np.asarray(streams.rows))   # [T, S, SDN_COLS]
    fwd_perm = [(i, (i + 1) % S) for i in range(S)]
    vocab = model.cfg.vocab_size
    dt = jnp.dtype(model.cfg.compute_dtype)
    # padded init pages: arm q slices its own [:sizes[q]] rows back out
    init_pad = tuple(
        {"layers": jax.tree.map(
            lambda a: jnp.concatenate(
                [a, jnp.zeros((Lmax - a.shape[0],) + a.shape[1:],
                              a.dtype)], 0) if a.shape[0] < Lmax else a,
            ip["layers"])}
        for ip in init_pages)

    def round_body(pp_l, outer, pc_l, rows_l, dec_tokens, dec_pos,
                   dec_pages, pf_tokens, pf_len, pf_pages):
        R = dec_tokens.shape[0]
        P = pf_tokens.shape[1]
        d = model.cfg.d_model
        zeros_d = lambda: jnp.zeros((R, 1, d), dt)
        zeros_p = lambda: jnp.zeros((1, P, d), dt)

        def chunk_of(q):
            return {"layers": jax.tree.map(
                lambda a: a[0, 0, :sizes[q]], pp_l["layers"])}

        def cache_of(pc, q):
            return {"layers": jax.tree.map(
                lambda a: a[0][:, :sizes[q]], pc["layers"])}

        def cache_set(pc, q, new_c):
            return {"layers": jax.tree.map(
                lambda a, n: a.at[0, :, :sizes[q]].set(
                    n.astype(a.dtype)),
                pc["layers"], new_c["layers"])}

        def mk_dec(q):
            def br(carry, row):
                dec_pool, pf_pool, pc, dec_next, pf_next = carry
                if q == 0:
                    x = model.decode_embed(outer, dec_tokens[:, None],
                                           dec_pos[:, None])
                else:
                    x = jax.lax.dynamic_index_in_dim(
                        dec_pool, row[sir.SDCOL_A], 0, keepdims=False)
                y, new_c = _decode_chunk(model, chunk_of(q),
                                         cache_of(pc, q), x, dec_pos,
                                         dec_pages)
                pc = cache_set(pc, q, new_c)
                if q == C - 1:
                    dec_next = jnp.argmax(
                        model.logits(outer, y)[:, 0, :vocab],
                        -1).astype(jnp.int32)
                    sd = zeros_d()
                else:
                    sd = y.astype(dt)
                return (dec_pool, pf_pool, pc, dec_next, pf_next), \
                    sd, zeros_p()
            return br

        def mk_pf(q):
            def br(carry, row):
                dec_pool, pf_pool, pc, dec_next, pf_next = carry
                j = row[sir.SDCOL_MB]
                n_valid = jax.lax.dynamic_index_in_dim(
                    pf_len, j, 0, keepdims=False)
                page = jax.lax.dynamic_index_in_dim(
                    pf_pages, j, 0, keepdims=False)
                if q == 0:
                    toks = jax.lax.dynamic_index_in_dim(
                        pf_tokens, j, 0, keepdims=False)
                    x = model.decode_embed(
                        outer, toks[None, :],
                        jnp.arange(P, dtype=jnp.int32)[None, :])
                else:
                    x = jax.lax.dynamic_index_in_dim(
                        pf_pool, row[sir.SDCOL_A], 0, keepdims=False)
                ipq = {"layers": jax.tree.map(
                    lambda a: a[:sizes[q]], init_pad[q]["layers"])}
                y_seq, new_c = _prefill_chunk(
                    model, chunk_of(q), ipq, cache_of(pc, q), x,
                    n_valid, page)
                pc = cache_set(pc, q, new_c)
                if q == C - 1:
                    idx = jnp.clip(n_valid - 1, 0, P - 1)
                    h = jax.lax.dynamic_slice_in_dim(y_seq, idx, 1, 1)
                    tok = jnp.argmax(
                        model.logits(outer, h)[0, 0, :vocab]
                    ).astype(jnp.int32)
                    pf_next = jax.lax.dynamic_update_index_in_dim(
                        pf_next, tok, j, 0)
                    sp = zeros_p()
                else:
                    sp = y_seq.astype(dt)
                return (dec_pool, pf_pool, pc, dec_next, pf_next), \
                    zeros_d(), sp
            return br

        arms = [mk_dec(q) if kind == sir.DECODE else mk_pf(q)
                for kind, q in streams.branches]
        arms.append(lambda carry, row: (carry, zeros_d(), zeros_p()))

        def tick(carry, row_t):
            row = row_t[0]
            carry, sd, sp = jax.lax.switch(
                row[sir.SDCOL_BRANCH], arms, carry, row)
            rd = jax.lax.ppermute(sd, "pipe", fwd_perm) if S > 1 else sd
            rp = jax.lax.ppermute(sp, "pipe", fwd_perm) if S > 1 else sp
            dec_pool, pf_pool, pc, dec_next, pf_next = carry
            dec_pool = jax.lax.dynamic_update_index_in_dim(
                dec_pool, rd, jnp.where(row[sir.SDCOL_RECV_D] >= 0,
                                        row[sir.SDCOL_RECV_D], nd), 0)
            pf_pool = jax.lax.dynamic_update_index_in_dim(
                pf_pool, rp, jnp.where(row[sir.SDCOL_RECV_P] >= 0,
                                       row[sir.SDCOL_RECV_P], npf), 0)
            return (dec_pool, pf_pool, pc, dec_next, pf_next), None

        carry = (jnp.zeros((nd + 1, R, 1, d), dt),
                 jnp.zeros((npf + 1, 1, P, d), dt),
                 pc_l,
                 jnp.zeros((R,), jnp.int32),
                 jnp.zeros((max(F, 1),), jnp.int32))
        (_dp, _pp, pc_l, dec_next, pf_next), _ = jax.lax.scan(
            tick, carry, rows_l)
        return dec_next[None], pf_next[None], pc_l

    run = shard_map(
        round_body, mesh=mesh,
        in_specs=(P_(None, "pipe"), P_(), P_("pipe"),
                  P_(None, "pipe", None), P_(), P_(), P_(), P_(), P_(),
                  P_()),
        out_specs=(P_("pipe"), P_("pipe"), P_("pipe")),
        check_rep=False)

    def round_fn(packed_params, outer, packed_caches, dec_tokens,
                 dec_pos, dec_pages, pf_tokens, pf_len, pf_pages):
        return run(packed_params, outer, packed_caches, rows,
                   dec_tokens, dec_pos, dec_pages, pf_tokens, pf_len,
                   pf_pages)

    return round_fn


# ===========================================================================
# engines
# ===========================================================================


class ServeEngine:
    """Continuous-batching inference through the schedule-IR serving
    round.  ``backend`` picks the scan (SPMD) or mpmd (shard_map)
    execution of the *same* per-chunk compute; emitted tokens are
    bitwise-identical across backends for a given trace."""

    def __init__(self, model, params, splan, *, backend: str = "scan",
                 mesh=None, registry=None, verify: bool = True):
        if backend not in SERVE_BACKENDS:
            raise ValueError(f"unknown serve backend {backend!r}; "
                             f"choose from {SERVE_BACKENDS}")
        if model.cfg.is_encdec or model.hybrid:
            raise _unsupported_arch(model, "the pipelined ServeEngine")
        self.model, self.splan, self.backend = model, splan, backend
        self.registry = registry
        self.verify = verify
        if verify:
            splan.verify(device_streams=(backend == "mpmd"))
        self._outer = params["outer"]
        sizes = splan.stage_sizes
        self._chunks = model.partition_stage_params(
            params["stages"], sizes, n_chunks=len(sizes))
        self._mesh = mesh
        self._build(sizes)

    # ------------------------------------------------------------- lowering
    def _build(self, sizes: Tuple[int, ...]) -> None:
        splan, model = self.splan, self.model
        self._sizes = tuple(sizes)
        caches, init_pages = chunk_page_caches(
            model, sizes, splan.n_pages, splan.page_seq)
        if self.backend == "scan":
            table = splan.serve_table()
            fn = make_scan_round(model, table, init_pages)
            self._fn = jax.jit(fn, donate_argnums=(2,))
            self._caches = caches
            self._params_arg = self._chunks
        else:
            from repro.runtime import sharding as rsh
            from repro.models.model import pack_chunk_params
            streams = splan.serve_streams()
            S = streams.n_devices
            if self._mesh is None:
                self._mesh = rsh.mpmd_pipe_mesh(S)
            if "pipe" not in self._mesh.shape \
                    or self._mesh.shape["pipe"] != S:
                raise ValueError(
                    f"mpmd serving needs a mesh with a 'pipe' axis of "
                    f"size {S}, got {dict(self._mesh.shape)}")
            packed, _ = pack_chunk_params(self._chunks, S)
            fn = make_mpmd_round(model, streams, init_pages, sizes,
                                 self._mesh)
            self._fn = jax.jit(fn, donate_argnums=(2,))
            self._caches = pack_serve_caches(caches, sizes)
            self._params_arg = packed
        self._warm = False

    def _round(self, batch: Dict[str, np.ndarray]):
        args = (batch["dec_tokens"], batch["dec_pos"],
                batch["dec_pages"], batch["pf_tokens"], batch["pf_len"],
                batch["pf_pages"])
        dec_next, pf_next, self._caches = self._fn(
            self._params_arg, self._outer, self._caches, *args)
        if self.backend == "mpmd":
            S = self.splan.n_stages
            dec_next, pf_next = dec_next[S - 1], pf_next[S - 1]
        return dec_next, pf_next

    def _warm_up(self) -> float:
        """Compile the round on throwaway caches (the caches argument
        is donated) so steady-state latencies exclude XLA compilation
        — PR 7's compile-time exclusion, applied to serving."""
        splan = self.splan
        R, F = splan.n_slots, max(splan.max_prefill, 1)
        P = splan.prompt_budget
        zero = {"dec_tokens": np.zeros((R,), np.int32),
                "dec_pos": np.zeros((R,), np.int32),
                "dec_pages": np.full((R,), splan.n_pages, np.int32),
                "pf_tokens": np.zeros((F, P), np.int32),
                "pf_len": np.zeros((F,), np.int32),
                "pf_pages": np.full((F,), splan.n_pages, np.int32)}
        real = self._caches
        self._caches = jax.tree.map(jnp.array, real)   # throwaway copy
        t0 = time.time()
        out = self._round(zero)
        jax.block_until_ready(out[0])
        compile_s = time.time() - t0
        self._caches = real
        self._warm = True
        if self.registry is not None:
            self.registry.gauge("serve/compile_s").set(compile_s)
        return compile_s

    # ------------------------------------------------------------ execution
    def run(self, requests, *, max_rounds: Optional[int] = None
            ) -> Dict[int, tuple]:
        """Drive the trace to completion; returns ``{rid: tokens}``
        (rejected requests map to ``()``).  The scheduler event log of
        the last run is kept on ``self.last_events`` for
        ``verify_request_trace``."""
        if self.splan.max_prefill < 1 and requests:
            raise ValueError("max_prefill=0 can never admit a request")
        if not self._warm:
            self._warm_up()
        sched = ContinuousBatcher(self.splan, requests,
                                  registry=self.registry)
        limit = max_rounds if max_rounds is not None else (
            max((q.arrival for q in requests), default=0)
            + sum(max(q.gen_len, 1) for q in requests) + len(requests)
            + 8)
        hist = (self.registry.histogram("serve/token_ms")
                if self.registry is not None else None)
        r, n_tokens, busy_s = 0, 0, 0.0
        while sched.active:
            if r > limit:
                raise RuntimeError(
                    f"serving exceeded {limit} rounds with "
                    f"{len(sched.live)} live and {len(sched.queue)} "
                    f"queued requests — admission is stuck")
            batch = sched.poll(r)
            if not sched.n_round_tokens():
                nxt = sched.next_arrival()
                r = max(r + 1, nxt if nxt is not None else r + 1)
                continue
            t0 = time.time()
            dec_next, pf_next = self._round(batch)
            jax.block_until_ready(dec_next)
            dt_s = time.time() - t0
            toks = sched.n_round_tokens()
            busy_s += dt_s
            n_tokens += toks
            if hist is not None:
                for _ in range(toks):
                    hist.observe(dt_s * 1e3)
            sched.commit(r, dec_next, pf_next)
            r += 1
        self.last_events: List[Dict[str, Any]] = sched.events
        if self.registry is not None and busy_s > 0:
            self.registry.gauge("serve/decode_tok_per_s").set(
                n_tokens / busy_s)
        return dict(sched.results)

    # -------------------------------------------------------------- elastic
    def restate(self, new_splan) -> None:
        """Mid-run repartition onto ``new_splan``'s stage split: stage
        weights regroup by flat layer order and the paged KV buffers
        concat-and-resplit along the layer axis, so every request's
        state survives at the same page index and the emitted tokens
        are unchanged.  Page geometry must match."""
        old = self.splan
        for f in ("n_slots", "max_prefill", "prompt_budget", "n_pages",
                  "page_seq"):
            if getattr(old, f) != getattr(new_splan, f):
                raise ValueError(
                    f"restate cannot change {f} "
                    f"({getattr(old, f)} -> {getattr(new_splan, f)}): "
                    f"page geometry is carried state")
        if self.backend == "mpmd":
            chunk_caches = unpack_serve_caches(self._caches,
                                              self._sizes)
        else:
            chunk_caches = self._caches
        full = jax.tree.map(lambda *xs: jnp.concatenate(xs, 1),
                            *[c["layers"] for c in chunk_caches])
        # pull off the old mesh: the new round fn may shard over a
        # different device set, and donated inputs committed to the old
        # one would be rejected at the jit boundary
        full = jax.device_get(full)
        new_sizes = new_splan.stage_sizes
        self._chunks = self.model.partition_stage_params(
            self._chunks, new_sizes, n_chunks=len(new_sizes))
        self.splan = new_splan
        if self.verify:
            new_splan.verify(device_streams=(self.backend == "mpmd"))
        self._mesh = None if self.backend == "mpmd" else self._mesh
        self._build(new_sizes)
        # overwrite the freshly-initialized pages with the carried
        # state (layer axis is 1 — axis 0 is the page axis)
        carried, lo = [], 0
        for L in new_sizes:
            carried.append({"layers": jax.tree.map(
                lambda a, lo=lo, L=L: a[:, lo:lo + L], full)})
            lo += L
        carried = tuple(carried)
        if self.backend == "mpmd":
            self._caches = pack_serve_caches(carried, new_sizes)
        else:
            self._caches = carried
        if self.registry is not None:
            self.registry.emit("serve_restate",
                               sizes=list(new_sizes),
                               backend=self.backend)


class SimpleEngine:
    """Whole-model reference engine: each request prefills and decodes
    independently through ``Model.decode_step`` on a fresh cache.  The
    golden reference the pipelined engine is tested against, and the
    serving fallback for hybrid/enc-dec archs ``stage_decode`` gates
    out.  Applies the same admission budgets, so results line up
    request-for-request.

    Prefill consumes the *whole* prompt in one jitted call — a masked
    ``lax.scan`` of ``decode_step`` over the padded prompt buffer
    (bitwise the old token-by-token stepping, minus per-token
    dispatch), compiled once for all prompt lengths."""

    def __init__(self, model, params, splan, *, registry=None):
        self.model, self.params, self.splan = model, params, splan
        self.registry = registry
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(self._prefill_fn)
        self._warm = False

    def _prefill_fn(self, params, cache, toks, n_valid):
        """toks [1, P] zero-padded -> (last valid logits, filled
        cache); positions >= n_valid leave cache and logits
        untouched."""
        model = self.model
        tok0 = jax.lax.dynamic_slice_in_dim(toks, 0, 1, 1)
        logits, cache = model.decode_step(params, cache, tok0,
                                          jnp.asarray(0, jnp.int32))

        def body(carry, i):
            cache, logits = carry
            ti = jax.lax.dynamic_slice_in_dim(toks, i, 1, 1)
            lg, nc = model.decode_step(params, cache, ti, i)
            keep = i < n_valid
            cache = jax.tree.map(
                lambda o, n: jnp.where(keep, n.astype(o.dtype), o),
                cache, nc)
            logits = jnp.where(keep, lg.astype(logits.dtype), logits)
            return (cache, logits), None

        (cache, logits), _ = jax.lax.scan(
            body, (cache, logits),
            jnp.arange(1, toks.shape[1], dtype=jnp.int32))
        return logits, cache

    def _warm_up(self) -> None:
        """Compile prefill + decode on a throwaway cache so reported
        latencies exclude XLA compilation."""
        model, splan = self.model, self.splan
        t0 = time.time()
        warm = model.init_cache(1, splan.page_seq)
        toks = jnp.zeros((1, splan.prompt_budget), jnp.int32)
        logits, warm = self._prefill(self.params, warm, toks,
                                     jnp.asarray(1, jnp.int32))
        logits, warm = self._decode(self.params, warm, toks[:, :1],
                                    jnp.asarray(1, jnp.int32))
        jax.block_until_ready(logits)
        del warm
        self._warm = True
        if self.registry is not None:
            self.registry.gauge("serve/compile_s").set(
                time.time() - t0)

    def run(self, requests, *, max_rounds: Optional[int] = None
            ) -> Dict[int, tuple]:
        model, params, splan = self.model, self.params, self.splan
        vocab = model.cfg.vocab_size
        P = splan.prompt_budget
        if not self._warm:
            self._warm_up()
        hist = (self.registry.histogram("serve/token_ms")
                if self.registry is not None else None)
        results: Dict[int, tuple] = {}
        for req in sorted(requests, key=lambda q: (q.arrival, q.rid)):
            if not admissible(req, splan):
                results[req.rid] = ()
                continue
            cache = model.init_cache(1, splan.page_seq)
            toks_in = np.zeros((1, P), np.int32)
            toks_in[0, :len(req.prompt)] = req.prompt
            t0 = time.time()
            logits, cache = self._prefill(
                params, cache, jnp.asarray(toks_in),
                jnp.asarray(len(req.prompt), jnp.int32))
            jax.block_until_ready(logits)
            if hist is not None:
                hist.observe((time.time() - t0) * 1e3)
            toks = [int(jnp.argmax(logits[0, -1, :vocab]))]
            pos = len(req.prompt)
            while len(toks) < req.gen_len:
                t0 = time.time()
                logits, cache = self._decode(
                    params, cache,
                    jnp.asarray([[toks[-1]]], jnp.int32),
                    jnp.asarray(pos, jnp.int32))
                toks.append(int(jnp.argmax(logits[0, -1, :vocab])))
                pos += 1
                if hist is not None:
                    hist.observe((time.time() - t0) * 1e3)
            results[req.rid] = tuple(toks)
            if self.registry is not None:
                self.registry.emit("serve_request", rid=req.rid,
                                   prompt_len=len(req.prompt),
                                   gen=req.gen_len)
        return results
