"""Continuous-batching scheduler: request admission and eviction over
request slots and per-stage KV pages.

The scheduler is pure host-side bookkeeping — its decisions depend
only on the arrival trace and the tokens the rounds emit, never on
device timing, so the same trace + the same emitted tokens produce the
same admissions on every backend (the cross-backend bitwise test rests
on this).  Every decision is appended to ``events``, the log
``planner.verify.verify_request_trace`` checks against the serving
invariants (page lifetime == request lifetime, one decode per live
request per round, no slot sharing).

KV pages are allocated as one index per request, valid on *every*
stage: stage ``q`` holds a page buffer for its own layer slice, and a
request's state lives at the same page index in all of them.  Aligned
indices are what keep an elastic repartition trivial — concatenating
the per-stage page buffers along the layer axis and resplitting by the
new partition moves every layer's state without touching page ids.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Any, Dict, List, Optional

import numpy as np

from repro.serve.trace import Request


def admissible(req: Request, splan) -> bool:
    """Whether a request fits the plan's static budgets: a non-empty
    prompt within ``prompt_budget``, at least one generated token, and
    prompt + generation within one ``page_seq`` KV page."""
    p = len(req.prompt)
    return (1 <= p <= splan.prompt_budget and req.gen_len >= 1
            and p + req.gen_len <= splan.page_seq)


class ContinuousBatcher:
    """FIFO continuous batching over ``n_slots`` request slots.

    Per round ``r``, :meth:`poll` builds the dense arrays one serving
    round consumes — every live slot decodes one token; up to
    ``max_prefill`` queued requests whose ``arrival <= r`` are admitted
    into free slots/pages as prefill lanes (head-of-line blocking: a
    request that cannot be admitted blocks the queue, preserving FIFO
    order) — and :meth:`commit` folds the round's emitted tokens back
    in, evicting requests that reached ``gen_len``.

    Inadmissible requests (see :func:`admissible`) are rejected
    permanently at the head of the queue with an empty result.
    """

    def __init__(self, splan, requests, *, registry=None):
        self.splan = splan
        self.n_slots = splan.n_slots
        self.max_prefill = splan.max_prefill
        self.prompt_budget = splan.prompt_budget
        self.n_pages = splan.n_pages
        self.n_stages = splan.n_stages
        self.queue = deque(sorted(requests,
                                  key=lambda q: (q.arrival, q.rid)))
        self.free_slots = list(range(self.n_slots))
        self.free_pages = list(range(self.n_pages))
        heapq.heapify(self.free_slots)
        heapq.heapify(self.free_pages)
        self.live: Dict[int, Dict[str, Any]] = {}      # slot -> record
        self.results: Dict[int, tuple] = {}
        self.events: List[Dict[str, Any]] = []
        self.registry = registry
        self._dec_slots: List[int] = []
        self._pf_lanes: List[tuple] = []               # (lane, slot)

    # ------------------------------------------------------------------ state
    @property
    def active(self) -> bool:
        return bool(self.live) or bool(self.queue)

    def next_arrival(self) -> Optional[int]:
        return self.queue[0].arrival if self.queue else None

    def _log(self, **ev) -> None:
        self.events.append(ev)
        if self.registry is not None:
            self.registry.emit("serve_sched", **ev)

    # ------------------------------------------------------------------ round
    def poll(self, r: int) -> Dict[str, np.ndarray]:
        """Arrays for round ``r``: the decode wave over live slots
        (dead slots point at the trash page ``n_pages``) plus newly
        admitted prefill lanes (``pf_len == 0`` marks an idle lane)."""
        R, F, P = self.n_slots, max(self.max_prefill, 1), \
            self.prompt_budget
        dec_tokens = np.zeros((R,), np.int32)
        dec_pos = np.zeros((R,), np.int32)
        dec_pages = np.full((R,), self.n_pages, np.int32)
        pf_tokens = np.zeros((F, P), np.int32)
        pf_len = np.zeros((F,), np.int32)
        pf_pages = np.full((F,), self.n_pages, np.int32)

        self._dec_slots = sorted(self.live)
        for slot in self._dec_slots:
            rec = self.live[slot]
            dec_tokens[slot] = rec["tokens"][-1]
            dec_pos[slot] = rec["prompt_len"] + len(rec["tokens"]) - 1
            dec_pages[slot] = rec["page"]
            self._log(ev="decode", round=r, rid=rec["rid"], slot=slot)

        self._pf_lanes = []
        lane = 0
        while self.queue and lane < self.max_prefill:
            req = self.queue[0]
            if req.arrival > r:
                break
            if not admissible(req, self.splan):
                self.queue.popleft()
                self.results[req.rid] = ()
                self._log(ev="reject", round=r, rid=req.rid,
                          prompt_len=len(req.prompt),
                          gen_len=req.gen_len)
                continue
            if not self.free_slots or not self.free_pages:
                break                      # head-of-line blocking (FIFO)
            self.queue.popleft()
            slot = heapq.heappop(self.free_slots)
            page = heapq.heappop(self.free_pages)
            self.live[slot] = {"rid": req.rid, "page": page,
                               "prompt_len": len(req.prompt),
                               "gen": req.gen_len, "tokens": []}
            p = len(req.prompt)
            pf_tokens[lane, :p] = req.prompt
            pf_len[lane] = p
            pf_pages[lane] = page
            self._pf_lanes.append((lane, slot))
            self._log(ev="admit", round=r, rid=req.rid, slot=slot,
                      pages=[page] * self.n_stages, prompt_len=p,
                      gen_len=req.gen_len)
            lane += 1
        return {"dec_tokens": dec_tokens, "dec_pos": dec_pos,
                "dec_pages": dec_pages, "pf_tokens": pf_tokens,
                "pf_len": pf_len, "pf_pages": pf_pages}

    def n_round_tokens(self) -> int:
        """Tokens the polled round will emit (one per live slot, one
        per admitted lane)."""
        return len(self._dec_slots) + len(self._pf_lanes)

    def commit(self, r: int, dec_next, pf_next) -> None:
        """Fold round ``r``'s emitted tokens back in: live slots append
        their decode token, admitted lanes their first (prefill) token;
        requests reaching ``gen_len`` are evicted and their slot and
        page return to the free heaps."""
        dec_next = np.asarray(dec_next)
        pf_next = np.asarray(pf_next)
        for slot in self._dec_slots:
            self.live[slot]["tokens"].append(int(dec_next[slot]))
            if len(self.live[slot]["tokens"]) == self.live[slot]["gen"]:
                self._evict(slot, r)
        for lane, slot in self._pf_lanes:
            self.live[slot]["tokens"].append(int(pf_next[lane]))
            if len(self.live[slot]["tokens"]) == self.live[slot]["gen"]:
                self._evict(slot, r)
        self._dec_slots, self._pf_lanes = [], []

    def _evict(self, slot: int, r: int) -> None:
        rec = self.live.pop(slot)
        self.results[rec["rid"]] = tuple(rec["tokens"])
        heapq.heappush(self.free_slots, slot)
        heapq.heappush(self.free_pages, rec["page"])
        self._log(ev="evict", round=r, rid=rec["rid"], slot=slot)
