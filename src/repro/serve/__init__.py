"""Continuous-batching inference on the pipelined runtime.

Serving is a first-class pipelined workload here, not a sidecar loop:
a serving *round* (one batched decode wave + up to ``max_prefill``
freshly admitted prompts) compiles through ``planner/schedule_ir`` to
the same dense int32 artifacts the training interpreters execute — a
:class:`~repro.planner.schedule_ir.ServeTable` for the SPMD
``lax.scan`` backend and per-device
:class:`~repro.planner.schedule_ir.ServeStreams` for the MPMD
``shard_map`` backend — verified by ``planner/verify`` before they run.

  ``trace``      seeded Poisson arrival traces (:func:`poisson_trace`)
                 and the :class:`Request` record.
  ``scheduler``  :class:`ContinuousBatcher` — FIFO admission over
                 request slots and per-stage KV pages, eviction at
                 ``gen_len``, and a verifiable admit/decode/evict
                 event log (``planner.verify.verify_request_trace``).
  ``engine``     :class:`ServeEngine` (the pipelined engine, scan and
                 mpmd backends, bitwise-identical tokens) and
                 :class:`SimpleEngine` (whole-model token-by-token
                 reference; the fallback for hybrid/enc-dec archs the
                 staged decode path gates out).

See docs/SERVING.md for the request lifecycle and KV-page layout.
"""
from repro.serve.engine import ServeEngine, SimpleEngine
from repro.serve.scheduler import ContinuousBatcher, admissible
from repro.serve.trace import Request, poisson_trace

__all__ = ["ServeEngine", "SimpleEngine", "ContinuousBatcher",
           "admissible", "Request", "poisson_trace"]
