"""The unified Runtime facade: one config, one entry point, both
workloads.

``RuntimeConfig`` is the single frozen bag of execution knobs that used
to sprawl across nine keyword arguments on ``make_ir_state`` /
``make_ir_train_step`` (mode, lr, gamma, clip, backend, tracer,
execution, mesh, verify); ``Runtime`` binds it to a planner artifact
and a model and exposes the two workloads:

    rt = Runtime(plan, model, RuntimeConfig(mode="spectrain", lr=2e-2))
    state = rt.init_state(model.init(key), batch_sds)
    state, metrics = rt.train_step(state, batch)       # PipelinePlan

    rt = Runtime(splan, model, RuntimeConfig(execution="mpmd"))
    results = rt.serve_step(params, requests)          # ServePlan

Dispatch is by plan type: a ``planner.PipelinePlan`` gives a training
runtime (streaming or IR-interpreted by ``plan.schedule``), a
``planner.ServePlan`` a serving runtime (``serve/engine.py``; the
``execution`` knob picks the scan/SPMD or shard_map/MPMD round).  The
legacy constructors stay importable for one release behind
``DeprecationWarning`` shims — see ``docs/SERVING.md`` for the
migration table.

``add_runtime_args`` / ``runtime_config_from_args`` are the one shared
argparse wiring ``launch/train.py`` and ``launch/serve.py`` both build
their config from.
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Any, Callable, Dict, Optional

import jax

from repro.core import pipeline_stream as ps

_SCHEDULES = ("stream",) + ps.IR_SCHEDULES


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Execution knobs for :class:`Runtime`, validated at construction.

    ``mode``       staleness-handling scheme (vanilla / pipedream /
                   spectrain); training only.
    ``schedule``   pipeline schedule the plan was compiled for —
                   ``"stream"`` (tick runtime) or an IR round schedule;
                   cross-checked against the plan at bind time.
                   ``None`` (default) adopts the bound plan's schedule
                   (serving plans carry none).
    ``backend``    IR round-body construction (scan / unrolled);
                   SPMD training only.
    ``execution``  SPMD (replicated weights, default) or MPMD
                   (stage-local weights over the pipe mesh axis) for
                   IR training rounds and serving rounds.
    ``verify``     statically verify compiled schedule artifacts
                   before execution (``planner/verify.py``).
    ``trace``      instrument steps for the pipeline tracer (a tracer
                   instance is passed to :class:`Runtime` separately).
    ``lr/gamma/clip/ticks_per_step``  optimizer and tick knobs the
                   training step consumes; serving ignores them.
    """
    mode: str = "spectrain"
    schedule: Optional[str] = None
    backend: str = "scan"
    execution: str = "spmd"
    verify: bool = True
    trace: bool = False
    lr: float = 1e-2
    gamma: float = 0.9
    clip: Optional[float] = None
    ticks_per_step: int = 1

    def __post_init__(self):
        if self.mode not in ps.MODES:
            raise ValueError(f"unknown mode {self.mode!r}; "
                             f"known: {ps.MODES}")
        if self.schedule is not None and self.schedule not in _SCHEDULES:
            raise ValueError(f"unknown schedule {self.schedule!r}; "
                             f"known: {_SCHEDULES}")
        if self.backend not in ps.IR_BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"known: {ps.IR_BACKENDS}")
        if self.execution not in ps.EXECS:
            raise ValueError(f"unknown execution {self.execution!r}; "
                             f"known: {ps.EXECS}")
        if self.execution == "mpmd" and self.schedule == "stream":
            raise ValueError(
                "execution='mpmd' runs IR round schedules "
                f"({'/'.join(ps.IR_SCHEDULES)}) and serving rounds; "
                "the stream schedule is SPMD-only")
        if self.execution == "mpmd" and self.clip:
            raise ValueError(
                "execution='mpmd' does not support clip: the global "
                "norm's canonical-order reduction is not "
                "bit-reproducible on the packed stage layout")
        if self.ticks_per_step < 1:
            raise ValueError(f"ticks_per_step must be >= 1, got "
                             f"{self.ticks_per_step}")

    def replace(self, **kw) -> "RuntimeConfig":
        return dataclasses.replace(self, **kw)


class Runtime:
    """A planner artifact bound to a model under one
    :class:`RuntimeConfig`.

    Training (``plan`` is a :class:`~repro.planner.PipelinePlan`):
    :meth:`init_state` builds the schedule's train state from canonical
    init params and :meth:`train_step` executes one round/tick step —
    jitted with state donation exactly as the launchers did, except
    under the traced MPMD round, which jits per tick internally.

    Serving (``plan`` is a :class:`~repro.planner.ServePlan`):
    :meth:`serve_engine` builds the continuous-batching
    :class:`~repro.serve.engine.ServeEngine` (``config.execution``
    picks the scan or mpmd round) and :meth:`serve_step` drives a
    request trace through it to completion.
    """

    def __init__(self, plan, model, config: Optional[RuntimeConfig]
                 = None, *, tracer=None, mesh=None, registry=None):
        from repro.planner.api import PipelinePlan, ServePlan
        if not isinstance(plan, (PipelinePlan, ServePlan)):
            raise TypeError(
                f"Runtime needs a planner PipelinePlan or ServePlan, "
                f"got {type(plan).__name__}")
        self.plan, self.model = plan, model
        self.config = config if config is not None else RuntimeConfig()
        self.tracer, self.mesh, self.registry = tracer, mesh, registry
        self.serving = isinstance(plan, ServePlan)
        if not self.serving:
            if self.config.schedule is not None \
                    and self.config.schedule != plan.schedule:
                raise ValueError(
                    f"RuntimeConfig.schedule={self.config.schedule!r} "
                    f"does not match the plan's schedule "
                    f"{plan.schedule!r}")
            if self.config.execution == "mpmd" \
                    and plan.schedule not in ps.IR_SCHEDULES:
                raise ValueError(
                    "execution='mpmd' runs IR round schedules "
                    f"({'/'.join(ps.IR_SCHEDULES)}); this plan's "
                    f"schedule is {plan.schedule!r}")
        if tracer is not None and not self.config.trace:
            raise ValueError("a tracer was passed but config.trace is "
                             "False; set RuntimeConfig(trace=True)")
        self._step: Optional[Callable] = None
        self._engine = None

    # ------------------------------------------------------------- training
    @property
    def _ir(self) -> bool:
        return (not self.serving
                and self.plan.schedule in ps.IR_SCHEDULES)

    def init_state(self, params, batch_sds=None) -> Dict[str, Any]:
        """Train state from canonical init ``params``
        (``model.init(key)``); ``batch_sds`` is required by the
        streaming schedule's activation rings."""
        if self.serving:
            raise TypeError("init_state is a training entry point; "
                            "this Runtime binds a ServePlan — use "
                            "serve_engine/serve_step")
        c = self.config
        if self._ir:
            return ps.make_ir_state(
                self.model, params, batch_sds, plan=self.plan,
                mode=c.mode, execution=c.execution, mesh=self.mesh,
                verify=c.verify)
        return ps.make_state(self.model, params, batch_sds,
                             mode=c.mode,
                             ticks_per_step=c.ticks_per_step,
                             plan=self.plan)

    def train_step(self, state, batch):
        """One training step (round or tick group); built and jitted
        lazily on first call, donated state."""
        if self.serving:
            raise TypeError("train_step is a training entry point; "
                            "this Runtime binds a ServePlan — use "
                            "serve_step")
        if self._step is None:
            c = self.config
            if self._ir:
                fn = ps.make_ir_train_step(
                    self.model, plan=self.plan, mode=c.mode, lr=c.lr,
                    gamma=c.gamma, clip=c.clip, backend=c.backend,
                    tracer=self.tracer, execution=c.execution,
                    mesh=self.mesh, verify=c.verify)
            else:
                fn = ps.make_train_step(
                    self.model, mode=c.mode, lr=c.lr, gamma=c.gamma,
                    clip=c.clip, ticks_per_step=c.ticks_per_step,
                    plan=self.plan)
            # the traced mpmd round jits per tick and measures wall
            # time on the host; an outer jit would swallow its marks
            if not (c.execution == "mpmd" and self.tracer is not None):
                fn = jax.jit(fn, donate_argnums=0)
            if self.tracer is not None:
                fn = self.tracer.wrap_step(fn)
            self._step = fn
        return self._step(state, batch)

    # -------------------------------------------------------------- serving
    def serve_engine(self, params):
        """The continuous-batching engine for ``params`` (built once
        and cached; ``config.execution`` picks the scan or mpmd
        serving round)."""
        if not self.serving:
            raise TypeError("serve_engine needs a ServePlan; this "
                            "Runtime binds a training PipelinePlan — "
                            "use init_state/train_step")
        if self._engine is None:
            from repro.serve import ServeEngine
            backend = "mpmd" if self.config.execution == "mpmd" \
                else "scan"
            self._engine = ServeEngine(
                self.model, params, self.plan, backend=backend,
                mesh=self.mesh, registry=self.registry,
                verify=self.config.verify)
        return self._engine

    def serve_step(self, params, requests, *,
                   max_rounds: Optional[int] = None) -> Dict[int, tuple]:
        """Drive ``requests`` (a trace of ``serve.Request``) through
        the engine to completion; returns ``{rid: emitted tokens}``."""
        return self.serve_engine(params).run(requests,
                                             max_rounds=max_rounds)


# ---------------------------------------------------------------- argparse
# the one shared flag wiring train.py and serve.py build their
# RuntimeConfig from (satellite: delete the duplicated per-launcher
# copies)


def add_runtime_args(ap, *, serving: bool = False) -> None:
    """Install the RuntimeConfig flags on ``ap``.  ``--exec`` stays as
    a hidden deprecated alias for ``--execution`` for one release."""
    if not serving:
        ap.add_argument("--mode", default="spectrain",
                        choices=("sync",) + ps.MODES)
        ap.add_argument("--schedule", default="stream",
                        choices=_SCHEDULES,
                        help="pipeline schedule: the streaming tick "
                             "runtime (default) or an IR-interpreted "
                             "round schedule (gpipe / 1f1b / 2bw / "
                             "interleaved)")
        ap.add_argument("--ir-backend", default="scan",
                        dest="ir_backend", choices=ps.IR_BACKENDS,
                        help="round-body construction for IR "
                             "schedules: 'scan' compiles a lax.scan "
                             "over the plan's event table (O(1) trace "
                             "size in the round's microbatch count), "
                             "'unrolled' inlines every event (the "
                             "reference oracle)")
        ap.add_argument("--lr", type=float, default=1e-2)
        ap.add_argument("--gamma", type=float, default=0.9)
        ap.add_argument("--clip", type=float, default=0.0)
    ap.add_argument("--execution", default=None, dest="execution",
                    choices=ps.EXECS,
                    help="execution backend: 'spmd' (default) runs "
                         "rounds as one replicated program, 'mpmd' "
                         "keeps stage weights/KV device-local "
                         "(shard_map over the pipe axis, payloads "
                         "cross stage cuts via ppermute); "
                         "bitwise-identical results, 1/S the "
                         "per-device weight memory (needs >= S "
                         "devices)")
    ap.add_argument("--exec", default=None, dest="exec_legacy",
                    choices=ps.EXECS, help=argparse.SUPPRESS)
    ap.add_argument("--no-verify", action="store_true",
                    dest="no_verify",
                    help="skip the static schedule verifier "
                         "(planner/verify.py) that runs by default at "
                         "step construction")


def runtime_config_from_args(args, **overrides) -> RuntimeConfig:
    """Build the :class:`RuntimeConfig` from parsed launcher flags —
    the single translation point from argv to config.  ``overrides``
    win over flags (launchers pin fields their workload fixes, e.g.
    serving has no --mode)."""
    execution = getattr(args, "execution", None)
    legacy = getattr(args, "exec_legacy", None)
    if legacy is not None:
        import warnings
        warnings.warn("--exec is deprecated; use --execution "
                      "(--exec will be removed next release)",
                      DeprecationWarning, stacklevel=2)
        if execution is not None and execution != legacy:
            raise SystemExit(f"--execution {execution} conflicts with "
                             f"legacy --exec {legacy}")
        execution = legacy
    kw: Dict[str, Any] = {
        "execution": execution or "spmd",
        "verify": not getattr(args, "no_verify", False),
    }
    if hasattr(args, "mode") and args.mode != "sync":
        kw["mode"] = args.mode
    if hasattr(args, "schedule"):
        kw["schedule"] = args.schedule
    if hasattr(args, "ir_backend"):
        kw["backend"] = args.ir_backend
    if hasattr(args, "lr"):
        kw["lr"] = args.lr
    if hasattr(args, "gamma"):
        kw["gamma"] = args.gamma
    if hasattr(args, "clip"):
        kw["clip"] = args.clip or None
    if getattr(args, "trace", ""):
        kw["trace"] = True
    kw.update(overrides)
    return RuntimeConfig(**kw)
