"""Predicted-vs-measured drift report.

Diffs the tracer's reconstructed measured timeline against the plan's
IR-derived metrics and the profiler's cost estimates:

  * **bubble**: realized idle fraction of device-tick slots vs the IR's
    ``plan.bubble_frac`` (unit-cost) and the cost-weighted predicted
    timeline's bubble;
  * **per-stage cost model**: measured per-stage forward seconds vs
    ``plan.stage_costs_s``, compared as shares of their totals so the
    host-vs-model absolute scale cancels — per-stage relative error
    > ~0.2 means the partition was computed from a miscalibrated
    profile and should be re-profiled (``--profile-method timed``);
  * **per-device busy/idle/P2P shares**: the Fig. 10 axes.  P2P is
    modelled (cut activation bytes / link bandwidth, the
    ``benchmarks/_timeline.py`` constants) — the host simulator moves
    activations through memory, not a link, so measured P2P is 0 and
    the modelled value is reported alongside for the breakdown;
  * **staleness histogram**: realized weight-version lags per phase vs
    the plan's ``s_fwd``/``s_bwd`` vectors.
"""
from __future__ import annotations

from typing import Any, Dict, List

from repro.obs.trace import PipelineTracer, timeline_stats

PCIE_BW = 12.0e9    # bytes/s effective per link (benchmarks/_timeline.py)


def _shares(xs: List[float]) -> List[float]:
    tot = sum(xs)
    return [x / tot if tot else 0.0 for x in xs]


def _modelled_p2p_s(plan) -> float:
    """Per-cut activation transfer time from the plan's profile (0 when
    the profile carries no byte counts — synthetic profiles)."""
    prof = plan.profile
    if prof is None or not prof.layers:
        return 0.0
    act = max(lp.act_bytes for lp in prof.layers)
    return 2.0 * act / PCIE_BW      # activation fwd + cotangent bwd


def drift_report(tracer: PipelineTracer) -> Dict[str, Any]:
    plan = tracer.plan
    D = plan.n_devices
    m_spans, m_makespan = tracer.measured_timeline()
    p_spans, p_makespan = tracer.predicted_timeline()
    m_stats = timeline_stats(m_spans, m_makespan, D)
    p_stats = timeline_stats(p_spans, p_makespan, D)

    meas = tracer.measured_stage_costs()
    pred = list(plan.stage_costs_s) if any(plan.stage_costs_s) \
        else [1.0] * plan.n_chunks
    ms, ps = _shares(meas), _shares(pred)
    rel_err = [m / p - 1.0 if p else float("inf")
               for m, p in zip(ms, ps)]
    scale = (sum(meas) / sum(pred)) if sum(pred) else float("inf")

    return {
        "schedule": plan.schedule,
        "n_stages": plan.n_stages,
        "n_chunks": plan.n_chunks,
        "partition": list(plan.stage_sizes),
        "steps_recorded": tracer.n_steps(),
        "bubble": {
            "measured": m_stats["bubble_frac"],
            "predicted_ir": plan.bubble_frac,
            "predicted_weighted": p_stats["bubble_frac"],
            "drift": m_stats["bubble_frac"] - plan.bubble_frac,
        },
        "devices": {
            "busy_frac": m_stats["busy_frac"],
            "idle_frac": [1.0 - b for b in m_stats["busy_frac"]],
            "p2p_s_modelled": _modelled_p2p_s(plan),
            "makespan_s": m_stats["makespan_s"],
        },
        "stage_cost_model": {
            "measured_s": meas,
            "predicted_s": pred,
            "measured_share": ms,
            "predicted_share": ps,
            "rel_err": rel_err,
            "max_abs_rel_err": max(abs(e) for e in rel_err),
            "time_scale": scale,
        },
        "staleness": {
            "realized": tracer.staleness_histogram(),
            "plan_s_fwd": list(plan.s_fwd),
            "plan_s_bwd": list(plan.s_bwd),
        },
    }


def format_drift(rep: Dict[str, Any]) -> str:
    """Human-readable drift report (what ``train.py --trace`` prints)."""
    b = rep["bubble"]
    sc = rep["stage_cost_model"]
    dv = rep["devices"]
    lines = [
        f"# drift report: {rep['schedule']} x{rep['n_stages']} "
        f"partition={rep['partition']} over {rep['steps_recorded']} steps",
        f"# bubble: measured {b['measured']:.3f}  "
        f"ir-predicted {b['predicted_ir']:.3f}  "
        f"cost-weighted {b['predicted_weighted']:.3f}  "
        f"drift {b['drift']:+.3f}",
        "# device busy fractions: "
        + " ".join(f"d{i}={f:.2f}" for i, f in enumerate(dv['busy_frac']))
        + f"  (p2p modelled {dv['p2p_s_modelled']:.2e}s/cut)",
        "# stage  pred_s      meas_s      pred_share meas_share rel_err",
    ]
    for k, (p, m, psh, msh, e) in enumerate(zip(
            sc["predicted_s"], sc["measured_s"],
            sc["predicted_share"], sc["measured_share"], sc["rel_err"])):
        lines.append(f"#  s{k:<4d} {p:<11.3e} {m:<11.3e} "
                     f"{psh:<10.3f} {msh:<10.3f} {e:+.3f}")
    lines.append(
        f"# cost model: max |rel err| {sc['max_abs_rel_err']:.3f}, "
        f"wall/model time scale {sc['time_scale']:.2f}x")
    st = rep["staleness"]["realized"]
    lines.append(
        "# staleness (lag: events): fwd {"
        + ", ".join(f"{k}: {v}" for k, v in sorted(st["fwd"].items()))
        + "}  bwd {"
        + ", ".join(f"{k}: {v}" for k, v in sorted(st["bwd"].items()))
        + "}")
    return "\n".join(lines)
