"""Pipeline tracer: measured per-(device, event) spans from the runtimes.

Measurement model
-----------------

The host simulator executes every pipeline "device" serially inside one
jitted step, so wall-clock spans cannot be read off per device directly.
The tracer therefore measures **per-event durations** and *reconstructs*
the parallel timeline the IR describes:

  * IR-interpreter runtimes (``backend="unrolled"`` and ``"scan"`` in
    ``core/pipeline_stream.py``): every compute event ends with an
    **ordered host callback** carrying a data dependence on that event's
    outputs; consecutive callback timestamps attribute the round's wall
    time to its events.  The callbacks arrive in the IR's timeline order
    (the same order ``round_compute_program`` / the event table emit),
    so arrival index *is* the event index.
  * streaming runtime: one step is one fused tick over all stages — the
    tracer records per-step wall time and attributes it across stages by
    separately **probed** per-stage costs (:func:`probe_stage_costs`,
    the PipeDream profile-then-attribute approach).

Reconstruction lays measured durations on the IR's discrete tick grid:
tick ``t`` starts when every device finished tick ``t-1`` (the IR's
synchronous-time semantics), a device's events within a tick run
back-to-back.  Realized bubble fraction, per-device busy/idle and the
per-stage cost vector all fall out of the reconstructed spans; the
predicted lane applies the same reconstruction to the planner's modelled
durations (fwd = stage cost, bwd = 2x — the standard 1:2 fwd:bwd FLOP
ratio the roofline model also uses).

The first recorded round is dropped from aggregates when more than one
exists (it pays XLA compilation).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

BWD_FWD_RATIO = 2.0     # modelled bwd/fwd cost ratio (2 matmuls vs 1)


@dataclass(frozen=True)
class Span:
    """One lane-resident interval of the (re)constructed timeline."""
    device: int          # pipe device = Perfetto lane (tid)
    name: str            # "fwd m3 q1", "tick 7", ...
    t0: float            # seconds from timeline origin
    dur: float           # seconds
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def t1(self) -> float:
        return self.t0 + self.dur


def round_event_metas(plan) -> List[Dict[str, Any]]:
    """Static per-event metadata for one round of an IR schedule, in the
    exact order the interpreter executes (and the tracer's callbacks
    arrive): ``kind``, ``mb``, ``chunk``, ``wv`` (weight-version lag),
    ``tick`` (round-relative) and ``device``."""
    from repro.planner import schedule_ir as sir

    sched = plan.round_ir()
    M = plan.round_microbatches
    base = M if plan.schedule == "2bw" else 0
    prog = plan.round_program()
    ticks = [e.t for e in sched.events
             if e.kind != sir.UPDATE and base <= e.mb < base + M]
    if len(ticks) != len(prog):
        raise ValueError(
            f"{plan.schedule}: {len(ticks)} round events vs "
            f"{len(prog)} program entries")
    t0 = min(ticks)
    D = plan.n_devices
    return [
        {"kind": kind, "mb": m, "chunk": q, "wv": s,
         "tick": t - t0, "device": q % D}
        for (kind, m, q, s), t in zip(prog, ticks)]


def device_stream_tick_groups(plan) -> List[List[int]]:
    """Event-index groups per schedule tick, in tick order — the mark
    granularity of the MPMD execution path.

    The MPMD round marks once per *tick* (one shard_map call per tick
    when traced; events inside a tick run concurrently on different
    devices, so per-event marks would race), while
    :func:`round_event_metas` is per *event*.  Group ``t`` lists the
    meta indices of every event in the round's ``t``-th distinct tick —
    the same rank compression ``planner.schedule_ir
    .compile_device_streams`` applies.  Install on the tracer with
    :meth:`PipelineTracer.set_tick_groups`."""
    by: Dict[int, List[int]] = {}
    for i, m in enumerate(round_event_metas(plan)):
        by.setdefault(m["tick"], []).append(i)
    return [by[t] for t in sorted(by)]


def _reconstruct(metas: Sequence[Dict[str, Any]],
                 durs: Sequence[float]) -> Tuple[List[Span], float]:
    """Lay per-event durations on the IR tick grid (synchronous ticks,
    back-to-back events per device within a tick).  Returns (spans,
    makespan)."""
    if len(metas) != len(durs):
        raise ValueError(f"{len(durs)} durations for {len(metas)} events")
    spans: List[Span] = []
    cursor = 0.0
    by_tick: Dict[int, List[int]] = {}
    for i, m in enumerate(metas):
        by_tick.setdefault(m["tick"], []).append(i)
    for t in sorted(by_tick):
        dev_off: Dict[int, float] = {}
        for i in by_tick[t]:
            m = metas[i]
            off = dev_off.get(m["device"], 0.0)
            spans.append(Span(
                device=m["device"],
                name=f"{m['kind']} m{m['mb']} q{m['chunk']}",
                t0=cursor + off, dur=float(durs[i]),
                args={"op": m["kind"], "mb": m["mb"], "chunk": m["chunk"],
                      "wv_lag": m["wv"], "tick": t}))
            dev_off[m["device"]] = off + float(durs[i])
        cursor += max(dev_off.values()) if dev_off else 0.0
    return spans, cursor


def timeline_stats(spans: Sequence[Span], makespan: float,
                   n_devices: int) -> Dict[str, Any]:
    """Busy/idle accounting over a reconstructed timeline."""
    busy = [0.0] * n_devices
    for s in spans:
        busy[s.device] += s.dur
    total = n_devices * makespan
    return {
        "makespan_s": makespan,
        "busy_s": busy,
        "idle_s": [max(0.0, makespan - b) for b in busy],
        "busy_frac": [b / makespan if makespan else 0.0 for b in busy],
        "bubble_frac": 1.0 - (sum(busy) / total if total else 0.0),
    }


def probe_stage_costs(model, stage_trees, *, mb: int = 1, seq: int = 16,
                      iters: int = 3,
                      clock: Callable[[], float] = time.perf_counter
                      ) -> List[float]:
    """Measured per-stage forward wall time (jitted, warm) — the
    streaming runtime's attribution weights and the PipeDream-style
    realized profile a recalibration would feed back to the planner."""
    import jax
    import jax.numpy as jnp

    x = jnp.zeros((mb, seq, model.cfg.d_model),
                  jnp.dtype(model.cfg.compute_dtype))
    costs = []
    for sp in stage_trees:
        f = jax.jit(lambda p, xx: model.stage_apply(
            p, (xx, jnp.zeros((), jnp.float32)))[0])
        jax.block_until_ready(f(sp, x))         # compile + warm
        t0 = clock()
        for _ in range(iters):
            out = f(sp, x)
        jax.block_until_ready(out)
        costs.append((clock() - t0) / iters)
    return costs


class PipelineTracer:
    """Collects measured event timings for one :class:`PipelinePlan`.

    Usage (the ``launch/train.py --trace`` wiring)::

        tracer = PipelineTracer(plan)
        step = pipeline_stream.make_ir_train_step(..., tracer=tracer)
        step = tracer.wrap_step(jax.jit(step, donate_argnums=0))
        ... run steps ...
        obs.write_trace(path, tracer)
        print(obs.format_drift(obs.drift_report(tracer)))

    ``clock`` is injectable for deterministic tests (a fake clock that
    advances a fixed amount per call yields exactly-uniform durations).
    """

    def __init__(self, plan, *,
                 clock: Callable[[], float] = time.perf_counter):
        from repro.planner.api import ROUND_SCHEDULES

        self.plan = plan
        self.clock = clock
        self.is_round = plan.schedule in ROUND_SCHEDULES
        self.metas = round_event_metas(plan) if self.is_round else []
        self.rounds: List[List[float]] = []   # per-round event durations
        self.step_walls: List[float] = []     # per-step wall seconds
        self.probed: Optional[List[float]] = None
        self.dropped_rounds = 0               # mark-count mismatches
        self.tick_groups: Optional[List[List[int]]] = None
        self._cur: List[float] = []
        self._t0: Optional[float] = None

    # ------------------------------------------------------ runtime hooks
    def _mark(self) -> None:
        """Ordered host callback target: one call per compute event, in
        the IR's timeline order (arrival index == event index)."""
        self._cur.append(self.clock())

    def set_tick_groups(self, groups: Sequence[Sequence[int]]) -> None:
        """Switch to tick-granular marks (the MPMD execution path): one
        mark per schedule tick instead of one per event
        (:func:`device_stream_tick_groups`).  Each measured tick
        duration is attributed to *every* event in that tick — honest
        for MPMD, where a tick's events run concurrently on different
        devices and the slowest sets the tick's wall time, but an upper
        bound per event (the tracer cannot see the intra-tick split
        from one mark per tick)."""
        groups = [list(g) for g in groups]
        covered = sorted(i for g in groups for i in g)
        if covered != list(range(len(self.metas))):
            raise ValueError(
                f"tick groups cover event indices {covered[:8]}..., "
                f"expected exactly 0..{len(self.metas) - 1}")
        self.tick_groups = groups

    def wrap_step(self, step_fn: Callable) -> Callable:
        """Wrap a (jitted) train step with round bracketing: resets the
        mark buffer, times the call, and files the round's durations."""
        def traced_step(state, batch):
            self._cur = []
            self._t0 = self.clock()
            out = step_fn(state, batch)
            import jax
            out = jax.block_until_ready(out)
            wall = self.clock() - self._t0
            self.step_walls.append(wall)
            if self.is_round:
                want = (len(self.tick_groups)
                        if self.tick_groups is not None else len(self.metas))
                if len(self._cur) == want:
                    ts = [self._t0] + self._cur
                    durs = [ts[i + 1] - ts[i]
                            for i in range(len(self._cur))]
                    if self.tick_groups is not None:
                        ev = [0.0] * len(self.metas)
                        for t, grp in enumerate(self.tick_groups):
                            for i in grp:
                                ev[i] = durs[t]
                        durs = ev
                    self.rounds.append(durs)
                elif self._cur:
                    self.dropped_rounds += 1
            return out
        return traced_step

    def set_probed(self, costs: Sequence[float]) -> None:
        self.probed = [float(c) for c in costs]

    # ------------------------------------------------------- aggregation
    def _steady(self, seq: Sequence) -> Sequence:
        """Drop the first (compiling) entry when more than one exists."""
        return seq[1:] if len(seq) > 1 else seq

    def mean_durations(self) -> List[float]:
        """Per-event durations averaged over steady rounds (IR
        schedules only)."""
        rounds = self._steady(self.rounds)
        if not rounds:
            raise ValueError("tracer recorded no complete rounds")
        n = len(rounds[0])
        return [sum(r[i] for r in rounds) / len(rounds) for i in range(n)]

    def n_steps(self) -> int:
        return len(self.step_walls)

    # ------------------------------------------------------- timelines
    def measured_timeline(self) -> Tuple[List[Span], float]:
        if self.is_round:
            return _reconstruct(self.metas, self.mean_durations())
        return self._stream_timeline(self._stream_weights())

    def predicted_timeline(self) -> Tuple[List[Span], float]:
        """The planner's modelled timeline on the same tick grid
        (fwd = stage cost, bwd = ``BWD_FWD_RATIO`` x)."""
        costs = self._plan_costs()
        if self.is_round:
            durs = [costs[m["chunk"]] *
                    (1.0 if m["kind"] == "fwd" else BWD_FWD_RATIO)
                    for m in self.metas]
            return _reconstruct(self.metas, durs)
        return self._stream_timeline(costs, predicted=True)

    def _plan_costs(self) -> List[float]:
        costs = list(self.plan.stage_costs_s or [])
        if not costs or not any(costs):
            costs = [1.0] * self.plan.n_chunks
        return costs

    def _stream_weights(self) -> List[float]:
        if self.probed:
            return list(self.probed)
        return self._plan_costs()

    def _stream_timeline(self, weights: Sequence[float], *,
                         predicted: bool = False
                         ) -> Tuple[List[Span], float]:
        """Streaming runtime: one span per (device, step); span length
        is the step wall scaled by that stage's share of the bottleneck
        stage's cost (every stage runs concurrently inside the fused
        tick, the bottleneck sets the step time)."""
        walls = self._steady(self.step_walls)
        if not walls:
            raise ValueError("tracer recorded no steps")
        if predicted:
            # modelled step time: bottleneck stage fwd+bwd
            walls = [max(weights) * (1.0 + BWD_FWD_RATIO)] * len(walls)
        wmax = max(weights)
        spans: List[Span] = []
        cursor = 0.0
        for t, wall in enumerate(walls):
            for k, w in enumerate(weights):
                spans.append(Span(
                    device=k, name=f"tick {t} s{k}",
                    t0=cursor, dur=wall * (w / wmax),
                    args={"op": "tick", "tick": t, "chunk": k,
                          "attributed": True}))
            cursor += wall
        return spans, cursor

    # ------------------------------------------------------- measurements
    def measured_stage_costs(self) -> List[float]:
        """Realized per-(chunk-)stage forward cost in seconds: the mean
        measured fwd-event duration (IR schedules) or the probed stage
        times (streaming) — the vector a profiler recalibration feeds
        back into ``planner.plan()``."""
        if not self.is_round:
            if not self.probed:
                raise ValueError(
                    "streaming tracer needs probe_stage_costs() results "
                    "(tracer.set_probed) for per-stage measurements")
            return list(self.probed)
        durs = self.mean_durations()
        C = self.plan.n_chunks
        tot = [0.0] * C
        n = [0] * C
        for m, d in zip(self.metas, durs):
            if m["kind"] == "fwd":
                tot[m["chunk"]] += d
                n[m["chunk"]] += 1
        return [t / max(1, c) for t, c in zip(tot, n)]

    def staleness_histogram(self) -> Dict[str, Dict[int, int]]:
        """Realized weight-version-lag counts per phase, from the
        executed events (IR schedules) or the plan vectors (stream)."""
        out: Dict[str, Dict[int, int]] = {"fwd": {}, "bwd": {}}
        if self.is_round:
            for m in self.metas:
                h = out[m["kind"]]
                h[m["wv"]] = h.get(m["wv"], 0) + 1
        else:
            for s in self.plan.s_fwd:
                out["fwd"][s] = out["fwd"].get(s, 0) + 1
            for s in self.plan.s_bwd:
                out["bwd"][s] = out["bwd"].get(s, 0) + 1
        return out
