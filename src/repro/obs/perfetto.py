"""Chrome/Perfetto trace export + schema validation.

Emits the Trace Event Format JSON that both ``chrome://tracing`` and
https://ui.perfetto.dev load: an object with a ``traceEvents`` list of
complete-duration (``"ph": "X"``) events, one **process** per lane group
(pid 0 = measured, pid 1 = predicted) and one **thread lane per pipe
device** inside each, named via ``"M"`` metadata events.  Timestamps are
microseconds relative to the timeline origin.

``validate_trace`` is the schema check the CI trace-smoke job and the
trace tests run; ``python -m repro.obs.perfetto trace.json`` validates a
file from the command line.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

PID_MEASURED, PID_PREDICTED = 0, 1
_LANE_NAMES = {PID_MEASURED: "measured", PID_PREDICTED: "predicted"}


def _lane_events(spans, pid: int, label: str) -> List[Dict[str, Any]]:
    ev: List[Dict[str, Any]] = [{
        "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
        "args": {"name": label},
    }]
    devices = sorted({s.device for s in spans})
    for d in devices:
        ev.append({"ph": "M", "name": "thread_name", "pid": pid, "tid": d,
                   "args": {"name": f"device {d}"}})
    for s in spans:
        ev.append({
            "ph": "X", "name": s.name, "pid": pid, "tid": s.device,
            "ts": s.t0 * 1e6, "dur": s.dur * 1e6,
            "cat": s.args.get("op", "event"),
            "args": dict(s.args),
        })
    return ev


def trace_events(tracer) -> Dict[str, Any]:
    """Full trace object: measured lane group + the IR's predicted lane
    group, plus plan metadata for provenance."""
    m_spans, m_span = tracer.measured_timeline()
    p_spans, p_span = tracer.predicted_timeline()
    p = tracer.plan
    return {
        "traceEvents": (
            _lane_events(m_spans, PID_MEASURED,
                         f"measured ({p.schedule})") +
            _lane_events(p_spans, PID_PREDICTED,
                         f"predicted ({p.schedule} IR)")),
        "displayTimeUnit": "ms",
        "otherData": {
            "schedule": p.schedule,
            "n_stages": p.n_stages,
            "n_chunks": p.n_chunks,
            "partition": list(p.stage_sizes),
            "measured_makespan_s": m_span,
            "predicted_makespan_s": p_span,
            "steps_recorded": tracer.n_steps(),
        },
    }


def validate_trace(obj: Any) -> List[str]:
    """Schema problems in a trace object (empty list = valid).

    Checks the invariants Perfetto needs to render the two lane groups:
    a ``traceEvents`` list; every event a dict with a string ``name``
    and ``ph`` in {"X", "M"}; every "X" event carrying finite
    non-negative ``ts``/``dur`` and integer ``pid``/``tid``; and at
    least one "X" event in each of the measured and predicted groups.
    """
    problems: List[str] = []
    if not isinstance(obj, dict):
        return [f"trace must be a JSON object, got {type(obj).__name__}"]
    ev = obj.get("traceEvents")
    if not isinstance(ev, list):
        return ["missing or non-list traceEvents"]
    seen_x = set()
    for i, e in enumerate(ev):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "M"):
            problems.append(f"{where}: ph={ph!r} not in ('X', 'M')")
            continue
        if not isinstance(e.get("name"), str) or not e["name"]:
            problems.append(f"{where}: missing name")
        if not isinstance(e.get("pid"), int) or \
                not isinstance(e.get("tid"), int):
            problems.append(f"{where}: pid/tid must be ints")
            continue
        if ph == "X":
            seen_x.add(e["pid"])
            for fld in ("ts", "dur"):
                v = e.get(fld)
                ok = isinstance(v, (int, float)) and v == v \
                    and v not in (float("inf"), float("-inf")) and v >= 0
                if not ok:
                    problems.append(
                        f"{where}: {fld}={v!r} not a finite number >= 0")
    for pid, label in _LANE_NAMES.items():
        if pid not in seen_x:
            problems.append(f"no span events in the {label!r} lane group "
                            f"(pid {pid})")
    return problems


def write_trace(path: str, tracer) -> Dict[str, Any]:
    """Build, validate and write the trace JSON; returns the object."""
    obj = trace_events(tracer)
    problems = validate_trace(obj)
    if problems:
        raise ValueError("invalid trace: " + "; ".join(problems))
    with open(path, "w") as f:
        json.dump(obj, f)
        f.write("\n")
    return obj


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="validate a Perfetto trace JSON file")
    ap.add_argument("trace", help="path to a trace JSON file")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        obj = json.load(f)
    problems = validate_trace(obj)
    for p in problems:
        print(f"INVALID: {p}")
    if not problems:
        n = sum(1 for e in obj["traceEvents"] if e.get("ph") == "X")
        print(f"OK: {n} span events across "
              f"{len({e['pid'] for e in obj['traceEvents']})} lane groups")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
