"""Observability: pipeline tracing, drift reports, metrics registry.

The measurement counterpart of the planner: the schedule IR *predicts* a
per-device timeline (bubble fraction, staleness, per-stage cost); this
package *measures* one from the running interpreter and diffs the two —
the feedback loop that turns the planner from open-loop to closed-loop.

  * :mod:`repro.obs.trace`    — :class:`PipelineTracer`: per-event host
    timestamps from the IR interpreter backends, per-step wall time for
    the streaming runtime, and a parallel-timeline reconstruction.
  * :mod:`repro.obs.perfetto` — Chrome/Perfetto trace-JSON export
    (measured + predicted lane groups) and a trace-schema validator.
  * :mod:`repro.obs.drift`    — predicted-vs-measured drift report:
    realized bubble, per-stage busy/idle shares, staleness histograms,
    per-stage cost-model relative error.
  * :mod:`repro.obs.metrics`  — counters / gauges / histograms +
    structured events → JSONL and a summary table; the one code path
    behind ``train.py``'s human and ``--json`` step records.
"""
from repro.obs.drift import drift_report, format_drift  # noqa: F401
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, format_step)
from repro.obs.perfetto import (trace_events, validate_trace,  # noqa: F401
                                write_trace)
from repro.obs.trace import (PipelineTracer, Span,  # noqa: F401
                             device_stream_tick_groups,
                             probe_stage_costs, round_event_metas)
