"""Structured telemetry: counters / gauges / histograms + event JSONL.

One registry instance per process (train or serve driver, tests).  Two
surfaces:

  * **instruments** — ``registry.counter(name)`` / ``gauge`` /
    ``histogram``: in-memory aggregates, dumped as one ``summary`` event
    on :meth:`MetricsRegistry.close` and renderable as a table
    (:meth:`MetricsRegistry.summary`);
  * **events** — ``registry.emit("heartbeat_missed", worker=3, ...)``:
    one JSON line per event, appended and flushed immediately (so a
    KeyboardInterrupt or crash loses nothing), and kept in
    ``registry.events`` for tests.

``train.py``'s human and ``--json`` step records both come from
:meth:`log_step` — one record-construction code path, two formatters
(``json.dumps`` and :func:`format_step`).
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List, Optional


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = None

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming aggregate + a bounded sample reservoir for quantiles
    (first ``cap`` observations — ample for driver-scale runs)."""
    __slots__ = ("count", "total", "min", "max", "_sample", "_cap")

    def __init__(self, cap: int = 4096):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._sample: List[float] = []
        self._cap = cap

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if len(self._sample) < self._cap:
            self._sample.append(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 100], nearest-rank over the reservoir."""
        if not self._sample:
            return 0.0
        xs = sorted(self._sample)
        i = min(len(xs) - 1, max(0, round(q / 100.0 * (len(xs) - 1))))
        return xs[i]

    def snapshot(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0}
        return {"count": self.count, "mean": self.mean,
                "min": self.min, "max": self.max,
                "p50": self.percentile(50), "p99": self.percentile(99)}


class MetricsRegistry:
    """Counters/gauges/histograms + structured events -> JSONL.

    ``jsonl_path=None`` keeps everything in memory (tests, tracing-only
    runs); with a path, every event is one appended-and-flushed JSON
    line.  Usable as a context manager; :meth:`close` is idempotent and
    safe to call from a ``finally`` after KeyboardInterrupt.
    """

    def __init__(self, jsonl_path: Optional[str] = None, *,
                 clock: Callable[[], float] = time.time):
        self.clock = clock
        self.events: List[Dict[str, Any]] = []
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}
        self._path = jsonl_path
        self._file = open(jsonl_path, "a") if jsonl_path else None

    # --------------------------------------------------------- instruments
    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        return self._hists.setdefault(name, Histogram())

    def kernel_hook(self) -> Callable[[str, float], None]:
        """Timing hook for ``kernels.ops.set_timing_hook``: feeds each
        (kernel name, microseconds) sample into a histogram."""
        def hook(name: str, us: float) -> None:
            self.histogram(f"kernel/{name}_us").observe(us)
        return hook

    # -------------------------------------------------------------- events
    def emit(self, event: str, **fields: Any) -> Dict[str, Any]:
        rec = {"event": event, "t": self.clock(), **fields}
        self.events.append(rec)
        if self._file is not None:
            json.dump(rec, self._file)
            self._file.write("\n")
            self._file.flush()
        return rec

    def log_step(self, *, step: int, loss: float, tok_per_s: float,
                 **extra: Any) -> Dict[str, Any]:
        """The train driver's per-step record — the single code path
        behind both the human line and ``--json`` stdout, also emitted
        to the JSONL stream as a ``train_step`` event."""
        rec = {"step": step, "loss": loss, "tok_per_s": tok_per_s, **extra}
        self.counter("train/steps_logged").inc()
        self.gauge("train/loss").set(loss)
        self.gauge("train/tok_per_s").set(tok_per_s)
        self.emit("train_step", **rec)
        return rec

    def find(self, event: str) -> List[Dict[str, Any]]:
        return [e for e in self.events if e.get("event") == event]

    # ------------------------------------------------------------- summary
    def snapshot(self) -> Dict[str, Any]:
        return {
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {k: g.value for k, g in self._gauges.items()},
            "histograms": {k: h.snapshot() for k, h in self._hists.items()},
        }

    def summary(self) -> str:
        snap = self.snapshot()
        lines = ["# metric                                  value"]
        for k, v in sorted(snap["counters"].items()):
            lines.append(f"# {k:<40} {v:g}")
        for k, v in sorted(snap["gauges"].items()):
            lines.append(f"# {k:<40} "
                         f"{'-' if v is None else format(v, 'g')}")
        for k, h in sorted(snap["histograms"].items()):
            if not h.get("count"):
                continue
            lines.append(
                f"# {k:<40} n={h['count']} mean={h['mean']:.1f} "
                f"p50={h['p50']:.1f} p99={h['p99']:.1f} max={h['max']:.1f}")
        return "\n".join(lines)

    def close(self) -> None:
        """Emit a final ``summary`` event and close the JSONL stream —
        idempotent, and the KeyboardInterrupt flush path."""
        if self._file is not None:
            self.emit("summary", **self.snapshot())
            self._file.close()
            self._file = None

    def __enter__(self) -> "MetricsRegistry":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def format_step(rec: Dict[str, Any]) -> str:
    """Human rendering of a :meth:`MetricsRegistry.log_step` record."""
    return (f"step {rec['step']:5d}  loss {rec['loss']:.4f}  "
            f"tok/s {rec['tok_per_s']}")
