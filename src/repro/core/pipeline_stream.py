"""Async streaming pipeline — the paper's PipeDream-style runtime.

One ``train_step`` call = one pipeline **tick**.  Every stage performs one
forward (of the microbatch injected ``k`` ticks ago) and one backward (of
the microbatch injected ``2(S−1)−k`` ticks ago) per tick; in-flight
activations/cotangents live in ring buffers carried across steps inside
the train state.  Each stage applies its own gradient the tick its
backward completes — per-minibatch, per-stage weight updates, i.e. exactly
the staleness structure of §3.1.  After the 2(S−1)-tick warm-up there is
**zero bubble**.

Weight-handling modes (§3.2 / Fig. 7):

  vanilla    fwd & bwd use current weights            (stale, inconsistent)
  pipedream  fwd uses current, bwd the stashed fwd weights (stale, consistent)
  spectrain  fwd uses Ŵ = W − s_fwd·η·v (Eq. 4 with s_fwd = 2(S−1−k));
             bwd uses current weights (s_bwd = 0 → already the target)

Gradient synchronization over the `data` (and `pod`) mesh axes is inserted
by GSPMD from the sharding specs — synchronous DP across replicas, async
across pipeline stages, exactly the paper's hybrid.

Backward uses stored stage *inputs* plus recompute (remat), so the rings
hold one activation tensor per (stage, in-flight microbatch) — the same
memory PipeDream's activation stashing pays, and ~L× less than storing
residuals.

Stage parameters are **ragged per-stage trees**: ``state["params"]
["stages"]`` is a tuple of ``S`` pytrees whose ``layers`` leaves are
``[L_k, ...]`` for the plan's per-stage layer counts — the same ragged
canonical layout ``Model.init`` produces (no ``n_layers % n_stages``
constraint anywhere).  Activations are ``d_model``-wide at every cut,
so the rings stay uniform ``[S, ...]`` arrays — only weights (and
their momentum/stash/prediction mirrors) go ragged.  A planner
``PipelinePlan`` with a non-uniform (DP) partition is therefore
*executed*, not just logged: ``make_state`` repartitions the canonical
trees via ``Model.partition_stage_params`` (a no-op when the plan's
sizes match; legacy stacked ``[S, Lps, ...]`` inputs are accepted and
regrouped) and validates the plan's layer ranges against the model.

Besides the streaming tick loop above, this module hosts an
**IR-interpreter runtime** (``make_ir_state`` / ``make_ir_train_step``)
executing the planner's round-based schedule families — GPipe, 1F1B
(PipeDream-flush), PipeDream-2BW, and interleaved/virtual-stage 1F1B.
One ``train_step`` call is one flush round (or 2BW accumulation group):
the step walks the IR's compute events in timeline order instead of a
hard-coded fill/steady/drain structure, so the control flow is the
schedule.  Two interchangeable round bodies exist (``backend=``):
the default ``"scan"`` lowers the round to the planner's dense
:class:`~repro.planner.schedule_ir.EventTable` and runs a ``lax.scan``
over its rows with ``lax.switch`` dispatch per (opcode, chunk, lag) —
trace size O(#branches) ≤ O(2·n_chunks), independent of the round's
microbatch count; ``"unrolled"`` inlines every event into the trace
(the original interpreter, kept as the reference oracle the scan
backend is tested bit-identical against).  Per-event weight reads
resolve through the IR — flush
schedules read current weights (their derived staleness is 0), 2BW
reads the previous version from a weight stash whose depth comes from
``Schedule.weight_stash_depth`` (2, the "double buffer"), and
``spectrain`` mode predicts each read forward by that event's derived
version lag (Eq. 4 with s from the IR, not a closed form).  Virtual
stages make ``params["stages"]`` a tuple of ``n_chunks = S·v`` chunk
trees; device d of the S devices hosts chunks ``d, d+S, …``
(``Model.device_chunk_params``).
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import spectrain as st
from repro.models.layers import shard_act
from repro.optim import sgd

MODES = ("vanilla", "pipedream", "spectrain")


def _plan_vectors(S: int, plan):
    """(s_fwd, bwd_lag, fb_gap) per stage — from a planner
    ``PipelinePlan`` when given (IR-derived), else the closed-form
    streaming schedule.

    ``s_fwd``   prediction distance (updates between fwd read and the
                minibatch's own update) — Eq. 4's s;
    ``bwd_lag`` injection→backward ticks, 2(S−1)−k — gates warm-up
                validity and the stage-0 batch-ring read;
    ``fb_gap``  same-stage fwd→backward ticks, 2(S−1−k) — the stash-ring
                gather offsets.

    The runtime's dataflow (one fwd/bwd wave per tick, ring rotation) IS
    the stream schedule, so only stream plans are accepted; the planner
    derives the same vectors by walking IR events, which turns the
    constants below into a checked property.
    """
    if plan is None:
        return ([st.version_difference_stream(k, S, "forward")
                 for k in range(S)],
                [2 * (S - 1) - k for k in range(S)],
                [2 * (S - 1 - k) for k in range(S)])
    if plan.schedule != "stream":
        raise ValueError(
            f"pipeline_stream executes the stream schedule, got a "
            f"{plan.schedule!r} plan (use core.simulator for those)")
    if plan.n_stages != S:
        raise ValueError(f"plan has {plan.n_stages} stages, model has {S}")
    return list(plan.s_fwd), list(plan.bwd_lag), list(plan.fb_gap)


def stage_sizes(model, plan) -> Tuple[int, ...]:
    """Per-stage layer counts this runtime executes.

    Without a plan: the uniform split the model was initialized with.
    With a plan: the plan's partition, validated as an *executable*
    artifact — its layer ranges must tile exactly the model's layers
    across exactly the model's stages (a plan built against a different
    model fails here rather than silently mis-slicing weights).
    """
    S = model.n_stages
    if plan is None:
        return tuple(model.stage_sizes)
    part = plan.partition
    if part.n_stages != plan.n_stages:
        raise ValueError(f"plan partition has {part.n_stages} stages but "
                         f"plan.n_stages={plan.n_stages}")
    if part.n_layers != model.cfg.n_layers:
        raise ValueError(
            f"plan partitions {part.n_layers} layers, model has "
            f"{model.cfg.n_layers}")
    sizes = part.sizes()
    if len(sizes) != S:
        raise ValueError(f"plan has {len(sizes)} stages, model has {S}")
    if min(sizes) < 1:
        raise ValueError(f"plan has an empty stage: sizes={sizes}")
    return sizes


def _ring_write(ring, idx, val):
    """ring leaves [R, ...]; write val at slot idx (traced scalar)."""
    return jax.tree.map(
        lambda r, v: jax.lax.dynamic_update_index_in_dim(
            r, v.astype(r.dtype), idx, 0), ring, val)


def _ring_read(ring, idx):
    return jax.tree.map(
        lambda r: jax.lax.dynamic_index_in_dim(r, idx, 0, keepdims=False),
        ring)


def _per_stage_gather(ring, idx_vec):
    """ring leaves [S, R, ...]; gather slot idx_vec[k] for each stage k."""
    def leaf(r):
        return jax.vmap(
            lambda rk, i: jax.lax.dynamic_index_in_dim(rk, i, 0, False)
        )(r, idx_vec)
    return jax.tree.map(leaf, ring)


def _predict_stages(stage_trees, mom_trees, lr, s_fwd_v):
    """Eq. 4 per stage tree with that stage's (python int) distance."""
    return tuple(
        st.predict_weights(w, v, lr, s)
        for w, v, s in zip(stage_trees, mom_trees, s_fwd_v))


def make_state(model, params, batch_sds, *, mode: str = "spectrain",
               ticks_per_step: int = 1,
               fused_predict: bool = False, plan=None) -> Dict[str, Any]:
    """Streaming train state: params + momentum + in-flight rings.

    ``params`` is the ragged canonical init layout (legacy stacked
    ``[S, Lps, ...]`` trees are accepted too); for S > 1 its stage
    weights are repartitioned to the plan's sizes (the model's default
    split without a plan) — see module docstring.

    ``ticks_per_step``: the global batch is split into this many per-tick
    minibatches; one train_step runs that many ticks via lax.scan (the
    paper injects one minibatch per time unit).

    ``fused_predict``: store the next tick's predicted weights (bf16),
    computed inside the update pass (the kernels/fused_update schedule):
    identical math, but the prediction costs no extra HBM pass and the
    forward reads 2-byte weights."""
    cfg = model.cfg
    S = model.n_stages
    if S == 1:
        return {
            "params": params,
            "momentum": sgd.init(params).v,
            "step": jnp.zeros((), jnp.int32),
        }
    # _plan_vectors and stage_sizes validate the plan (stream schedule,
    # stage count, layer coverage) so a mismatched plan fails here rather
    # than under-sizing the rings or mis-slicing the stage weights that a
    # (plan-less or otherwise) train step later indexes.
    _, lag, gap = _plan_vectors(S, plan)
    sizes = stage_sizes(model, plan)
    params = {"outer": params["outer"],
              "stages": model.partition_stage_params(params["stages"],
                                                     sizes)}
    state: Dict[str, Any] = {
        "params": params,
        "momentum": sgd.init(params).v,
        "step": jnp.zeros((), jnp.int32),
    }
    if fused_predict and mode == "spectrain":
        cdt = jnp.dtype(cfg.compute_dtype)
        state["pred"] = {
            "outer": jax.tree.map(lambda p: p.astype(cdt), params["outer"]),
            "stages": tuple(
                jax.tree.map(lambda p: p.astype(cdt), t)
                for t in params["stages"]),
        }
    R = max(max(lag), max(gap)) + 1
    tok_sds = batch_sds["tokens"]
    B, seq = tok_sds.shape[0], tok_sds.shape[1]
    if B % ticks_per_step:
        raise ValueError(f"global batch {B} not divisible by "
                         f"ticks_per_step={ticks_per_step}")
    mb = B // ticks_per_step
    d = cfg.d_model
    cdt = jnp.dtype(cfg.compute_dtype)
    state.update({
        "tick": jnp.zeros((), jnp.int32),
        "fwd_buf": jnp.zeros((S, mb, seq, d), cdt),
        "bwd_buf": jnp.zeros((S, mb, seq, d), cdt),
        "stash_x": jnp.zeros((S, R, mb, seq, d), cdt),
        "batch_ring": jax.tree.map(
            lambda s: jnp.zeros((R, mb) + tuple(s.shape[1:]), s.dtype),
            batch_sds),
    })
    if mode == "pipedream":
        # per-stage weight rings: leaves [R, ...] mirroring each ragged
        # stage tree (the stacked layout had a single [S, R, ...] ring)
        state["w_stash"] = tuple(
            jax.tree.map(
                lambda p: jnp.broadcast_to(p[None], (R,) + p.shape),
                t)
            for t in params["stages"])
    return state


def init_state(model, key, batch_sds, *, mode: str = "spectrain",
               ticks_per_step: int = 1, plan=None):
    return make_state(model, model.init(key), batch_sds, mode=mode,
                      ticks_per_step=ticks_per_step, plan=plan)


def make_train_step(model, *, mode: str = "spectrain", lr: float,
                    gamma: float = 0.9, clip: Optional[float] = None,
                    ticks_per_step: int = 1, fused_predict: bool = False,
                    bwd_dtype: Optional[str] = None, plan=None) -> Callable:
    """``fused_predict``: prediction computed inside the update pass and
    stored bf16 (see make_state) — same math, one less weight pass/tick.
    ``bwd_dtype``: linearize the backward at weights cast to this dtype
    (e.g. "bfloat16") — gradients and their data-axis all-reduce then move
    half the bytes (standard mixed-precision training).
    ``plan``: optional ``repro.planner.PipelinePlan`` (stream schedule);
    supplies the IR-derived prediction distances and ring offsets in
    place of the closed-form constants, and its partition (validated by
    ``make_state``) determines the ragged stage trees this step
    executes."""
    assert mode in MODES, mode
    fused_predict = fused_predict and mode == "spectrain"
    S = model.n_stages
    s_fwd_v, bwd_lag, fb_gap = _plan_vectors(S, plan)
    if plan is not None:
        stage_sizes(model, plan)   # fail fast on an unexecutable plan
    R = max(max(bwd_lag), max(fb_gap)) + 1
    s_fwd_embed = float(s_fwd_v[0])
    g_vec = jnp.array(fb_gap, jnp.int32)       # stash gather offsets
    lag_vec = jnp.array(bwd_lag, jnp.int32)    # injection -> bwd ticks
    s_fwd_v = [float(s) for s in s_fwd_v]

    def stage_fn(sp, xk):
        xk, aux = model.stage_apply(sp, (xk, jnp.zeros((), jnp.float32)))
        return xk, aux

    # ------------------------------------------------------------- S == 1
    def step_degenerate(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch))(state["params"])
        if clip:
            grads, _ = sgd.clip_by_global_norm(grads, clip)
        params, mom = sgd.update(state["params"],
                                 sgd.MomentumState(state["momentum"]),
                                 grads, lr=lr, gamma=gamma)
        return ({**state, "params": params, "momentum": mom.v,
                 "step": state["step"] + 1},
                {"loss": loss, "loss_valid": jnp.ones((), jnp.float32)})

    if S == 1:
        return step_degenerate

    # ------------------------------------------------------------- S > 1
    def tick_fn(state: Dict[str, Any], batch):
        t = state["tick"]
        params, mom = state["params"], state["momentum"]
        outer, stages = params["outer"], params["stages"]
        mom_outer, mom_stages = mom["outer"], mom["stages"]

        # ---------- forward weights (Eq. 4) ------------------------------
        if fused_predict:
            # prediction was produced by the previous tick's update pass
            stages_f = state["pred"]["stages"]
            outer_embed_f = state["pred"]["outer"]
        elif mode == "spectrain":
            stages_f = _predict_stages(stages, mom_stages, lr, s_fwd_v)
            outer_embed_f = st.predict_weights(outer, mom_outer, lr,
                                               s_fwd_embed)
        else:
            stages_f, outer_embed_f = stages, outer

        # ---------- inject + forward all stages --------------------------
        x_new = model.embed(outer_embed_f, batch)
        A = state["fwd_buf"].at[0].set(x_new)
        A = shard_act(A, "stage", "act_batch", None, None)
        outs = [stage_fn(stages_f[k], A[k]) for k in range(S)]
        out = jnp.stack([o for o, _aux in outs])

        slot = jnp.mod(t, R)
        stash = jax.lax.dynamic_update_index_in_dim(
            state["stash_x"], A, slot, 1)
        batch_ring = _ring_write(state["batch_ring"], slot, batch)

        # ---------- head loss at the last stage ---------------------------
        valid_head = (t >= (S - 1)).astype(jnp.float32)
        tgt = _ring_read(batch_ring, jnp.mod(t - (S - 1), R))["targets"]

        loss, head_vjp = jax.vjp(
            lambda outer_, xlast: model.head_loss(outer_, xlast, tgt),
            outer, out[S - 1])
        g_outer_head, cot_last = head_vjp(valid_head)

        # ---------- backward all stages ------------------------------------
        valid_b = ((t - lag_vec) >= 0)
        B_cot = state["bwd_buf"].at[S - 1].set(cot_last)
        B_cot = B_cot * valid_b[:, None, None, None].astype(B_cot.dtype)
        idx = jnp.mod(t - g_vec, R)
        X_b = _per_stage_gather(stash, idx)
        aux_cot = valid_b.astype(jnp.float32)

        if mode == "pipedream":
            stages_b = tuple(_ring_read(state["w_stash"][k], idx[k])
                             for k in range(S))
        else:
            stages_b = stages
        if bwd_dtype is not None:
            bdt = jnp.dtype(bwd_dtype)
            stages_b = tuple(jax.tree.map(lambda p: p.astype(bdt), t_)
                             for t_ in stages_b)
        gW, gXs = [], []
        for k in range(S):
            _, vjp_k = jax.vjp(stage_fn, stages_b[k], X_b[k])
            gw_k, gx_k = vjp_k((B_cot[k], aux_cot[k]))
            gW.append(gw_k)
            gXs.append(gx_k)
        gX = jnp.stack(gXs)

        # ---------- embed backward -----------------------------------------
        old_batch = _ring_read(batch_ring, jnp.mod(t - lag_vec[0], R))
        _, evjp = jax.vjp(lambda o: model.embed(o, old_batch), outer)
        (g_outer_embed,) = evjp(gX[0] * valid_b[0].astype(gX.dtype))

        g_outer = jax.tree.map(jnp.add, g_outer_head, g_outer_embed)
        grads = {"outer": g_outer, "stages": tuple(gW)}
        if clip:
            grads, _ = sgd.clip_by_global_norm(grads, clip)

        # ---------- per-tick, per-stage update ------------------------------
        new_params, new_mom = sgd.update(
            params, sgd.MomentumState(mom), grads, lr=lr, gamma=gamma)
        new_pred = None
        if fused_predict:
            # Eq. 4 evaluated inside the update pass (the fused_update
            # kernel's schedule): for tick t+1, Ŵ = W_{t+1} − s·η·v_t.
            cdt = jnp.dtype(model.cfg.compute_dtype)
            new_pred = {
                "stages": tuple(
                    jax.tree.map(lambda p: p.astype(cdt), t_)
                    for t_ in _predict_stages(new_params["stages"],
                                              new_mom.v["stages"],
                                              lr, s_fwd_v)),
                "outer": jax.tree.map(
                    lambda p: p.astype(cdt),
                    st.predict_weights(new_params["outer"],
                                       new_mom.v["outer"], lr,
                                       s_fwd_embed)),
            }

        # ---------- rotate in-flight buffers --------------------------------
        A_next = jnp.roll(out, 1, axis=0)
        B_next = jnp.roll(gX, -1, axis=0)

        new_state = {
            **state,
            "params": new_params, "momentum": new_mom.v,
            "step": state["step"] + 1, "tick": t + 1,
            "fwd_buf": A_next, "bwd_buf": B_next,
            "stash_x": stash, "batch_ring": batch_ring,
        }
        if mode == "pipedream":
            new_state["w_stash"] = tuple(
                _ring_write(state["w_stash"][k], slot, stages[k])
                for k in range(S))
        if new_pred is not None:
            new_state["pred"] = new_pred
        return new_state, {"loss": loss, "loss_valid": valid_head}

    if ticks_per_step == 1:
        return tick_fn

    def train_step(state: Dict[str, Any], batch):
        T = ticks_per_step
        mbs = jax.tree.map(
            lambda x: x.reshape((T, x.shape[0] // T) + x.shape[1:]), batch)
        state, mets = jax.lax.scan(tick_fn, state, mbs)
        n = jnp.maximum(jnp.sum(mets["loss_valid"]), 1.0)
        return state, {"loss": jnp.sum(mets["loss"] * mets["loss_valid"]) / n,
                       "loss_valid": n}

    return train_step


# ===========================================================================
# IR-interpreter runtime: round-based schedules (gpipe / 1f1b / 2bw /
# interleaved) executed by walking the planner IR's event timeline
# ===========================================================================

# one source of truth lives next to the emitters (schedule_ir has no
# repro.core imports, so this does not cycle)
from repro.planner import schedule_ir as sir  # noqa: E402
from repro.planner.schedule_ir import ROUND_SCHEDULES as IR_SCHEDULES  # noqa: E402,E501

IR_BACKENDS = ("scan", "unrolled")
EXECS = ("spmd", "mpmd")


def _resolve_execution(execution, legacy, caller: str):
    """One-release back-compat shim for the old builtin-shadowing
    ``exec=`` keyword: resolve ``execution=`` (new) against a legacy
    ``**{"exec": ...}`` catch-all, warning on the old spelling and
    rejecting anything else that landed in the catch-all."""
    unknown = set(legacy) - {"exec"}
    if unknown:
        raise TypeError(f"{caller}() got unexpected keyword arguments "
                        f"{sorted(unknown)}")
    if "exec" in legacy:
        import warnings
        warnings.warn(
            f"{caller}(exec=...) is deprecated; pass execution= "
            f"instead (exec= will be removed next release)",
            DeprecationWarning, stacklevel=3)
        if execution is not None and execution != legacy["exec"]:
            raise TypeError(
                f"{caller}() got both execution={execution!r} and "
                f"legacy exec={legacy['exec']!r}")
        execution = legacy["exec"]
    execution = "spmd" if execution is None else execution
    if execution not in EXECS:
        raise ValueError(
            f"unknown execution {execution!r}; known: {EXECS}")
    return execution


def _mpmd_mesh(mesh, n_devices: int):
    """Resolve/validate the mesh the MPMD path shard_maps over: a
    ``pipe`` axis of exactly ``n_devices`` (one pipeline stage per
    device) and every other axis of size 1 — the path runs pure
    pipeline parallelism; data/tensor axes belong to the SPMD path."""
    from repro.runtime import sharding as rsh

    if mesh is None:
        mesh = rsh.mpmd_pipe_mesh(n_devices)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if sizes.get("pipe") != n_devices:
        raise ValueError(
            f"mpmd needs a mesh with a 'pipe' axis of size {n_devices} "
            f"(one device per pipeline stage), got axes {sizes}")
    extra = {k: v for k, v in sizes.items() if k != "pipe" and v != 1}
    if extra:
        raise ValueError(
            f"mpmd runs pure pipeline parallelism; non-pipe mesh axes "
            f"must have size 1, got {extra}")
    return mesh


def _trace_mark(tracer, dep):
    """Ordered host callback attributing wall time to the just-computed
    event (``repro.obs.trace.PipelineTracer._mark``).

    The callback token carries a data dependence on the event's output,
    so the mark cannot be scheduled before the compute it brackets; with
    ``ordered=True`` the callbacks fire in program order — which is the
    IR's timeline order, so the tracer indexes events by arrival.  Only
    reached when a tracer is installed: the tracer-less trace/jaxpr is
    byte-identical to the uninstrumented interpreter.
    """
    from jax.experimental import io_callback

    leaf = jax.tree.leaves(dep)[0]
    tok = jnp.ravel(leaf)[0]
    io_callback(lambda _t: tracer._mark(), None, tok, ordered=True)


def _unsupported(combo: str, why: str, use: str) -> NotImplementedError:
    """Structured ``NotImplementedError`` for unsupported feature
    combinations: names the combination, the reason it is out of scope,
    and the supported alternative — one fixed shape so every gate reads
    the same (tests pin all three parts)."""
    return NotImplementedError(
        f"unsupported combination: {combo} — {why}; "
        f"supported alternative: {use}")


def _ir_plan_check(model, plan) -> Tuple[int, ...]:
    """Validate a plan as an executable artifact for the IR interpreter;
    returns the per-chunk layer counts."""
    if plan is None:
        raise ValueError("the IR-interpreter runtime needs a plan "
                         "(repro.planner.plan(..., schedule='1f1b'|...))")
    if plan.schedule not in IR_SCHEDULES:
        raise ValueError(
            f"IR interpreter executes {IR_SCHEDULES}, got a "
            f"{plan.schedule!r} plan (the stream schedule runs through "
            f"make_train_step)")
    if plan.n_stages != model.n_stages:
        raise ValueError(f"plan has {plan.n_stages} device stages, model "
                         f"has {model.n_stages}")
    part = plan.partition
    if part.n_layers != model.cfg.n_layers:
        raise ValueError(f"plan partitions {part.n_layers} layers, model "
                         f"has {model.cfg.n_layers}")
    sizes = part.sizes()
    if len(sizes) != plan.n_chunks:
        raise ValueError(f"plan has {len(sizes)} chunk-stages, expected "
                         f"{plan.n_chunks} (= {plan.n_stages} stages × "
                         f"{plan.virtual_stages} virtual)")
    if min(sizes) < 1:
        raise ValueError(f"plan has an empty chunk-stage: sizes={sizes}")
    if plan.round_microbatches < 1:
        raise ValueError(f"plan carries no round size "
                         f"(round_microbatches={plan.round_microbatches})")
    depth = max(plan.w_stash_depth) if plan.w_stash_depth else 1
    if depth > 2:
        raise _unsupported(
            f"a {plan.schedule!r} plan with IR-derived weight-stash "
            f"depth {depth}",
            "the interpreter implements only single-buffer and 2BW "
            "double-buffer weight reads (depth <= 2)",
            "a schedule whose IR derives depth <= 2 (1f1b, gpipe, "
            "interleaved, 2bw)")
    return sizes


def _round_program(plan):
    """One canonical round of compute events, in timeline order.

    Each entry is ``(kind, local_mb, chunk_stage, s)`` with ``s`` the
    IR-derived version lag of that event's weight read (the per-(stage,
    microbatch) SpecTrain prediction distance).  Flush schedules use
    round 0; 2BW uses a steady accumulation group (every group executes
    identically under the double-buffer rotation) — the base selection
    and extraction live on the plan (``PipelinePlan.round_program``)."""
    return plan.round_program()


def make_ir_state(model, params, batch_sds, *, plan,
                  mode: str = "spectrain",
                  execution: Optional[str] = None,
                  mesh=None, verify: bool = True,
                  **legacy) -> Dict[str, Any]:
    """Train state for the IR interpreter: chunked params + momentum
    (+ the 2BW double buffer when the IR derives a stash depth of 2).

    ``params`` is the ragged canonical init layout (legacy stacked
    trees are accepted); its stage weights are repartitioned into
    ``plan.n_chunks`` ragged chunk trees by the plan's partition
    (virtual stages give a device several chunk trees —
    ``Model.device_chunk_params`` recovers the per-device grouping).
    Unlike the streaming runtime there are no activation rings: the
    interpreter's in-flight activations live inside one traced round,
    sized by the schedule itself (peak = ``plan.act_stash``).

    ``execution="mpmd"`` builds the packed stage-local layout instead: the
    ragged chunk trees are zero-padded and stacked into ``[v, S, Lmax,
    ...]`` leaves (``models.model.pack_chunk_params``) and device_put
    with ``P(None, 'pipe')`` on ``mesh`` (default: the first S local
    devices), so chunk ``q``'s weights/momentum/stash live *only* on
    pipe device ``q % S`` — per-device parameter memory drops to
    ~1/S.  The state additionally carries ``chunk_sizes`` (the ragged
    per-chunk layer counts, for unpacking/checkpoint migration).
    """
    assert mode in MODES, mode
    execution = _resolve_execution(execution, legacy, "make_ir_state")
    del batch_sds  # interpreter state holds no rings; shape-agnostic
    sizes = _ir_plan_check(model, plan)
    if verify:
        plan.verify()   # static artifact verification (planner/verify.py)
    chunks = model.partition_stage_params(params["stages"], sizes,
                                          n_chunks=plan.n_chunks)
    if execution == "mpmd":
        from repro.models.model import pack_chunk_params
        from repro.runtime import sharding as rsh

        if model.hybrid:
            raise _unsupported(
                "execution='mpmd' with a hybrid SSM/attention model",
                "per-stage 'shared' blocks have no flat layer order to "
                "pack into the [v, S, Lmax] stage-local layout",
                "execution='spmd' (runs hybrid models with every "
                "schedule)")
        mesh = _mpmd_mesh(mesh, plan.n_devices)
        packed, psizes = pack_chunk_params(chunks, plan.n_devices)
        assert psizes == tuple(sizes), (psizes, sizes)
        pparams = {"outer": params["outer"], "stages": packed}
        state: Dict[str, Any] = {
            "params": pparams,
            "momentum": sgd.init(pparams).v,
            "step": jnp.zeros((), jnp.int32),
            "chunk_sizes": jnp.asarray(sizes, jnp.int32),
        }
        if max(plan.w_stash_depth) > 1:
            state["stash"] = {
                "params": jax.tree.map(jnp.array, pparams),
                "momentum": jax.tree.map(jnp.array, state["momentum"]),
            }
        return jax.device_put(state, rsh.mpmd_state_shardings(mesh, state))
    params = {"outer": params["outer"], "stages": chunks}
    state = {
        "params": params,
        "momentum": sgd.init(params).v,
        "step": jnp.zeros((), jnp.int32),
    }
    if max(plan.w_stash_depth) > 1:
        # 2BW: reads are pinned one version back; stash starts equal to
        # params (version 0 reads version 0 — the IR's warm-up truncation)
        state["stash"] = {
            "params": jax.tree.map(jnp.array, params),
            "momentum": jax.tree.map(jnp.array, state["momentum"]),
        }
    return state


def make_ir_train_step(model, *, plan, mode: str = "spectrain", lr: float,
                       gamma: float = 0.9, clip: Optional[float] = None,
                       backend: str = "scan", tracer=None,
                       execution: Optional[str] = None, mesh=None,
                       verify: bool = True, **legacy) -> Callable:
    """Schedule-driven step: one call executes one flush round (gpipe /
    1f1b / interleaved) or one 2BW accumulation group of
    ``plan.round_microbatches`` microbatches, by interpreting the IR's
    compute events in timeline order.

    Weight reads per event:

      flush schedules   current weights — no update lands inside a round,
                        so every mode coincides (IR staleness 0)
      2bw               the stashed previous version (the double buffer);
                        ``spectrain`` predicts it forward by the event's
                        IR-derived lag (s = 1): Ŵ = W_prev − s·η·v_prev

    The gradient is the mean over the round's microbatches; the update
    applies once per round to current params (2BW then rotates the
    double buffer).

    ``backend`` selects how the round body is built:

      scan       (default) ``lax.scan`` over the plan's dense
                 :class:`~repro.planner.schedule_ir.EventTable`, one row
                 per compute event, dispatched by ``lax.switch`` over
                 the table's (opcode, chunk, lag) branches — trace size
                 O(#branches) ≤ 2·n_chunks, independent of M, so rounds
                 with M·C ≫ 100 compile in constant time
      unrolled   every compute event inlined into the trace (the
                 original interpreter) — O(M·C) trace, kept as the
                 reference oracle; ``tests/test_ir_scan.py`` pins the
                 scan backend bit-identical to it

    Both backends accumulate gradients, losses and the outer tree in
    the same timeline order, so they are bitwise interchangeable.

    ``tracer`` (a ``repro.obs.trace.PipelineTracer``) instruments the
    round: the unrolled body wraps every event in a ``jax.named_scope``
    and both bodies end each event with an ordered host-timestamp
    callback (``_trace_mark``), which the tracer turns into per-(device,
    event) spans.  ``tracer=None`` (the default) adds nothing to the
    trace — the step stays byte-identical to the untraced interpreter.

    ``execution`` selects the execution model: ``"spmd"`` (default) runs the
    round as one replicated program (stage weights visible everywhere,
    GSPMD free to shard); ``"mpmd"`` runs each device's tick stream
    inside a ``shard_map`` over ``mesh``'s ``pipe`` axis against
    stage-*local* packed weights, moving activations/cotangents across
    the stage cuts via ``ppermute`` (see :func:`_make_mpmd_step`) —
    bitwise-identical losses and state leaves, ~1/S per-device weight
    memory.  ``backend`` applies to the SPMD path only; mpmd requires
    the matching ``make_ir_state(..., execution="mpmd")`` packed state and
    refuses ``clip`` and hybrid models.

    ``verify=True`` (the default) statically verifies the plan's
    compiled artifacts before building the step — slot dataflow, ring
    comm matching, closed-form staleness, completeness, exact resource
    bounds (``planner/verify.py``); ``verify=False`` skips it (the
    launcher's ``--no-verify``).
    """
    assert mode in MODES, mode
    if backend not in IR_BACKENDS:
        raise ValueError(
            f"unknown IR backend {backend!r}; known: {IR_BACKENDS}")
    execution = _resolve_execution(execution, legacy,
                                   "make_ir_train_step")
    if verify and plan is not None and plan.schedule in IR_SCHEDULES:
        plan.verify()   # static artifact verification (planner/verify.py)
    if execution == "mpmd":
        if clip:
            raise _unsupported(
                "execution='mpmd' with clip_by_global_norm",
                "the global norm's canonical-order reduction is not "
                "bit-reproducible on the packed stage layout",
                "execution='spmd' with clip, or execution='mpmd' with "
                "clip=None")
        if model.hybrid:
            raise _unsupported(
                "execution='mpmd' with a hybrid SSM/attention model",
                "per-stage 'shared' blocks have no flat layer order to "
                "pack into the [v, S, Lmax] stage-local layout",
                "execution='spmd' (runs hybrid models with every "
                "schedule)")
        return _make_mpmd_step(model, plan=plan, mode=mode, lr=lr,
                               gamma=gamma, tracer=tracer, mesh=mesh)
    sizes = _ir_plan_check(model, plan)
    del sizes
    prog = _round_program(plan)
    C = plan.n_chunks
    M = plan.round_microbatches
    two_buf = max(plan.w_stash_depth) > 1
    table = (sir.compile_event_table(prog, C, M) if backend == "scan"
             else None)

    def stage_fn(sp, xk):
        xk, aux = model.stage_apply(sp, (xk, jnp.zeros((), jnp.float32)))
        return xk, aux

    def step(state: Dict[str, Any], batch):
        B = jax.tree.leaves(batch)[0].shape[0]
        # ValueError, not assert: these invariants guard user-supplied
        # shapes and must survive `python -O`
        if B % M:
            raise ValueError(
                f"batch {B} not divisible by the {plan.schedule!r} plan's "
                f"round size (round_microbatches={M})")
        mbs = jax.tree.map(
            lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]), batch)
        mb = lambda m: jax.tree.map(lambda x: x[m], mbs)

        params, mom = state["params"], state["momentum"]
        if two_buf:
            base_p, base_m = state["stash"]["params"], \
                state["stash"]["momentum"]
        else:
            base_p, base_m = params, mom

        # per-(chunk, lag) read-weight cache: the IR drives prediction —
        # flush events carry s = 0 (no-op), 2BW events s = 1
        cache: Dict[Tuple[str, int], Any] = {}

        def chunk_w(q, s):
            key = ("c%d" % q, s)
            if key not in cache:
                w = base_p["stages"][q]
                if mode == "spectrain" and s > 0:
                    w = st.predict_weights(w, base_m["stages"][q], lr,
                                           float(s))
                cache[key] = w
            return cache[key]

        def outer_w(s):
            key = ("outer", s)
            if key not in cache:
                w = base_p["outer"]
                if mode == "spectrain" and s > 0:
                    w = st.predict_weights(w, base_m["outer"], lr, float(s))
                cache[key] = w
            return cache[key]

        # ------------------------------------------------ unrolled body
        def unrolled_round():
            acts: Dict[Tuple[int, int], Any] = {}  # (m, q) -> chunk input
            outs: Dict[Tuple[int, int], Any] = {}  # (m, q) -> chunk output
            cots: Dict[Tuple[int, int], Any] = {}  # (m, q) -> out cotangent
            g_chunks = [None] * C
            # the outer grad runs as two independent accumulators (head
            # contributions at chunk C-1, embed contributions at chunk
            # 0) combined once after the round — the association the
            # MPMD backend reproduces without per-event cross-device
            # traffic (head and embed live on different devices)
            g_out_h = g_out_e = None
            losses = []

            def acc(a, g):
                return g if a is None else jax.tree.map(jnp.add, a, g)

            for kind, m, q, s in prog:
                scope = (jax.named_scope(f"{kind}/m{m}/q{q}/s{s}")
                         if tracer is not None else contextlib.nullcontext())
                with scope:
                    if kind == "fwd":
                        x = model.embed(outer_w(s), mb(m)) if q == 0 \
                            else outs.pop((m, q - 1))
                        acts[(m, q)] = x
                        out, _aux = stage_fn(chunk_w(q, s), x)
                        outs[(m, q)] = out
                        dep = out
                    else:
                        if q == C - 1:
                            tgt = mb(m)["targets"]
                            loss_m, head_vjp = jax.vjp(
                                lambda o, xl: model.head_loss(o, xl, tgt),
                                outer_w(s), outs.pop((m, q)))
                            go_head, cot = head_vjp(
                                jnp.ones((), loss_m.dtype))
                            g_out_h = acc(g_out_h, go_head)
                            losses.append(loss_m)
                        else:
                            cot = cots.pop((m, q + 1))
                        _, vjp_q = jax.vjp(stage_fn, chunk_w(q, s),
                                           acts.pop((m, q)))
                        gw, gx = vjp_q((cot, jnp.ones((), jnp.float32)))
                        g_chunks[q] = acc(g_chunks[q], gw)
                        if q == 0:
                            _, evjp = jax.vjp(
                                lambda o: model.embed(o, mb(m)),
                                outer_w(s))
                            (go_embed,) = evjp(gx)
                            g_out_e = acc(g_out_e, go_embed)
                        else:
                            cots[(m, q)] = gx
                        dep = gx
                if tracer is not None:
                    _trace_mark(tracer, dep)
            if acts or outs or cots:
                raise ValueError(
                    f"{plan.schedule!r} round program (round size {M}) "
                    f"left in-flight tensors: "
                    f"{sorted(acts) + sorted(outs) + sorted(cots)}")
            g_outer = jax.tree.map(jnp.add, g_out_h, g_out_e)
            return g_outer, tuple(g_chunks), sum(losses) / len(losses)

        # ---------------------------------------------------- scan body
        def scan_round():
            # activation/cotangent pools: uniform [n_slots, mb, seq, d]
            # rings indexed by the table's register-allocated slots
            # (d_model is constant at every cut, so one buffer serves
            # all chunks; weights stay ragged per-chunk trees)
            as_sds = lambda t: jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
            mb_sds = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), mbs)
            x_sd = jax.eval_shape(model.embed, as_sds(base_p["outer"]),
                                  mb_sds)
            out_sd, _ = jax.eval_shape(stage_fn,
                                       as_sds(base_p["stages"][0]), x_sd)
            if (out_sd.shape, out_sd.dtype) != (x_sd.shape, x_sd.dtype):
                raise ValueError(
                    f"scan backend needs one uniform activation pool, got "
                    f"embed {x_sd.shape}/{x_sd.dtype} vs stage "
                    f"{out_sd.shape}/{out_sd.dtype}")
            loss_sd = jax.eval_shape(model.head_loss,
                                     as_sds(base_p["outer"]), out_sd,
                                     mb_sds["targets"])

            def first_or_add(acc, g, first):
                # bit-compat with the unrolled body's None-then-assign
                # accumulator: the first contribution must be g itself,
                # not 0 + g (which flips the sign bit of exact -0.0s)
                return jax.tree.map(
                    lambda a, gg: jnp.where(first, gg, a + gg), acc, g)

            def fwd_branch(q, s):
                W, Wo = chunk_w(q, s), outer_w(s)

                def br(carry, row):
                    P, Q, gs, goh, goe, ls = carry
                    m = row[sir.COL_MB]
                    if q == 0:
                        x = model.embed(Wo, mb(m))
                        P = jax.lax.dynamic_update_index_in_dim(
                            P, x, row[sir.COL_A], 0)
                    else:
                        x = jax.lax.dynamic_index_in_dim(
                            P, row[sir.COL_A], 0, keepdims=False)
                    out, _aux = stage_fn(W, x)
                    P = jax.lax.dynamic_update_index_in_dim(
                        P, out, row[sir.COL_B], 0)
                    return (P, Q, gs, goh, goe, ls)
                return br

            def bwd_branch(q, s):
                W, Wo = chunk_w(q, s), outer_w(s)

                def br(carry, row):
                    P, Q, gs, goh, goe, ls = carry
                    first_g = row[sir.COL_FIRST_G] > 0
                    m = row[sir.COL_MB]
                    x = jax.lax.dynamic_index_in_dim(
                        P, row[sir.COL_A], 0, keepdims=False)
                    if q == C - 1:
                        out = jax.lax.dynamic_index_in_dim(
                            P, row[sir.COL_B], 0, keepdims=False)
                        tgt = mb(m)["targets"]
                        loss_m, head_vjp = jax.vjp(
                            lambda o, xl: model.head_loss(o, xl, tgt),
                            Wo, out)
                        go_head, cot = head_vjp(jnp.ones((), loss_m.dtype))
                        goh = first_or_add(goh, go_head,
                                           row[sir.COL_FIRST_O] > 0)
                        ls = ls + loss_m
                    else:
                        cot = jax.lax.dynamic_index_in_dim(
                            Q, row[sir.COL_B], 0, keepdims=False)
                    _, vjp_q = jax.vjp(stage_fn, W, x)
                    gw, gx = vjp_q((cot, jnp.ones((), jnp.float32)))
                    gs = tuple(
                        first_or_add(t, gw, first_g) if i == q else t
                        for i, t in enumerate(gs))
                    if q == 0:
                        _, evjp = jax.vjp(lambda o: model.embed(o, mb(m)),
                                          Wo)
                        (go_embed,) = evjp(gx)
                        goe = first_or_add(goe, go_embed,
                                           row[sir.COL_FIRST_E] > 0)
                    else:
                        Q = jax.lax.dynamic_update_index_in_dim(
                            Q, gx, row[sir.COL_C], 0)
                    return (P, Q, gs, goh, goe, ls)
                return br

            branches = [fwd_branch(q, s) if kind == "fwd"
                        else bwd_branch(q, s)
                        for kind, q, s in table.branches]

            def body(carry, row):
                carry = jax.lax.switch(row[sir.COL_BRANCH], branches,
                                       carry, row)
                if tracer is not None:
                    # token touches both pools and the loss accumulator
                    # so the mark trails this row's writes
                    P, Q, _gs, _goh, _goe, ls = carry
                    _trace_mark(
                        tracer,
                        ls + (P.ravel()[0] + Q.ravel()[0]).astype(ls.dtype)
                        * 0)
                return carry, None

            carry0 = (
                jnp.zeros((table.n_val_slots,) + x_sd.shape, x_sd.dtype),
                jnp.zeros((max(table.n_cot_slots, 1),) + x_sd.shape,
                          x_sd.dtype),
                jax.tree.map(jnp.zeros_like, params["stages"]),
                jax.tree.map(jnp.zeros_like, params["outer"]),
                jax.tree.map(jnp.zeros_like, params["outer"]),
                jnp.zeros((), loss_sd.dtype),
            )
            (_, _, g_chunks, go_h, go_e, loss_sum), _ = jax.lax.scan(
                body, carry0, jnp.asarray(table.rows))
            g_outer = jax.tree.map(jnp.add, go_h, go_e)
            return g_outer, g_chunks, loss_sum / M

        g_outer, g_chunks, loss = (scan_round if backend == "scan"
                                   else unrolled_round)()
        grads = {"outer": g_outer, "stages": tuple(g_chunks)}
        grads = jax.tree.map(lambda g: g / M, grads)
        if clip:
            grads, _ = sgd.clip_by_global_norm(grads, clip)
        new_params, new_mom = sgd.update(
            params, sgd.MomentumState(mom), grads, lr=lr, gamma=gamma)
        new_state = {
            **state,
            "params": new_params, "momentum": new_mom.v,
            "step": state["step"] + 1,
        }
        if two_buf:
            new_state["stash"] = {"params": params, "momentum": mom}
        return new_state, {"loss": loss,
                           "loss_valid": jnp.ones((), jnp.float32)}

    return step


# ===========================================================================
# MPMD execution path: stage-local weights via shard_map, activations
# and cotangents crossing the stage cuts via ppermute ring transfers
# ===========================================================================

def _make_mpmd_step(model, *, plan, mode, lr, gamma, tracer, mesh):
    """True MPMD round body: one ``shard_map`` over the ``pipe`` axis
    runs each device's tick stream (:meth:`PipelinePlan.device_streams`)
    against its *local* packed weight shard.

    Per tick every device (1) ``lax.switch``-dispatches its row's branch
    — a (kind, chunk, lag) compute event or the NOP — reading/writing
    its private activation/cotangent slot pools and statically slicing
    its own chunks out of the packed ``[v, 1, Lmax, ...]`` shard, then
    (2) the whole mesh runs two ``ppermute`` rings (forward ring
    ``d -> d+1`` carries the tick's stage outputs, backward ring
    ``d -> d-1`` the cotangents) and (3) parks the received payload in
    the slot its row names (or a trash slot on idle ticks, so the
    program stays SPMD-uniform while the *execution* is MPMD: different
    devices run different branches each tick).

    Bitwise parity with the SPMD interpreters is by construction, not
    tolerance: a device's stream preserves the global timeline order of
    its own chunks' events, so every per-chunk gradient accumulates in
    scan order; the outer gradient runs as the same two head/embed
    accumulators the SPMD bodies use (head contributions live on device
    ``(C-1) % S``, embed on device 0) combined once outside the
    shard_map by *static indexing* of the per-device partials — no
    psum, whose identity-element adds would flip -0.0 bits.  The update
    itself is elementwise on the packed layout (padding rows stay
    exactly zero), so unpacking the new state reproduces the SPMD state
    leaves byte-for-byte.

    With a ``tracer`` the tick loop is unrolled into one *individually
    jitted* shard_map call per tick, executed eagerly with a blocking
    host mark between calls (``io_callback`` is not safe inside
    shard_map, and an ordered callback's token breaks XLA sharding
    propagation for explicitly-sharded entry parameters) — so the
    traced step must NOT be wrapped in an outer ``jax.jit``, and
    attribution is tick-granular: install the groups from
    ``obs.trace.device_stream_tick_groups`` on the tracer.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    sizes = _ir_plan_check(model, plan)
    streams = plan.device_streams()
    C, M, S = plan.n_chunks, plan.round_microbatches, plan.n_devices
    T = streams.rows.shape[0]
    two_buf = max(plan.w_stash_depth) > 1
    mesh = _mpmd_mesh(mesh, S)
    d_head = (C - 1) % S
    nv, nc = streams.n_val_slots, streams.n_cot_slots
    lags = sorted({s for _k, _q, s in streams.branches})
    rows = jnp.asarray(streams.rows)          # [T, S, DN_COLS]
    fwd_perm = [(i, (i + 1) % S) for i in range(S)]
    bwd_perm = [(i, (i - 1) % S) for i in range(S)]

    def stage_fn(sp, xk):
        xk, aux = model.stage_apply(sp, (xk, jnp.zeros((), jnp.float32)))
        return xk, aux

    def _pre(state, batch):
        """Round prologue: microbatch split + per-lag weight reads.

        Prediction (Eq. 4) is elementwise, so predicting the whole
        packed tree equals the SPMD per-chunk prediction bit-for-bit
        (padding stays zero).  One read per distinct lag, *outside*
        the shard_map."""
        mbs = jax.tree.map(
            lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]), batch)
        if two_buf:
            base_p, base_m = state["stash"]["params"], \
                state["stash"]["momentum"]
        else:
            base_p, base_m = state["params"], state["momentum"]
        stage_rd = {}
        outer_rd = {}
        for s in lags:
            if mode == "spectrain" and s > 0:
                stage_rd[s] = st.predict_weights(
                    base_p["stages"], base_m["stages"], lr, float(s))
                outer_rd[s] = st.predict_weights(
                    base_p["outer"], base_m["outer"], lr, float(s))
            else:
                stage_rd[s] = base_p["stages"]
                outer_rd[s] = base_p["outer"]
        return mbs, stage_rd, outer_rd

    def _post(state, gs_g, goh_g, goe_g, ls_g):
        """Round epilogue: combine the per-device outer partials by
        *static indexing* (head lives on device (C-1)%S, embed on
        device 0) — the one cross-device add of the round, in the same
        head+embed order as the SPMD bodies (a psum would add identity
        elements and flip -0.0 bits) — then apply the SGD update."""
        params, mom = state["params"], state["momentum"]
        go = jax.tree.map(lambda h, e: h[d_head] + e[0], goh_g, goe_g)
        loss = ls_g[d_head] / M
        grads = {"outer": go, "stages": gs_g}
        grads = jax.tree.map(lambda g: g / M, grads)
        new_params, new_mom = sgd.update(
            params, sgd.MomentumState(mom), grads, lr=lr, gamma=gamma)
        new_state = {
            **state,
            "params": new_params, "momentum": new_mom.v,
            "step": state["step"] + 1,
        }
        if two_buf:
            new_state["stash"] = {"params": params, "momentum": mom}
        return new_state, {"loss": loss,
                           "loss_valid": jnp.ones((), jnp.float32)}

    _jits: dict = {}   # traced path: cached pre / per-tick / post jits

    def step(state: Dict[str, Any], batch):
        B = jax.tree.leaves(batch)[0].shape[0]
        if B % M:
            raise ValueError(
                f"batch {B} not divisible by the {plan.schedule!r} plan's "
                f"round size (round_microbatches={M})")
        base_p = state["stash"]["params"] if two_buf \
            else state["params"]

        as_sds = lambda t: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
        mb_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                (x.shape[0] // M,) + x.shape[1:], x.dtype), batch)
        x_sd = jax.eval_shape(model.embed, as_sds(base_p["outer"]), mb_sds)
        chunk0_sds = {"layers": jax.tree.map(
            lambda a: jax.ShapeDtypeStruct((sizes[0],) + a.shape[3:],
                                           a.dtype),
            base_p["stages"]["layers"])}
        out_sd, _ = jax.eval_shape(stage_fn, chunk0_sds, x_sd)
        if (out_sd.shape, out_sd.dtype) != (x_sd.shape, x_sd.dtype):
            raise ValueError(
                f"mpmd needs one uniform activation/transfer shape, got "
                f"embed {x_sd.shape}/{x_sd.dtype} vs stage "
                f"{out_sd.shape}/{out_sd.dtype}")
        loss_sd = jax.eval_shape(model.head_loss, as_sds(base_p["outer"]),
                                 out_sd, mb_sds["targets"])
        zeros_x = lambda: jnp.zeros(x_sd.shape, x_sd.dtype)

        def make_tick(mbs_l, srd_l, ord_l):
            """The shared per-tick body, closed over a device's *local*
            views: replicated microbatches, the packed weight shard
            ``[v, 1, Lmax, ...]`` per lag, the replicated outer reads."""
            mb = lambda m: jax.tree.map(lambda x: x[m], mbs_l)

            def chunk_of(s, q):
                j, Lq = q // S, sizes[q]
                return {"layers": jax.tree.map(
                    lambda a: a[j, 0, :Lq], srd_l[s]["layers"])}

            def first_or_add(acc, g, first):
                return jax.tree.map(
                    lambda a, gg: jnp.where(first, gg, a + gg), acc, g)

            def gs_acc(gs, gw, q, first):
                # static in-place accumulate of chunk q's ragged grad
                # into the packed local shard (padding rows untouched)
                j, Lq = q // S, sizes[q]

                def leaf(a, g):
                    cur = a[j, 0, :Lq]
                    return a.at[j, 0, :Lq].set(jnp.where(first, g, cur + g))

                return {"layers": jax.tree.map(leaf, gs["layers"],
                                               gw["layers"])}

            def mk_fwd(q, s):
                def br(carry, row):
                    V, Ct, gs, goh, goe, ls = carry
                    m = row[sir.DCOL_MB]
                    if q == 0:
                        x = model.embed(ord_l[s], mb(m))
                        V = jax.lax.dynamic_update_index_in_dim(
                            V, x, row[sir.DCOL_A], 0)
                    else:
                        x = jax.lax.dynamic_index_in_dim(
                            V, row[sir.DCOL_A], 0, keepdims=False)
                    out, _aux = stage_fn(chunk_of(s, q), x)
                    if q == C - 1:
                        V = jax.lax.dynamic_update_index_in_dim(
                            V, out, row[sir.DCOL_B], 0)
                        sf = zeros_x()
                    else:
                        sf = out
                    return (V, Ct, gs, goh, goe, ls), sf, zeros_x()
                return br

            def mk_bwd(q, s):
                def br(carry, row):
                    V, Ct, gs, goh, goe, ls = carry
                    m = row[sir.DCOL_MB]
                    x = jax.lax.dynamic_index_in_dim(
                        V, row[sir.DCOL_A], 0, keepdims=False)
                    if q == C - 1:
                        out = jax.lax.dynamic_index_in_dim(
                            V, row[sir.DCOL_B], 0, keepdims=False)
                        tgt = mb(m)["targets"]
                        loss_m, head_vjp = jax.vjp(
                            lambda o, xl: model.head_loss(o, xl, tgt),
                            ord_l[s], out)
                        go_head, cot = head_vjp(jnp.ones((), loss_m.dtype))
                        goh = first_or_add(goh, go_head,
                                           row[sir.DCOL_FIRST_O] > 0)
                        ls = ls + loss_m
                    else:
                        cot = jax.lax.dynamic_index_in_dim(
                            Ct, row[sir.DCOL_C], 0, keepdims=False)
                    _, vjp_q = jax.vjp(stage_fn, chunk_of(s, q), x)
                    gw, gx = vjp_q((cot, jnp.ones((), jnp.float32)))
                    gs = gs_acc(gs, gw, q, row[sir.DCOL_FIRST_G] > 0)
                    if q == 0:
                        _, evjp = jax.vjp(lambda o: model.embed(o, mb(m)),
                                          ord_l[s])
                        (go_embed,) = evjp(gx)
                        goe = first_or_add(goe, go_embed,
                                           row[sir.DCOL_FIRST_E] > 0)
                        sb = zeros_x()
                    else:
                        sb = gx
                    return (V, Ct, gs, goh, goe, ls), zeros_x(), sb
                return br

            branches = [mk_fwd(q, s) if kind == "fwd" else mk_bwd(q, s)
                        for kind, q, s in streams.branches]
            branches.append(
                lambda carry, row: (carry, zeros_x(), zeros_x()))

            def tick(carry, row):
                carry, sf, sb = jax.lax.switch(
                    row[sir.DCOL_BRANCH], branches, carry, row)
                # both rings run every tick (idle devices carry the
                # NOP's garbage payload into a trash slot) so the
                # program stays SPMD while the execution is MPMD
                rf = jax.lax.ppermute(sf, "pipe", fwd_perm) if S > 1 \
                    else sf
                rb = jax.lax.ppermute(sb, "pipe", bwd_perm) if S > 1 \
                    else sb
                V, Ct, gs, goh, goe, ls = carry
                V = jax.lax.dynamic_update_index_in_dim(
                    V, rf, jnp.where(row[sir.DCOL_RECV_F] >= 0,
                                     row[sir.DCOL_RECV_F], nv), 0)
                Ct = jax.lax.dynamic_update_index_in_dim(
                    Ct, rb, jnp.where(row[sir.DCOL_RECV_B] >= 0,
                                      row[sir.DCOL_RECV_B], nc), 0)
                return (V, Ct, gs, goh, goe, ls)
            return tick

        def local_carry0(srd_l, ord_l):
            return (
                jnp.zeros((nv + 1,) + x_sd.shape, x_sd.dtype),
                jnp.zeros((nc + 1,) + x_sd.shape, x_sd.dtype),
                jax.tree.map(jnp.zeros_like, srd_l[lags[0]]),
                jax.tree.map(jnp.zeros_like, ord_l[lags[0]]),
                jax.tree.map(jnp.zeros_like, ord_l[lags[0]]),
                jnp.zeros((), loss_sd.dtype),
            )

        expand = lambda t: jax.tree.map(lambda x: x[None], t)

        if tracer is None:
            mbs, stage_rd, outer_rd = _pre(state, batch)

            def round_body(rows_l, mbs_l, srd_l, ord_l):
                tick = make_tick(mbs_l, srd_l, ord_l)

                def body(carry, row):
                    return tick(carry, row[0]), None

                (_V, _Ct, gs, goh, goe, ls), _ = jax.lax.scan(
                    body, local_carry0(srd_l, ord_l), rows_l)
                return gs, expand(goh), expand(goe), ls[None]

            run = shard_map(
                round_body, mesh=mesh,
                in_specs=(P(None, "pipe", None), P(), P(None, "pipe"),
                          P()),
                out_specs=(P(None, "pipe"), P("pipe"), P("pipe"),
                           P("pipe")),
                check_rep=False)
            gs_g, goh_g, goe_g, ls_g = run(rows, mbs, stage_rd, outer_rd)
            return _post(state, gs_g, goh_g, goe_g, ls_g)
        else:
            # tick-unrolled: one jitted shard_map per tick, a blocking
            # host mark between calls — io_callback is not safe inside
            # shard_map, and an ordered callback's token breaks XLA
            # sharding propagation with explicitly-sharded parameters,
            # so the traced round runs *eagerly* (per-tick jit, cached
            # after the first call).  Device-local carries cross the
            # calls as pipe-sharded globals (pools/outer partials gain
            # a leading [S] axis).
            if isinstance(jax.tree.leaves(state)[0], jax.core.Tracer):
                raise ValueError(
                    "the traced mpmd step measures real per-tick wall "
                    "time and must not be wrapped in an outer jax.jit "
                    "— call it eagerly (it jits each tick internally)")
            if not _jits:
                def tick_body(row_l, mbs_l, srd_l, ord_l,
                              V_l, Ct_l, gs, goh_l, goe_l, ls_l):
                    tick = make_tick(mbs_l, srd_l, ord_l)
                    carry = (V_l[0], Ct_l[0], gs,
                             jax.tree.map(lambda x: x[0], goh_l),
                             jax.tree.map(lambda x: x[0], goe_l),
                             ls_l[0])
                    V, Ct, gs, goh, goe, ls = tick(carry, row_l[0])
                    return (V[None], Ct[None], gs, expand(goh),
                            expand(goe), ls[None])

                _jits["tick"] = jax.jit(shard_map(
                    tick_body, mesh=mesh,
                    in_specs=(P("pipe", None), P(), P(None, "pipe"),
                              P(), P("pipe"), P("pipe"),
                              P(None, "pipe"), P("pipe"), P("pipe"),
                              P("pipe")),
                    out_specs=(P("pipe"), P("pipe"), P(None, "pipe"),
                               P("pipe"), P("pipe"), P("pipe")),
                    check_rep=False), donate_argnums=(4, 5, 6, 7, 8, 9))
                # the prologue and epilogue run under their own jits:
                # eager op-by-op execution would skip the FMA fusion
                # XLA applies inside the untraced step's single jit and
                # break bitwise parity with it
                _jits["pre"] = jax.jit(_pre)
                _jits["post"] = jax.jit(_post)
            mbs, stage_rd, outer_rd = _jits["pre"](state, batch)
            run = _jits["tick"]
            Vg = jnp.zeros((S, nv + 1) + x_sd.shape, x_sd.dtype)
            Cg = jnp.zeros((S, nc + 1) + x_sd.shape, x_sd.dtype)
            gs_g = jax.tree.map(jnp.zeros_like, stage_rd[lags[0]])
            big = lambda t: jax.tree.map(
                lambda x: jnp.zeros((S,) + x.shape, x.dtype), t)
            goh_g, goe_g = big(outer_rd[lags[0]]), big(outer_rd[lags[0]])
            ls_g = jnp.zeros((S,), loss_sd.dtype)
            for t in range(T):
                Vg, Cg, gs_g, goh_g, goe_g, ls_g = run(
                    rows[t], mbs, stage_rd, outer_rd,
                    Vg, Cg, gs_g, goh_g, goe_g, ls_g)
                jax.block_until_ready(ls_g)
                tracer._mark()
            return _jits["post"](state, gs_g, goh_g, goe_g, ls_g)

    return step
