"""Cross-pod asynchronous data parallelism with SpecTrain compensation
(beyond-paper; DESIGN.md §5).

At 2+ pods the inter-pod all-reduce rides the slow DCN link; hiding it
asynchronously re-creates exactly the staleness problem the paper solves
inside the pipeline — so we apply the same medicine at pod level:

  * each pod applies its **local** gradient immediately;
  * the **remote** pods' gradients arrive one step late (the all-reduce
    overlaps the next step's compute);
  * every pod computes its gradient at SpecTrain-predicted weights
    Ŵ = W − s·η·v with s = 1 (Eq. 4), compensating the one-step lag.

This module is the algorithm (validated for convergence in
tests/test_async_pod.py, mirroring how the simulator validates the
pipeline schedule).  The production mapping replaces the `pod`-axis
segment of the gradient all-reduce with a one-step-delayed
`shard_map`-psum over "pod" — the data-axis reduction stays synchronous.
Zhang et al.'s staleness-dependent learning-rate scaling is available via
``remote_scale``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List

import jax

from repro.core import spectrain as st
from repro.optim import sgd


class AsyncPodDP:
    """Host-level reference of the cross-pod async scheme.

    loss_fn(params, batch) -> scalar; one parameter copy per pod.
    """

    def __init__(self, loss_fn: Callable, params, *, n_pods: int = 2,
                 lr: float = 1e-2, gamma: float = 0.9,
                 predict: bool = True, remote_scale: float = 1.0,
                 delay: int = 1):
        self.loss_fn = loss_fn
        self.n = n_pods
        self.lr = lr
        self.gamma = gamma
        self.predict = predict
        self.remote_scale = remote_scale
        self.delay = delay
        self.params = [params for _ in range(n_pods)]
        self.mom = [sgd.init(params) for _ in range(n_pods)]
        # remote-gradient pipeline: arrivals are `delay` steps late
        self.remote_q: List[List[Any]] = [[] for _ in range(n_pods)]
        self._vag = jax.jit(jax.value_and_grad(loss_fn))
        self._upd = jax.jit(
            lambda p, v, g: sgd.update(p, sgd.MomentumState(v), g,
                                       lr=lr, gamma=gamma))
        self._pred = jax.jit(st.predict_weights)

    def step(self, batches: List[Any]) -> Dict[str, float]:
        assert len(batches) == self.n
        grads, losses = [], []
        for p in range(self.n):
            w = self.params[p]
            if self.predict:
                # remote gradients land `delay` steps later: compute the
                # gradient at the weights predicted for arrival (Eq. 4)
                w = self._pred(w, self.mom[p].v, self.lr, float(self.delay))
            loss, g = self._vag(w, batches[p])
            grads.append(g)
            losses.append(float(loss))

        for p in range(self.n):
            others = [grads[q] for q in range(self.n) if q != p]
            remote_now = jax.tree.map(
                lambda *xs: sum(xs) / len(xs), *others) \
                if len(others) > 1 else others[0]
            self.remote_q[p].append(remote_now)
            remote = (self.remote_q[p].pop(0)
                      if len(self.remote_q[p]) > self.delay else None)
            if remote is None:
                combined = grads[p]
            else:
                combined = jax.tree.map(
                    lambda gl, gr: (gl + self.remote_scale * gr *
                                    (self.n - 1)) / self.n,
                    grads[p], remote)
            new_p, new_m = self._upd(self.params[p], self.mom[p].v,
                                     combined)
            self.params[p], self.mom[p] = new_p, new_m
        return {"loss": sum(losses) / self.n}


class SyncPodDP:
    """Synchronous reference (every pod sees the full mean every step)."""

    def __init__(self, loss_fn, params, *, n_pods: int = 2, lr: float = 1e-2,
                 gamma: float = 0.9):
        self.loss_fn = loss_fn
        self.n = n_pods
        self.params = params
        self.mom = sgd.init(params)
        self.lr, self.gamma = lr, gamma
        self._vag = jax.jit(jax.value_and_grad(loss_fn))

    def step(self, batches) -> Dict[str, float]:
        gs, ls = [], []
        for b in batches:
            loss, g = self._vag(self.params, b)
            gs.append(g)
            ls.append(float(loss))
        g = jax.tree.map(lambda *xs: sum(xs) / len(xs), *gs)
        self.params, self.mom = sgd.update(
            self.params, self.mom, g, lr=self.lr, gamma=self.gamma)
        return {"loss": sum(ls) / len(ls)}
