"""Paper-exact pipelined-training simulator (Fig. 7 semantics).

Reproduces the *algorithmic* behaviour of the paper's 4 schemes on one
process, version-for-version:

  * ``sync``       — staleness-free reference (Data-P / single-GPU).
  * ``vanilla``    — pipelined, stale + inconsistent weights (Fig. 7b).
  * ``pipedream``  — weight stashing: bwd reuses the fwd weights (Fig. 7c).
  * ``spectrain``  — weight prediction, Eqs. (4)–(6) (Fig. 7d).

Timeline model (§3.1): the global weight version t advances once per time
unit; minibatch i reads stage-k forward weights at version

    v_f(i,k) = i + ⌈k/2⌉                (= t_c − s_fwd, Eq. 5)

and stage-k backward weights at

    v_b(i,k) = i + N − 1 − ⌊k/2⌋        (= t_c − s_bwd, Eq. 6)

with its round trip completing at t_c = i + N − 1, where its gradient is
applied (momentum SGD) producing version t_c + 1.  Processing minibatches
in order therefore only ever references versions that already exist.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import spectrain as st
from repro.optim import sgd


@dataclass
class StagedFns:
    """A model split into N sequential stages.

    params layout: {"outer": {"in": ..., "out": ...}, "stages": [N pytrees]}
    ``embed`` consumes outer["in"], ``head_loss`` consumes outer["out"].
    """
    embed: Callable[[Any, Any], jnp.ndarray]
    stage: Callable[[Any, jnp.ndarray], jnp.ndarray]
    head_loss: Callable[[Any, jnp.ndarray, Any], jnp.ndarray]


class Simulator:
    SCHEMES = ("sync", "vanilla", "pipedream", "spectrain")

    def __init__(self, fns: StagedFns, params, *, n_stages: int = 0,
                 scheme: str = "spectrain", lr: float = 1e-2,
                 gamma: float = 0.9, clip: Optional[float] = None,
                 rmse_s: Sequence[int] = (), plan=None):
        """``plan``: an optional ``repro.planner.PipelinePlan``; its
        IR-derived per-stage (s_fwd, s_bwd) replace the hardcoded
        round-robin closed forms, so any emitted schedule's staleness
        structure can be simulated.  Without a plan the paper's
        round-robin Eqs. (5)/(6) are used, as before.  Interleaved
        plans simulate at chunk-stage granularity (``plan.n_chunks``
        stages — the device folding changes the timeline, never the
        per-chunk staleness numerics)."""
        assert scheme in self.SCHEMES, scheme
        if plan is not None:
            n_chunks = getattr(plan, "n_chunks", plan.n_stages)
            if n_stages and n_stages != n_chunks:
                raise ValueError(f"n_stages={n_stages} contradicts "
                                 f"plan's {n_chunks} chunk-stages")
            n_stages = n_chunks
            self.s_fwd = tuple(plan.s_fwd)
            self.s_bwd = tuple(plan.s_bwd)
            # ragged-stage accounting: the per-stage staleness vectors
            # must describe exactly the stage list we execute — a plan
            # whose partition disagrees with the params' stage count
            # would silently pair stage k's weights with stage j's s.
            got = len(params["stages"])
            if got != n_chunks:
                raise ValueError(
                    f"params have {got} stage trees but plan has "
                    f"{n_chunks} (chunk-)stages")
        else:
            if not n_stages:
                raise ValueError("need n_stages or a plan")
            self.s_fwd = tuple(st.version_difference_paper(k, n_stages,
                                                           "forward")
                               for k in range(n_stages))
            self.s_bwd = tuple(st.version_difference_paper(k, n_stages,
                                                           "backward")
                               for k in range(n_stages))
        self.fns = fns
        self.N = n_stages
        self.scheme = scheme
        self.lr = lr
        self.gamma = gamma
        self.clip = clip
        self.rmse_s = tuple(rmse_s)

        self.hist: Dict[int, Any] = {0: params}
        self.mhist: Dict[int, Any] = {0: sgd.init(params).v}
        self.latest = 0
        self.i = 0  # next minibatch index

        self._stage_fwd = jax.jit(fns.stage)
        self._embed = jax.jit(fns.embed)

        def stage_bwd(w, x, cot):
            _, vjp = jax.vjp(fns.stage, w, x)
            return vjp(cot)
        self._stage_bwd = jax.jit(stage_bwd)

        def head_fwd_bwd(w, x, batch):
            (loss, vjp) = jax.vjp(lambda w_, x_: fns.head_loss(w_, x_, batch),
                                  w, x)
            gw, gx = vjp(jnp.ones((), loss.dtype))
            return loss, gw, gx
        self._head = jax.jit(head_fwd_bwd)

        def embed_bwd(w, batch, cot):
            _, vjp = jax.vjp(lambda w_: fns.embed(w_, batch), w)
            return vjp(cot)[0]
        self._embed_bwd = jax.jit(embed_bwd)

        self._predict = jax.jit(st.predict_weights)

    # ------------------------------------------------------------------ utils
    def _ensure(self, t: int):
        while self.latest < t:
            self.latest += 1
            self.hist[self.latest] = self.hist[self.latest - 1]
            self.mhist[self.latest] = self.mhist[self.latest - 1]

    def _gc(self, keep_from: int):
        for t in [t for t in self.hist if t < keep_from]:
            del self.hist[t]
            del self.mhist[t]

    def _weights_at(self, v: int, target: int, predicted: bool):
        """Full param pytree the scheme exposes at read-version v."""
        w = self.hist[v]
        if not predicted:
            return w
        s = target - v
        if s <= 0:
            return w
        return self._predict(w, self.mhist[v], self.lr, s)

    # ------------------------------------------------------------------ step
    def step(self, batch) -> Dict[str, Any]:
        N, i, scheme = self.N, self.i, self.scheme
        if scheme == "sync":
            t_c = self.latest
            v_f = [t_c] * N
            v_b = [t_c] * N
        else:
            t_c = i + N - 1
            self._ensure(t_c)
            # read versions from the (IR-derived or closed-form) staleness
            # vectors; max(0, ·) truncates warm-up reads to the initial
            # weights.  Under the default round-robin plan these are
            # exactly v_f = i + ⌈k/2⌉ and v_b = i + N − 1 − ⌊k/2⌋.
            v_f = [max(0, t_c - self.s_fwd[k]) for k in range(N)]
            v_b = [max(0, t_c - self.s_bwd[k]) for k in range(N)]
        predicted = scheme == "spectrain"

        # ---- forward ----------------------------------------------------
        stage_w_f = [self._weights_at(v_f[k], t_c, predicted)["stages"][k]
                     for k in range(N)]
        outer_f0 = self._weights_at(v_f[0], t_c, predicted)["outer"]
        x = self._embed(outer_f0["in"], batch)
        xs_in: List[jnp.ndarray] = []
        for k in range(N):
            xs_in.append(x)
            x = self._stage_fwd(stage_w_f[k], x)

        # ---- backward ----------------------------------------------------
        def bwd_weights(k):
            if scheme == "pipedream":   # stashing: reuse the fwd weights
                return self._weights_at(v_f[k], t_c, False)
            return self._weights_at(v_b[k], t_c, predicted)

        outer_bN = bwd_weights(N - 1)["outer"]
        loss, g_out, cot = self._head(outer_bN["out"], x, batch)
        grads_stages: List[Any] = [None] * N
        for k in reversed(range(N)):
            gw, cot = self._stage_bwd(bwd_weights(k)["stages"][k],
                                      xs_in[k], cot)
            grads_stages[k] = gw
        g_in = self._embed_bwd(bwd_weights(0)["outer"]["in"], batch, cot)
        grads = {"outer": {"in": g_in, "out": g_out}, "stages": grads_stages}

        # ---- update (producing version t_c + 1) ---------------------------
        if self.clip:
            grads, _ = sgd.clip_by_global_norm(grads, self.clip)
        base = self.hist[t_c]
        new_p, new_m = sgd.update(base, sgd.MomentumState(self.mhist[t_c]),
                                  grads, lr=self.lr, gamma=self.gamma)
        self.hist[t_c + 1] = new_p
        self.mhist[t_c + 1] = new_m.v
        self.latest = t_c + 1

        metrics: Dict[str, Any] = {"loss": float(loss), "version": t_c + 1}

        # ---- Fig. 8: prediction-vs-stale RMSE on the actual trajectory ----
        for s in self.rmse_s:
            v0 = t_c + 1 - s
            if v0 in self.hist:
                pred = self._predict(self.hist[v0], self.mhist[v0],
                                     self.lr, s)
                metrics[f"rmse_pred_s{s}"] = float(st.rmse(pred, new_p))
                metrics[f"rmse_stale_s{s}"] = float(
                    st.rmse(self.hist[v0], new_p))

        self._gc(t_c + 1 - max(2 * N, max(self.s_fwd) + 2,
                               max(self.rmse_s or (0,)) + 1))
        self.i += 1
        return metrics

    # ------------------------------------------------------------------
    @property
    def params(self):
        return self.hist[self.latest]


# ===========================================================================
# small staged models for tests / convergence benchmarks
# ===========================================================================


def make_mlp_staged(key, *, in_dim: int, width: int, depth: int,
                    n_classes: int, n_stages: int,
                    sizes: Optional[Sequence[int]] = None
                    ) -> Tuple[StagedFns, Any]:
    """SNN-style stacked-FC model split into ``n_stages`` stages.

    ``sizes``: per-stage layer counts (ragged, e.g. a DP partition's
    ``sizes()``); defaults to the uniform split (requires divisibility).
    """
    if sizes is None:
        assert depth % n_stages == 0
        sizes = (depth // n_stages,) * n_stages
    sizes = tuple(int(n) for n in sizes)
    if len(sizes) != n_stages or sum(sizes) != depth or min(sizes) < 1:
        raise ValueError(f"sizes {sizes} do not split {depth} layers "
                         f"into {n_stages} stages")
    keys = jax.random.split(key, depth + 2)
    bounds = [0]
    for n in sizes:
        bounds.append(bounds[-1] + n)

    def dense(k, fan_in, fan_out):
        w = jax.random.normal(k, (fan_in, fan_out)) / jnp.sqrt(fan_in)
        return {"w": w, "b": jnp.zeros((fan_out,))}

    params = {
        "outer": {"in": dense(keys[0], in_dim, width),
                  "out": dense(keys[1], width, n_classes)},
        "stages": [
            {"layers": [dense(keys[2 + j], width, width)
                        for j in range(bounds[s], bounds[s + 1])]}
            for s in range(n_stages)],
    }

    def embed(w, batch):
        return jax.nn.selu(batch["x"] @ w["w"] + w["b"])

    def stage(sp, x):
        for lw in sp["layers"]:
            x = jax.nn.selu(x @ lw["w"] + lw["b"])
        return x

    def head_loss(w, x, batch):
        logits = x @ w["w"] + w["b"]
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], -1)[:, 0]
        return jnp.mean(lse - gold)

    return StagedFns(embed, stage, head_loss), params


def staged_from_model(model, partition=None
                      ) -> Tuple[StagedFns, Callable[[Any], Any]]:
    """Adapt a repro.models.Model into StagedFns.

    Returns (fns, repack) where ``repack(model_params)`` produces the
    simulator param layout.  ``partition``: an optional planner
    ``Partition`` — repack then builds ragged per-stage trees from its
    layer ranges (``stage_apply`` reads each stage's layer count off the
    tree), so non-uniform DP splits simulate as they execute.  A
    partition with ``n_stages · v`` chunk-stages (interleaved plans)
    yields that many chunk trees — the simulator runs them as stages.
    """
    if partition is not None and partition.n_layers != model.cfg.n_layers:
        raise ValueError(f"partition covers {partition.n_layers} layers, "
                         f"model has {model.cfg.n_layers}")
    sizes = (partition.sizes() if partition is not None
             else tuple(model.stage_sizes))

    def repack(params):
        return {
            "outer": {"in": params["outer"], "out": params["outer"]},
            "stages": list(model.partition_stage_params(
                params["stages"], sizes, n_chunks=len(sizes))),
        }

    def embed(outer_in, batch):
        return model.embed(outer_in, batch)

    def stage(sp, x):
        (x, _aux) = model.stage_apply(sp, (x, jnp.zeros((), jnp.float32)))
        return x

    def head_loss(outer_out, x, batch):
        return model.head_loss(outer_out, x, batch["targets"])

    return StagedFns(embed, stage, head_loss), repack
