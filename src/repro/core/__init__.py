# The paper's primary contribution: SpecTrain weight prediction and the
# pipelined model-parallel runtimes (sync circular + async streaming), plus
# the paper-exact event simulator used for convergence reproductions.
from repro.core import pipeline_stream, pipeline_sync  # noqa: F401
from repro.core import simulator, spectrain  # noqa: F401
