"""SpecTrain: weight prediction via momentum-smoothed gradients (paper §3.2).

Equations implemented verbatim:

  (1)  v_t = γ·v_{t−1} + (1−γ)·g_t                     (smoothed gradient)
  (2)  W_{t+1} = W_t − η·g_t                            (SGD step)
  (3)  Ŵ_{t+1} = W_t − η·v_{t−1}                        (one-step prediction)
  (4)  Ŵ_{t+s} = W_t − s·η·v_{t−1}                      (s-step prediction)
  (5)  s_fwd  = ⌊k/2⌋ + N − k − 1                       (round-robin schedule)
  (6)  s_bwd  = ⌊k/2⌋

The streaming tick schedule (core/pipeline_stream.py) has its own version
differences, derived the same way (s = #updates between the weight read and
the minibatch's own update landing):

       s_fwd = 2·(N − 1 − k),   s_bwd = 0

The 1F1B schedule family added by the planner IR has closed forms too:

  1f1b / interleaved (flush)   s_fwd = s_bwd = 0    (synchronous rounds)
  2bw (PipeDream-2BW)          s_fwd = s_bwd = 1    (double-buffered, m ≥ N)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# version differences


def version_difference_paper(stage: int, n_stages: int, phase: str) -> int:
    """Eqs. (5)/(6) — the paper's round-robin 1F1B schedule."""
    k, n = stage, n_stages
    if not 0 <= k < n:
        raise ValueError(f"stage {k} out of range for {n} stages")
    if phase == "forward":
        return k // 2 + n - k - 1
    if phase == "backward":
        return k // 2
    raise ValueError(phase)


def version_difference_stream(stage: int, n_stages: int, phase: str) -> int:
    """The streaming-tick schedule (one 1F+1B wave per train_step)."""
    k, n = stage, n_stages
    if not 0 <= k < n:
        raise ValueError(f"stage {k} out of range for {n} stages")
    if phase == "forward":
        return 2 * (n - 1 - k)
    if phase == "backward":
        return 0
    raise ValueError(phase)


def version_difference_1f1b(stage: int, n_stages: int, phase: str) -> int:
    """1F1B with flush (PipeDream-flush) and its interleaved variant:
    gradients accumulate across the round and apply in one per-stage
    update after the drain, so no update can land between any weight
    read and the minibatch's own gradient apply — staleness-free like
    GPipe, for every (chunk-)stage and phase."""
    k, n = stage, n_stages
    if not 0 <= k < n:
        raise ValueError(f"stage {k} out of range for {n} stages")
    if phase not in ("forward", "backward"):
        raise ValueError(phase)
    return 0


def version_difference_2bw(stage: int, n_stages: int, phase: str) -> int:
    """PipeDream-2BW: group g's forward *and* backward are pinned to the
    weight version with g−1 updates applied (double buffering), and its
    own update is the g-th — a uniform, stage-independent staleness of 1
    for both phases (the 2BW paper's delay term)."""
    k, n = stage, n_stages
    if not 0 <= k < n:
        raise ValueError(f"stage {k} out of range for {n} stages")
    if phase not in ("forward", "backward"):
        raise ValueError(phase)
    return 1


# ---------------------------------------------------------------------------
# prediction


def predict_weights(params: Any, momentum: Any, lr, s) -> Any:
    """Eq. (4): Ŵ_{t+s} = W_t − s·η·v_{t−1}   (pytree-wise).

    ``s`` may be a python int or a traced scalar (per-stage vectors are
    handled by the pipeline runtimes which vmap/index this)."""
    s = jnp.asarray(s, jnp.float32)
    lr = jnp.asarray(lr, jnp.float32)

    def leaf(w, v):
        return (w.astype(jnp.float32) - s * lr * v.astype(jnp.float32)
                ).astype(w.dtype)

    return jax.tree.map(leaf, params, momentum)


def predict_weights_stacked(params: Any, momentum: Any, lr, s_per_stage):
    """Per-stage prediction for stage-stacked params.

    Every leaf of ``params`` has a leading [n_stages] axis; ``s_per_stage``
    is an int vector [n_stages].  Broadcasts s along the stage axis.
    """
    s = jnp.asarray(s_per_stage, jnp.float32)
    lr = jnp.asarray(lr, jnp.float32)

    def leaf(w, v):
        sb = s.reshape((-1,) + (1,) * (w.ndim - 1))
        return (w.astype(jnp.float32) - sb * lr * v.astype(jnp.float32)
                ).astype(w.dtype)

    return jax.tree.map(leaf, params, momentum)


# ---------------------------------------------------------------------------
# prediction-error metrics (Fig. 8)


def rmse(a: Any, b: Any) -> jnp.ndarray:
    """Root-mean-square error between two pytrees (global, fp32)."""
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)
                                - y.astype(jnp.float32)))
             for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    n = sum(x.size for x in jax.tree.leaves(a))
    return jnp.sqrt(sq / n)
