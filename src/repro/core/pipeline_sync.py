"""Synchronous circular pipeline (GPipe semantics) — staleness-free baseline.

Stage weights are the ragged per-stage canonical trees (tuple of
``S`` pytrees — any partition executes, no divisibility constraint);
microbatches rotate through the uniform ``[S, ...]`` activation buffer
with ``jnp.roll`` (lowers to collective-permute on a sharded axis);
autodiff through the tick scan produces the reverse pipeline.  Weight
update is one synchronous momentum-SGD step per global batch —
identical semantics to data parallelism, which is why it doubles as the
staleness-free reference in every convergence test.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import shard_act
from repro.optim import sgd


def pipeline_loss(model, params, batch, num_microbatches: int) -> jnp.ndarray:
    """Forward loss through the circular pipeline."""
    cfg = model.cfg
    S = model.n_stages
    if S == 1:
        return model.loss(params, batch)
    M = num_microbatches
    outer, stages = params["outer"], params["stages"]
    if not isinstance(stages, (tuple, list)):     # legacy stacked input
        stages = model.partition_stage_params(stages, model.stage_sizes)

    x = model.embed(outer, batch)                    # [B, s, d]
    B = x.shape[0]
    if B % M:
        # ValueError, not assert: guards a user-supplied shape and must
        # survive `python -O`
        raise ValueError(f"global batch {B} not divisible by "
                         f"num_microbatches={M}")
    mb = B // M
    xs = x.reshape((M, mb) + x.shape[1:])
    T = M + S - 1

    state = jnp.zeros((S, mb) + x.shape[1:], x.dtype)
    state = shard_act(state, "stage", "act_batch", None, None)

    def stage_fn(sp, xk):
        (xk, aux) = model.stage_apply(sp, (xk, jnp.zeros((), jnp.float32)))
        return xk, aux

    karange = jnp.arange(S)

    def tick(carry, t):
        prev_out, aux_sum = carry
        x_t = jax.lax.dynamic_index_in_dim(
            xs, jnp.clip(t, 0, M - 1), 0, keepdims=False)
        ins = jnp.roll(prev_out, 1, axis=0).at[0].set(x_t)
        ins = shard_act(ins, "stage", "act_batch", None, None)
        # per-stage python loop over the ragged stage trees (the
        # stacked layout's vmap cannot span differently-shaped stages)
        stage_outs = [stage_fn(stages[k], ins[k]) for k in range(S)]
        out = jnp.stack([o for o, _ in stage_outs])
        aux_vec = jnp.stack([a for _, a in stage_outs])
        valid = ((t - karange) >= 0) & ((t - karange) < M)
        aux_sum = aux_sum + jnp.sum(aux_vec * valid)
        return (out, aux_sum), out[-1]

    init = (state, jnp.zeros((), jnp.float32))
    (_, aux_sum), ys = jax.lax.scan(tick, init, jnp.arange(T))
    # drained outputs: ticks S-1 .. T-1 hold microbatches 0..M-1
    outs = ys[S - 1:]                                # [M, mb, s, d]
    outs = outs.reshape((B,) + outs.shape[2:])
    loss = model.head_loss(outer, outs, batch["targets"])
    return loss + aux_sum / M


def make_train_step(model, *, lr: float, gamma: float = 0.9,
                    num_microbatches: Optional[int] = None,
                    clip: Optional[float] = None) -> Callable:
    """Synchronous pipelined train step (params+momentum in state)."""
    M = num_microbatches or model.cfg.mesh_plan.num_microbatches

    def loss_fn(params, batch):
        return pipeline_loss(model, params, batch, M)

    def train_step(state: Dict[str, Any], batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        gnorm = None
        if clip:
            grads, gnorm = sgd.clip_by_global_norm(grads, clip)
        params, mom = sgd.update(state["params"],
                                 sgd.MomentumState(state["momentum"]),
                                 grads, lr=lr, gamma=gamma)
        new_state = {"params": params, "momentum": mom.v,
                     "step": state["step"] + 1}
        metrics = {"loss": loss}
        if gnorm is not None:
            metrics["grad_norm"] = gnorm
        return new_state, metrics

    return train_step


def init_state(model, key) -> Dict[str, Any]:
    params = model.init(key)
    return {"params": params, "momentum": sgd.init(params).v,
            "step": jnp.zeros((), jnp.int32)}
