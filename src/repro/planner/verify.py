"""Static schedule verifier: dataflow, race, and staleness analysis
over the compiled pipeline IR.

The scan interpreter (PR 5) and the MPMD device streams (PR 7) are both
driven by artifacts produced by hand-rolled greedy register allocators
(:func:`~repro.planner.schedule_ir.compile_event_table` and
:func:`~repro.planner.schedule_ir.compile_device_streams`).  Their only
check so far was bitwise parity against each other on the plans the
tests happen to enumerate; a slot-lifetime bug on an untested
(schedule, S, M, sizes) combination would corrupt gradients silently.

This module *proves* every compiled schedule before it runs, the way a
race detector verifies a program instead of sampling its executions.
It re-simulates the artifact row by row with symbolic value ids —
``v(m, q)`` (microbatch m's input to chunk q; ``v(m, C)`` the head
input) and ``c(m, q)`` (the cotangent w.r.t. ``v(m, q)``) — against an
independent model of what each event must read, write, free and send.
The checks are grouped into classes (the ``check`` field of each
:class:`Violation`):

``slot-hazard``
    every slot read is dominated by a write of the matching
    (chunk, mb, kind) value, no write clobbers a live value (WAR/WAW),
    and no slot reference escapes its pool — for the global scan pools
    *and* the per-device MPMD pools.
``comm-mismatch``
    every tick's ring sends pair up with an armed receive slot on the
    right neighbor, armed receives have a sender (an armed slot with no
    sender is filled with ring garbage), and no real payload is parked
    in the trash slot.
``wv-lag``
    each event's weight-version lag equals the SpecTrain/PipeDream
    closed form for its schedule, the row's ``wv`` column agrees with
    its branch spec, and stash reads stay within the IR-derived weight
    stash depth.
``double-contribution``
    first-contribution markers (per-chunk grad, head outer grad, embed
    outer grad) fire exactly once per round, on the owner's first
    backward — a missed marker accumulates into garbage, a repeated one
    resets the accumulator.
``completeness``
    every microbatch gets exactly one fwd and one bwd per chunk, in
    topological order, and the round ends with no in-flight values.
``resource-bound``
    verified peak slot liveness equals the allocator's pool sizes and
    the per-chunk activation-stash peak equals ``plan.act_stash``.
``placement``
    chunk q's events run on device q mod S; head/embed markers land on
    their statically-pinned devices.
``encoding``
    row columns are internally consistent with their branch spec (the
    canonical-form checks none of the above subsume).
``decode-once``
    serving only: the decode wave visits every chunk exactly once per
    round, and every live request decodes exactly once per round over
    its lifetime (admission round + 1 through eviction round).
``page-lifetime``
    serving only: a request's KV pages are allocated exactly at
    admission from free pages, held for the whole request lifetime,
    and freed exactly at eviction — page lifetime == request lifetime.

The serving round (PR 10) reuses the same machinery over the
forward-only prefill/decode staircase: :func:`verify_serve_table` /
:func:`verify_serve_streams` replay the hidden-state slot pools and
payload rings of :class:`~repro.planner.schedule_ir.ServeTable` /
:class:`~repro.planner.schedule_ir.ServeStreams`, and
:func:`verify_request_trace` checks a continuous-batching scheduler's
emitted admit/decode/evict log against the KV-page and slot
invariants.

What the verifier cannot prove: numerical properties of the branch
bodies themselves (it checks *which* values flow, not what the kernels
compute), wall-clock validity of the tick grid, or anything about the
weights' contents.  See docs/ARCHITECTURE.md for the full catalogue.

Entry points: :func:`verify_event_table`, :func:`verify_device_streams`
(collect-all, return a :class:`VerifyReport`), :func:`verify_plan` /
:func:`check_plan` (plan-level, raising), a mutation harness
(:func:`mutation_catalog`, :func:`self_test`) proving the checks have
power, and a CLI::

    python -m repro.planner.verify --schedule 1f1b --stages 3
    python -m repro.planner.verify --grid        # the CI verify grid
    python -m repro.planner.verify --self-test   # mutation harness
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.planner import schedule_ir as sir

CHECKS = ("slot-hazard", "comm-mismatch", "wv-lag", "double-contribution",
          "completeness", "resource-bound", "placement", "encoding",
          "decode-once", "page-lifetime")


@dataclass(frozen=True)
class Violation:
    """One failed invariant: ``check`` is the class (one of
    :data:`CHECKS`), ``site`` locates the row/tick, ``message`` names
    the expected-vs-found facts."""
    check: str
    site: str
    message: str

    def __str__(self) -> str:
        return f"[{self.check}] {self.site}: {self.message}"


class VerificationError(ValueError):
    """A compiled schedule artifact failed static verification."""

    def __init__(self, artifact: str, violations: Tuple[Violation, ...]):
        self.artifact = artifact
        self.violations = tuple(violations)
        lines = "\n".join(f"  {v}" for v in self.violations[:20])
        more = ("" if len(self.violations) <= 20
                else f"\n  ... and {len(self.violations) - 20} more")
        super().__init__(
            f"{artifact}: {len(self.violations)} verification "
            f"violation(s):\n{lines}{more}")


@dataclass(frozen=True)
class VerifyReport:
    """Outcome of verifying one artifact: all violations (the verifier
    never stops at the first) plus the measured stats the resource
    checks compared against."""
    artifact: str
    schedule: str
    n_events: int
    violations: Tuple[Violation, ...]
    stats: Dict[str, object]

    @property
    def ok(self) -> bool:
        return not self.violations

    def raise_on_violation(self) -> "VerifyReport":
        if self.violations:
            raise VerificationError(
                f"{self.schedule}/{self.artifact}", self.violations)
        return self


def expected_lag(schedule: str, chunk: int, n_chunks: int,
                 phase: str) -> int:
    """The closed-form weight-version lag of a (schedule, chunk, phase)
    read — PipeDream-flush/GPipe/interleaved are staleness-free by
    construction, 2BW pins every read one version back (the paper's
    double-buffer semantics, ``core/spectrain.py``)."""
    from repro.core import spectrain as st
    if schedule == "gpipe":
        return 0
    if schedule in ("1f1b", "interleaved"):
        return st.version_difference_1f1b(chunk, n_chunks, phase)
    if schedule == "2bw":
        return st.version_difference_2bw(chunk, n_chunks, phase)
    raise KeyError(f"no closed-form lag for schedule {schedule!r}; "
                   f"round schedules are {sir.ROUND_SCHEDULES}")


# ===========================================================================
# slot-pool simulation
# ===========================================================================


def _fmt(value: Tuple[str, int, int]) -> str:
    kind, m, q = value
    return f"{kind}({m},{q})"


class _Pool:
    """Symbolic slot pool: tracks which value id lives in which slot,
    flags reads of dead/mismatched slots and writes over live values,
    and records the peak liveness the resource checks compare against.

    On a mismatched read the pool frees the slot where the expected
    value *actually* lives (if anywhere), so one corruption yields a
    precise violation instead of a cascade."""

    def __init__(self, name: str, n_slots: int,
                 add: Callable[[str, str, str], None]):
        self.name = name
        self.n = n_slots
        self.add = add
        self.slots: Dict[int, Tuple[str, int, int]] = {}
        self.peak = 0

    def _in_range(self, slot: int, what: str, site: str) -> bool:
        if 0 <= slot < self.n:
            return True
        self.add("slot-hazard", site,
                 f"{what} targets {self.name} slot {slot} outside the "
                 f"pool [0, {self.n}) — a dynamic index there clamps "
                 f"onto a live slot")
        return False

    def write(self, slot: int, value: Tuple[str, int, int],
              site: str) -> None:
        if not self._in_range(slot, f"write of {_fmt(value)}", site):
            return
        held = self.slots.get(slot)
        if held is not None:
            self.add("slot-hazard", site,
                     f"write of {_fmt(value)} clobbers live "
                     f"{_fmt(held)} in {self.name} slot {slot} "
                     f"(WAW/WAR hazard)")
        self.slots[slot] = value
        self.peak = max(self.peak, len(self.slots))

    def read(self, slot: int, value: Tuple[str, int, int], site: str,
             *, free: bool) -> None:
        if self._in_range(slot, f"read of {_fmt(value)}", site):
            held = self.slots.get(slot)
            if held != value:
                found = "a dead slot" if held is None else _fmt(held)
                self.add("slot-hazard", site,
                         f"read of {_fmt(value)} from {self.name} slot "
                         f"{slot} finds {found}")
        if free:
            for s, v in list(self.slots.items()):
                if v == value:
                    del self.slots[s]
                    break

    def leftovers(self) -> List[str]:
        return [f"{_fmt(v)} in {self.name} slot {s}"
                for s, v in sorted(self.slots.items())]


class _Round:
    """Shared per-round bookkeeping: fwd/bwd completion and ordering,
    first-contribution markers, per-chunk activation-stash peaks."""

    def __init__(self, n_chunks: int, n_microbatches: int,
                 add: Callable[[str, str, str], None]):
        self.C, self.M, self.add = n_chunks, n_microbatches, add
        self.fwd_done: Dict[Tuple[int, int], str] = {}
        self.bwd_done: Dict[Tuple[int, int], str] = {}
        self.stash = [0] * n_chunks
        self.stash_peak = [0] * n_chunks
        self.first_bwd_site: Dict[int, str] = {}
        self.marks_g: Dict[int, List[str]] = {q: [] for q in range(n_chunks)}
        self.marks_o: List[str] = []
        self.marks_e: List[str] = []

    def fwd(self, m: int, q: int, site: str) -> None:
        if (m, q) in self.fwd_done:
            self.add("completeness", site,
                     f"fwd({m},{q}) emitted twice (first at "
                     f"{self.fwd_done[(m, q)]})")
            return
        if q > 0 and (m, q - 1) not in self.fwd_done:
            self.add("completeness", site,
                     f"fwd({m},{q}) before fwd({m},{q - 1})")
        self.fwd_done[(m, q)] = site
        self.stash[q] += 1
        self.stash_peak[q] = max(self.stash_peak[q], self.stash[q])

    def bwd(self, m: int, q: int, site: str) -> None:
        if (m, q) in self.bwd_done:
            self.add("completeness", site,
                     f"bwd({m},{q}) emitted twice (first at "
                     f"{self.bwd_done[(m, q)]})")
            return
        if (m, q) not in self.fwd_done:
            self.add("completeness", site,
                     f"bwd({m},{q}) before fwd({m},{q})")
        if q < self.C - 1 and (m, q + 1) not in self.bwd_done:
            self.add("completeness", site,
                     f"bwd({m},{q}) before bwd({m},{q + 1})")
        self.bwd_done[(m, q)] = site
        self.first_bwd_site.setdefault(q, site)
        self.stash[q] -= 1

    def marks(self, kind: str, q: int, fg: int, fo: int, fe: int,
              site: str) -> None:
        if kind != sir.BWD:
            if fg or fo or fe:
                self.add("double-contribution", site,
                         "first-contribution marker on a non-backward "
                         "event")
            return
        if fg:
            self.marks_g[q].append(site)
        if fo:
            if q != self.C - 1:
                self.add("double-contribution", site,
                         f"head first-marker on chunk {q}; the head "
                         f"grad is produced only at chunk {self.C - 1}")
            self.marks_o.append(site)
        if fe:
            if q != 0:
                self.add("double-contribution", site,
                         f"embed first-marker on chunk {q}; the embed "
                         f"grad is produced only at chunk 0")
            self.marks_e.append(site)

    def finish(self) -> None:
        for m in range(self.M):
            for q in range(self.C):
                if (m, q) not in self.fwd_done:
                    self.add("completeness", "round end",
                             f"fwd({m},{q}) never emitted")
                if (m, q) not in self.bwd_done:
                    self.add("completeness", "round end",
                             f"bwd({m},{q}) never emitted")
        head_first = self.first_bwd_site.get(self.C - 1, "<none>")
        embed_first = self.first_bwd_site.get(0, "<none>")
        for q in range(self.C):
            marks = self.marks_g[q]
            want = self.first_bwd_site.get(q, "<none>")
            if len(marks) != 1:
                self.add("double-contribution", "round end",
                         f"chunk {q} first-grad marker fires "
                         f"{len(marks)}x at {marks or '<never>'}, "
                         f"expected exactly once at {want}")
            elif marks[0] != want:
                self.add("double-contribution", marks[0],
                         f"chunk {q} first-grad marker not on its "
                         f"first backward ({want})")
        for name, marks, want in (("head", self.marks_o, head_first),
                                  ("embed", self.marks_e, embed_first)):
            if len(marks) != 1:
                self.add("double-contribution", "round end",
                         f"{name} outer-grad first-marker fires "
                         f"{len(marks)}x at {marks or '<never>'}, "
                         f"expected exactly once at {want}")
            elif marks[0] != want:
                self.add("double-contribution", marks[0],
                         f"{name} outer-grad first-marker not on the "
                         f"{name} owner's first backward ({want})")


def _check_branches(branches, C: int, add) -> None:
    for b, (kind, q, s) in enumerate(branches):
        if kind not in (sir.FWD, sir.BWD):
            add("encoding", f"branch {b}", f"unknown opcode {kind!r}")
        if not 0 <= q < C:
            add("encoding", f"branch {b}",
                f"chunk {q} out of range for {C} chunks")
        if s < 0:
            add("encoding", f"branch {b}", f"negative wv lag {s}")


def _check_lag(schedule: str, kind: str, q: int, s: int, C: int,
               w_stash_depth, site: str, add) -> None:
    phase = "forward" if kind == sir.FWD else "backward"
    try:
        want = expected_lag(schedule, q, C, phase)
    except KeyError:
        return
    if s != want:
        add("wv-lag", site,
            f"{kind}({q}) reads at lag {s}; the {schedule!r} closed "
            f"form is {want}")
    if w_stash_depth is not None and s + 1 > w_stash_depth[q]:
        add("wv-lag", site,
            f"lag {s} needs {s + 1} stashed weight versions on chunk "
            f"{q}; the IR derives depth {w_stash_depth[q]}")


# ===========================================================================
# event-table verification (the SPMD lax.scan backend's artifact)
# ===========================================================================


def verify_event_table(table: sir.EventTable, *, schedule: str,
                       act_stash: Optional[Tuple[int, ...]] = None,
                       w_stash_depth: Optional[Tuple[int, ...]] = None
                       ) -> VerifyReport:
    """Statically verify an :class:`~repro.planner.schedule_ir.EventTable`
    by re-simulating its rows against the global value/cotangent slot
    pools.  Collects every violation; never raises."""
    viols: List[Violation] = []

    def add(check: str, site: str, msg: str) -> None:
        viols.append(Violation(check, site, msg))

    C, M = table.n_chunks, table.n_microbatches
    rows = np.asarray(table.rows)
    nb = len(table.branches)
    _check_branches(table.branches, C, add)
    if rows.shape != (2 * M * C, sir.N_COLS):
        add("completeness", "table",
            f"rows shape {rows.shape} != ({2 * M * C}, {sir.N_COLS}) "
            f"for M={M}, C={C}")
    val = _Pool("value", table.n_val_slots, add)
    cot = _Pool("cotangent", table.n_cot_slots, add)
    rnd = _Round(C, M, add)

    for i, r in enumerate(map(tuple, rows.tolist())):
        br = r[sir.COL_BRANCH]
        if not 0 <= br < nb:
            add("encoding", f"row {i}",
                f"branch id {br} outside [0, {nb})")
            continue
        kind, q, s = table.branches[br]
        m = r[sir.COL_MB]
        site = f"row {i} ({kind} m={m} q={q})"
        if r[sir.COL_OP] != (sir.OP_FWD if kind == sir.FWD else sir.OP_BWD):
            add("encoding", site,
                f"op column {r[sir.COL_OP]} contradicts branch "
                f"opcode {kind!r}")
        if r[sir.COL_CHUNK] != q:
            add("encoding", site,
                f"chunk column {r[sir.COL_CHUNK]} contradicts branch "
                f"chunk {q}")
        if not 0 <= m < M:
            add("completeness", site,
                f"microbatch {m} outside [0, {M})")
            continue
        if r[sir.COL_WV] != s:
            add("wv-lag", site,
                f"wv column {r[sir.COL_WV]} contradicts the branch's "
                f"lag {s} — the interpreter predicts by the branch")
        _check_lag(schedule, kind, q, s, C, w_stash_depth, site, add)
        a, b, c = r[sir.COL_A], r[sir.COL_B], r[sir.COL_C]
        rnd.marks(kind, q, r[sir.COL_FIRST_G], r[sir.COL_FIRST_O],
                  r[sir.COL_FIRST_E], site)
        if kind == sir.FWD:
            rnd.fwd(m, q, site)
            if q == 0:
                val.write(a, ("v", m, 0), site)
            else:
                val.read(a, ("v", m, q), site, free=False)
            val.write(b, ("v", m, q + 1), site)
            if c != -1:
                add("encoding", site,
                    f"forward row carries a cotangent write slot {c}")
        else:
            rnd.bwd(m, q, site)
            val.read(a, ("v", m, q), site, free=True)
            if q == C - 1:
                val.read(b, ("v", m, C), site, free=True)
            else:
                cot.read(b, ("c", m, q + 1), site, free=True)
            if q > 0:
                cot.write(c, ("c", m, q), site)
            elif c != -1:
                add("encoding", site,
                    f"chunk-0 backward carries a cotangent write "
                    f"slot {c} (the embed backward consumes c(m,0) "
                    f"in-branch)")
    rnd.finish()
    for leak in val.leftovers() + cot.leftovers():
        add("completeness", "round end", f"round leaves live {leak}")
    if val.peak != table.n_val_slots:
        add("resource-bound", "round end",
            f"verified peak value liveness {val.peak} != allocated "
            f"n_val_slots {table.n_val_slots}")
    if cot.peak != table.n_cot_slots:
        add("resource-bound", "round end",
            f"verified peak cotangent liveness {cot.peak} != allocated "
            f"n_cot_slots {table.n_cot_slots}")
    if act_stash is not None and tuple(rnd.stash_peak) != tuple(act_stash):
        add("resource-bound", "round end",
            f"verified per-chunk activation-stash peaks "
            f"{tuple(rnd.stash_peak)} != plan.act_stash "
            f"{tuple(act_stash)}")
    return VerifyReport(
        artifact="event_table", schedule=schedule,
        n_events=int(rows.shape[0]), violations=tuple(viols),
        stats={"peak_val": val.peak, "peak_cot": cot.peak,
               "stash_peak": tuple(rnd.stash_peak)})


# ===========================================================================
# device-stream verification (the MPMD shard_map backend's artifact)
# ===========================================================================


def verify_device_streams(streams: sir.DeviceStreams, *, schedule: str,
                          act_stash: Optional[Tuple[int, ...]] = None,
                          w_stash_depth: Optional[Tuple[int, ...]] = None
                          ) -> VerifyReport:
    """Statically verify a
    :class:`~repro.planner.schedule_ir.DeviceStreams` artifact: per-tick
    re-simulation of every device's compute against its *private* slot
    pools, plus the ``ppermute`` ring matching — each tick's sends must
    land in an armed receive slot on the right neighbor, each armed slot
    must have a sender, head/embed markers must sit on their pinned
    devices.  Collects every violation; never raises."""
    viols: List[Violation] = []

    def add(check: str, site: str, msg: str) -> None:
        viols.append(Violation(check, site, msg))

    C, M, S = streams.n_chunks, streams.n_microbatches, streams.n_devices
    rows = np.asarray(streams.rows)
    T = rows.shape[0]
    nb = len(streams.branches)          # arm nb is the NOP
    nv, nc = streams.n_val_slots, streams.n_cot_slots
    d_head = (C - 1) % S
    _check_branches(streams.branches, C, add)
    if rows.shape[1:] != (S, sir.DN_COLS):
        add("encoding", "streams",
            f"rows shape {rows.shape} != (T, {S}, {sir.DN_COLS})")
    vals = [_Pool(f"dev{d} value", nv, add) for d in range(S)]
    cots = [_Pool(f"dev{d} cotangent", nc, add) for d in range(S)]
    rnd = _Round(C, M, add)
    n_events = 0

    for t in range(T):
        # -- phase 1: this tick's compute events, per device ------------
        sends_f: Dict[int, Tuple[str, Tuple[str, int, int]]] = {}
        sends_b: Dict[int, Tuple[str, Tuple[str, int, int]]] = {}
        for d in range(S):
            r = tuple(int(x) for x in rows[t, d])
            br = r[sir.DCOL_BRANCH]
            site = f"tick {t}/dev {d}"
            if not 0 <= br <= nb:
                add("encoding", site,
                    f"branch id {br} outside [0, {nb}]")
                continue
            if br == nb:                # NOP arm
                for col, name in ((sir.DCOL_A, "A"), (sir.DCOL_B, "B"),
                                  (sir.DCOL_C, "C")):
                    if r[col] != -1:
                        add("encoding", site,
                            f"idle row carries slot column {name}="
                            f"{r[col]}")
                if (r[sir.DCOL_FIRST_G] or r[sir.DCOL_FIRST_O]
                        or r[sir.DCOL_FIRST_E]):
                    add("double-contribution", site,
                        "first-contribution marker on an idle row")
                continue
            n_events += 1
            kind, q, s = streams.branches[br]
            m = r[sir.DCOL_MB]
            site = f"tick {t}/dev {d} ({kind} m={m} q={q})"
            if q % S != d:
                add("placement", site,
                    f"chunk {q} lives on device {q % S} "
                    f"(Megatron round-robin), scheduled on device {d}")
            if not 0 <= m < M:
                add("completeness", site,
                    f"microbatch {m} outside [0, {M})")
                continue
            _check_lag(schedule, kind, q, s, C, w_stash_depth, site, add)
            a, b, c = r[sir.DCOL_A], r[sir.DCOL_B], r[sir.DCOL_C]
            if r[sir.DCOL_FIRST_O] and d != d_head:
                add("placement", site,
                    f"head first-marker on device {d}; the head is "
                    f"statically pinned to device {d_head}")
            if r[sir.DCOL_FIRST_E] and d != 0:
                add("placement", site,
                    f"embed first-marker on device {d}; the embed is "
                    f"statically pinned to device 0")
            rnd.marks(kind, q, r[sir.DCOL_FIRST_G], r[sir.DCOL_FIRST_O],
                      r[sir.DCOL_FIRST_E], site)
            if kind == sir.FWD:
                rnd.fwd(m, q, site)
                if q == 0:
                    vals[d].write(a, ("v", m, 0), site)
                else:
                    vals[d].read(a, ("v", m, q), site, free=False)
                if q == C - 1:
                    vals[d].write(b, ("v", m, C), site)
                elif b != -1:
                    add("encoding", site,
                        f"non-head forward carries a local output "
                        f"slot B={b} (outputs ship on the ring)")
                if c != -1:
                    add("encoding", site,
                        f"forward row carries cotangent slot C={c}")
                if q < C - 1:
                    sends_f[(d + 1) % S] = (site, ("v", m, q + 1))
            else:
                rnd.bwd(m, q, site)
                vals[d].read(a, ("v", m, q), site, free=True)
                if q == C - 1:
                    vals[d].read(b, ("v", m, C), site, free=True)
                else:
                    if b != -1:
                        add("encoding", site,
                            f"non-head backward carries head slot "
                            f"B={b}")
                    cots[d].read(c, ("c", m, q + 1), site, free=True)
                if q == C - 1 and c != -1:
                    add("encoding", site,
                        f"head backward carries cotangent slot C={c}")
                if q > 0:
                    sends_b[(d - 1) % S] = (site, ("c", m, q))
        # -- phase 2: ring transfers land after every branch ran --------
        for d in range(S):
            r = tuple(int(x) for x in rows[t, d])
            site = f"tick {t}/dev {d}"
            for recv_col, sends, pool, ring in (
                    (sir.DCOL_RECV_F, sends_f, vals[d], "forward"),
                    (sir.DCOL_RECV_B, sends_b, cots[d], "backward")):
                slot = r[recv_col]
                sent = sends.pop(d, None)
                if slot < 0:
                    if sent is not None:
                        add("comm-mismatch", site,
                            f"{ring}-ring payload {_fmt(sent[1])} from "
                            f"{sent[0]} lands in the trash slot — its "
                            f"consumer will read a dead slot")
                    continue
                if sent is None:
                    add("comm-mismatch", site,
                        f"{ring}-ring receive armed into slot {slot} "
                        f"with no sender this tick — the slot is "
                        f"filled with ring garbage")
                    continue
                if slot >= pool.n:
                    add("comm-mismatch", site,
                        f"{ring}-ring payload {_fmt(sent[1])} parked "
                        f"in slot {slot} outside the live pool "
                        f"[0, {pool.n}) (the trash)")
                    continue
                pool.write(slot, sent[1], site)
        for sends, ring in ((sends_f, "forward"), (sends_b, "backward")):
            for nd, (src, value) in sends.items():
                add("comm-mismatch", f"tick {t}/dev {nd}",
                    f"{ring}-ring payload {_fmt(value)} from {src} has "
                    f"no matching receive")
    rnd.finish()
    for pool in vals + cots:
        for leak in pool.leftovers():
            add("completeness", "round end", f"round leaves live {leak}")
    peak_v = max(p.peak for p in vals)
    peak_c = max(p.peak for p in cots)
    if peak_v != nv:
        add("resource-bound", "round end",
            f"verified per-device peak value liveness {peak_v} != "
            f"allocated n_val_slots {nv}")
    if peak_c != nc:
        add("resource-bound", "round end",
            f"verified per-device peak cotangent liveness {peak_c} != "
            f"allocated n_cot_slots {nc}")
    if act_stash is not None and tuple(rnd.stash_peak) != tuple(act_stash):
        add("resource-bound", "round end",
            f"verified per-chunk activation-stash peaks "
            f"{tuple(rnd.stash_peak)} != plan.act_stash "
            f"{tuple(act_stash)}")
    return VerifyReport(
        artifact="device_streams", schedule=schedule, n_events=n_events,
        violations=tuple(viols),
        stats={"peak_val": peak_v, "peak_cot": peak_c,
               "stash_peak": tuple(rnd.stash_peak), "n_ticks": T})


# ===========================================================================
# plan-level entry points
# ===========================================================================


def verify_plan(plan, *, device_streams: bool = True
                ) -> Tuple[VerifyReport, ...]:
    """Verify every compiled artifact of a
    :class:`~repro.planner.api.PipelinePlan`.  Round schedules verify
    the event table and (by default) the device streams; non-round
    schedules re-validate the event timeline.  Returns the reports
    without raising — :func:`check_plan` is the raising wrapper."""
    if plan.schedule not in sir.ROUND_SCHEDULES:
        plan.round_ir().validate()
        return (VerifyReport(artifact="schedule", schedule=plan.schedule,
                             n_events=len(plan.round_ir().events),
                             violations=(), stats={}),)
    kw = dict(schedule=plan.schedule, act_stash=plan.act_stash,
              w_stash_depth=plan.w_stash_depth)
    reports = [verify_event_table(plan.event_table(), **kw)]
    if device_streams:
        reports.append(verify_device_streams(plan.device_streams(), **kw))
    return tuple(reports)


def check_plan(plan, *, device_streams: bool = True) -> None:
    """Raise :class:`VerificationError` if any of the plan's compiled
    artifacts fails static verification."""
    for report in verify_plan(plan, device_streams=device_streams):
        report.raise_on_violation()


# ===========================================================================
# serving-round verification (ServeTable / ServeStreams / request traces)
# ===========================================================================


def _serve_tick(kind: str, j: int, q: int) -> int:
    """The staircase tick of serve event ``(kind, lane, chunk)`` — the
    decode wave enters at tick 0, prefill lane j at tick 1 + j, one
    chunk per tick."""
    return q if kind == sir.DECODE else 1 + j + q


def _check_serve_branches(branches, C: int, add) -> None:
    for b, (kind, q) in enumerate(branches):
        if kind not in (sir.DECODE, sir.PREFILL):
            add("encoding", f"branch {b}", f"unknown serve opcode {kind!r}")
        if not 0 <= q < C:
            add("encoding", f"branch {b}",
                f"chunk {q} out of range for {C} chunks")


class _ServeRound:
    """Per-round serving bookkeeping: chain ordering per lane, the
    decode wave's exactly-once-per-chunk invariant, completeness."""

    def __init__(self, n_chunks: int, max_prefill: int, add):
        self.C, self.F, self.add = n_chunks, max_prefill, add
        self.done: Dict[Tuple[str, int, int], str] = {}

    def event(self, kind: str, j: int, q: int, site: str) -> bool:
        key = (kind, j, q)
        if key in self.done:
            check = ("decode-once" if kind == sir.DECODE
                     else "completeness")
            self.add(check, site,
                     f"{kind}({j},{q}) emitted twice (first at "
                     f"{self.done[key]}) — a re-decoded chunk advances "
                     f"its KV pages twice in one round")
            return False
        if q > 0 and (kind, j, q - 1) not in self.done:
            self.add("completeness", site,
                     f"{kind}({j},{q}) before {kind}({j},{q - 1})")
        self.done[key] = site
        return True

    def finish(self) -> None:
        lanes = [(sir.DECODE, 0)] + [(sir.PREFILL, j)
                                     for j in range(self.F)]
        for kind, j in lanes:
            for q in range(self.C):
                if (kind, j, q) not in self.done:
                    check = ("decode-once" if kind == sir.DECODE
                             else "completeness")
                    self.add(check, "round end",
                             f"{kind}({j},{q}) never emitted")


def verify_serve_table(table: sir.ServeTable) -> VerifyReport:
    """Statically verify a
    :class:`~repro.planner.schedule_ir.ServeTable` by re-simulating its
    rows against the decode/prefill hidden-state slot pools and the
    staircase encoding.  Collects every violation; never raises."""
    viols: List[Violation] = []

    def add(check: str, site: str, msg: str) -> None:
        viols.append(Violation(check, site, msg))

    C, F = table.n_chunks, table.max_prefill
    rows = np.asarray(table.rows)
    nb = len(table.branches)
    _check_serve_branches(table.branches, C, add)
    if rows.shape != ((1 + F) * C, sir.SN_COLS):
        add("completeness", "table",
            f"rows shape {rows.shape} != ({(1 + F) * C}, {sir.SN_COLS}) "
            f"for F={F}, C={C}")
    dec = _Pool("decode-hidden", table.n_dec_slots, add)
    pf = _Pool("prefill-hidden", table.n_pf_slots, add)
    rnd = _ServeRound(C, F, add)

    for i, r in enumerate(map(tuple, rows.tolist())):
        br = r[sir.SCOL_BRANCH]
        if not 0 <= br < nb:
            add("encoding", f"row {i}",
                f"branch id {br} outside [0, {nb})")
            continue
        kind, q = table.branches[br]
        j = r[sir.SCOL_MB]
        site = f"row {i} ({kind} j={j} q={q})"
        want_op = sir.OP_DECODE if kind == sir.DECODE else sir.OP_PREFILL
        if r[sir.SCOL_OP] != want_op:
            add("encoding", site,
                f"op column {r[sir.SCOL_OP]} contradicts branch "
                f"opcode {kind!r}")
        if r[sir.SCOL_CHUNK] != q:
            add("encoding", site,
                f"chunk column {r[sir.SCOL_CHUNK]} contradicts branch "
                f"chunk {q}")
        if kind == sir.DECODE and j != 0:
            add("encoding", site,
                f"decode wave carries prefill lane {j}")
            continue
        if kind == sir.PREFILL and not 0 <= j < F:
            add("completeness", site,
                f"prefill lane {j} outside [0, {F})")
            continue
        if r[sir.SCOL_T] != _serve_tick(kind, j, q):
            add("encoding", site,
                f"tick {r[sir.SCOL_T]} off the staircase (expected "
                f"{_serve_tick(kind, j, q)})")
        if not rnd.event(kind, j, q, site):
            continue
        pool = dec if kind == sir.DECODE else pf
        a, b = r[sir.SCOL_A], r[sir.SCOL_B]
        if q == 0:
            if a != -1:
                add("encoding", site,
                    f"chunk-0 row carries a read slot A={a} (the first "
                    f"chunk embeds in-branch)")
        else:
            pool.read(a, (kind, j, q), site, free=True)
        if q < C - 1:
            pool.write(b, (kind, j, q + 1), site)
        elif b != -1:
            add("encoding", site,
                f"last-chunk row carries a write slot B={b} (the head "
                f"emits the token in-branch)")
    rnd.finish()
    for leak in dec.leftovers() + pf.leftovers():
        add("completeness", "round end", f"round leaves live {leak}")
    if dec.peak != table.n_dec_slots:
        add("resource-bound", "round end",
            f"verified peak decode-hidden liveness {dec.peak} != "
            f"allocated n_dec_slots {table.n_dec_slots}")
    if pf.peak != table.n_pf_slots:
        add("resource-bound", "round end",
            f"verified peak prefill-hidden liveness {pf.peak} != "
            f"allocated n_pf_slots {table.n_pf_slots}")
    return VerifyReport(
        artifact="serve_table", schedule="serve",
        n_events=int(rows.shape[0]), violations=tuple(viols),
        stats={"peak_dec": dec.peak, "peak_pf": pf.peak})


def verify_serve_streams(streams: sir.ServeStreams) -> VerifyReport:
    """Statically verify a
    :class:`~repro.planner.schedule_ir.ServeStreams` artifact: per-tick
    re-simulation of every device's serve event against its *private*
    decode/prefill hidden pools, the two payload rings' send/receive
    matching, and the one-chunk-per-device placement.  Collects every
    violation; never raises."""
    viols: List[Violation] = []

    def add(check: str, site: str, msg: str) -> None:
        viols.append(Violation(check, site, msg))

    C, F, S = streams.n_chunks, streams.max_prefill, streams.n_devices
    rows = np.asarray(streams.rows)
    T = rows.shape[0]
    nb = len(streams.branches)          # arm nb is the NOP
    _check_serve_branches(streams.branches, C, add)
    if C != S:
        add("placement", "streams",
            f"serving folds one chunk per device; {C} chunks on "
            f"{S} devices")
    if rows.shape[1:] != (S, sir.SDN_COLS):
        add("encoding", "streams",
            f"rows shape {rows.shape} != (T, {S}, {sir.SDN_COLS})")
    if T != C + F:
        add("encoding", "streams",
            f"{T} ticks != the staircase's C + F = {C + F}")
    decs = [_Pool(f"dev{d} decode-hidden", streams.n_dec_slots, add)
            for d in range(S)]
    pfs = [_Pool(f"dev{d} prefill-hidden", streams.n_pf_slots, add)
           for d in range(S)]
    rnd = _ServeRound(C, F, add)
    n_events = 0

    for t in range(T):
        # -- phase 1: this tick's compute events, per device ------------
        sends_d: Dict[int, Tuple[str, Tuple[str, int, int]]] = {}
        sends_p: Dict[int, Tuple[str, Tuple[str, int, int]]] = {}
        for d in range(S):
            r = tuple(int(x) for x in rows[t, d])
            br = r[sir.SDCOL_BRANCH]
            site = f"tick {t}/dev {d}"
            if not 0 <= br <= nb:
                add("encoding", site,
                    f"branch id {br} outside [0, {nb}]")
                continue
            if br == nb:                # NOP arm
                if r[sir.SDCOL_A] != -1:
                    add("encoding", site,
                        f"idle row carries read slot A={r[sir.SDCOL_A]}")
                continue
            n_events += 1
            kind, q = streams.branches[br]
            j = r[sir.SDCOL_MB]
            site = f"tick {t}/dev {d} ({kind} j={j} q={q})"
            if q != d:
                add("placement", site,
                    f"chunk {q} lives on device {q} (serving is one "
                    f"chunk per device), scheduled on device {d}")
            if kind == sir.PREFILL and not 0 <= j < F:
                add("completeness", site,
                    f"prefill lane {j} outside [0, {F})")
                continue
            if kind == sir.DECODE and j != 0:
                add("encoding", site,
                    f"decode wave carries prefill lane {j}")
                continue
            if t != _serve_tick(kind, j, q):
                add("encoding", site,
                    f"tick {t} off the staircase (expected "
                    f"{_serve_tick(kind, j, q)})")
            if not rnd.event(kind, j, q, site):
                continue
            pool = decs[d] if kind == sir.DECODE else pfs[d]
            a = r[sir.SDCOL_A]
            if q == 0:
                if a != -1:
                    add("encoding", site,
                        f"chunk-0 row carries a read slot A={a} (the "
                        f"first chunk embeds in-branch)")
            else:
                pool.read(a, (kind, j, q), site, free=True)
            if q < C - 1:
                sends = sends_d if kind == sir.DECODE else sends_p
                sends[(d + 1) % S] = (site, (kind, j, q + 1))
        # -- phase 2: ring transfers land after every branch ran --------
        for d in range(S):
            r = tuple(int(x) for x in rows[t, d])
            site = f"tick {t}/dev {d}"
            for recv_col, sends, pool, ring in (
                    (sir.SDCOL_RECV_D, sends_d, decs[d], "decode"),
                    (sir.SDCOL_RECV_P, sends_p, pfs[d], "prefill")):
                slot = r[recv_col]
                sent = sends.pop(d, None)
                if slot < 0:
                    if sent is not None:
                        add("comm-mismatch", site,
                            f"{ring}-ring payload {_fmt(sent[1])} from "
                            f"{sent[0]} lands in the trash slot — its "
                            f"consumer will read a dead slot")
                    continue
                if sent is None:
                    add("comm-mismatch", site,
                        f"{ring}-ring receive armed into slot {slot} "
                        f"with no sender this tick — the slot is "
                        f"filled with ring garbage")
                    continue
                if slot >= pool.n:
                    add("comm-mismatch", site,
                        f"{ring}-ring payload {_fmt(sent[1])} parked "
                        f"in slot {slot} outside the live pool "
                        f"[0, {pool.n}) (the trash)")
                    continue
                pool.write(slot, sent[1], site)
        for sends, ring in ((sends_d, "decode"), (sends_p, "prefill")):
            for nd, (src, value) in sends.items():
                add("comm-mismatch", f"tick {t}/dev {nd}",
                    f"{ring}-ring payload {_fmt(value)} from {src} has "
                    f"no matching receive")
    rnd.finish()
    for pool in decs + pfs:
        for leak in pool.leftovers():
            add("completeness", "round end", f"round leaves live {leak}")
    peak_d = max((p.peak for p in decs), default=0)
    peak_p = max((p.peak for p in pfs), default=0)
    if peak_d != streams.n_dec_slots:
        add("resource-bound", "round end",
            f"verified per-device peak decode-hidden liveness {peak_d} "
            f"!= allocated n_dec_slots {streams.n_dec_slots}")
    if peak_p != streams.n_pf_slots:
        add("resource-bound", "round end",
            f"verified per-device peak prefill-hidden liveness {peak_p} "
            f"!= allocated n_pf_slots {streams.n_pf_slots}")
    return VerifyReport(
        artifact="serve_streams", schedule="serve", n_events=n_events,
        violations=tuple(viols),
        stats={"peak_dec": peak_d, "peak_pf": peak_p, "n_ticks": T})


def verify_request_trace(entries, *, n_slots: int, n_pages: int,
                         n_stages: Optional[int] = None,
                         complete: bool = True) -> VerifyReport:
    """Verify a continuous-batching scheduler's emitted event log
    (dicts with ``ev`` in {admit, decode, evict, reject}) against the
    serving invariants: page lifetime == request lifetime (pages come
    from the free set at admission and return exactly at eviction),
    one decode per live request per round over exactly the rounds
    ``admit+1 .. evict``, and no two live requests sharing a slot.
    With ``complete=True`` (a drained run) a still-live request at
    trace end is itself a page leak.  Never raises."""
    viols: List[Violation] = []

    def add(check: str, site: str, msg: str) -> None:
        viols.append(Violation(check, site, msg))

    live: Dict[object, Dict[str, object]] = {}
    slot_of: Dict[int, object] = {}
    held: Dict[int, Dict[int, object]] = {}   # stage -> page -> rid
    n_ev = 0
    for i, e in enumerate(entries):
        ev, r, rid = e.get("ev"), e.get("round"), e.get("rid")
        site = f"entry {i} ({ev} rid={rid} round={r})"
        if ev == "reject":
            continue
        n_ev += 1
        if ev == "admit":
            if rid in live:
                add("page-lifetime", site,
                    f"rid {rid} admitted twice (still live since round "
                    f"{live[rid]['admit']})")
                continue
            slot = e.get("slot")
            if not 0 <= slot < n_slots:
                add("slot-hazard", site,
                    f"slot {slot} outside [0, {n_slots})")
            elif slot in slot_of:
                add("slot-hazard", site,
                    f"slot {slot} already held by live rid "
                    f"{slot_of[slot]}")
            else:
                slot_of[slot] = rid
            pages = tuple(e.get("pages", ()))
            if n_stages is not None and len(pages) != n_stages:
                add("encoding", site,
                    f"{len(pages)} pages for {n_stages} stages")
            for st, p in enumerate(pages):
                if not 0 <= p < n_pages:
                    add("page-lifetime", site,
                        f"stage {st} page {p} outside [0, {n_pages})")
                    continue
                owner = held.setdefault(st, {}).get(p)
                if owner is not None:
                    add("page-lifetime", site,
                        f"stage {st} page {p} still held by live rid "
                        f"{owner} — an admission must draw from the "
                        f"free set")
                held[st][p] = rid
            live[rid] = {"slot": slot, "pages": pages,
                         "gen": e.get("gen_len"), "admit": r,
                         "decodes": []}
        elif ev == "decode":
            st = live.get(rid)
            if st is None:
                add("decode-once", site,
                    f"decode for rid {rid}, which is not live")
                continue
            if r in st["decodes"]:
                add("decode-once", site,
                    f"rid {rid} decodes twice in round {r}")
            st["decodes"].append(r)
            if e.get("slot") is not None and e["slot"] != st["slot"]:
                add("slot-hazard", site,
                    f"decode in slot {e['slot']} but rid {rid} was "
                    f"admitted into slot {st['slot']}")
        elif ev == "evict":
            st = live.pop(rid, None)
            if st is None:
                add("page-lifetime", site,
                    f"evict of rid {rid}, which is not live")
                continue
            slot_of.pop(st["slot"], None)
            for stg, p in enumerate(st["pages"]):
                if held.get(stg, {}).get(p) == rid:
                    del held[stg][p]
            want = list(range(st["admit"] + 1, r + 1))
            if st["decodes"] != want:
                want_s = (str(want) if want else
                          "(none: admitted and evicted in one round)")
                add("decode-once", site,
                    f"rid {rid} decoded in rounds {st['decodes']}, "
                    f"expected exactly once per live round: {want_s}")
            if st["gen"] is not None \
                    and len(st["decodes"]) != st["gen"] - 1:
                add("decode-once", site,
                    f"rid {rid} ran {len(st['decodes'])} decodes for "
                    f"gen_len {st['gen']} (the prefill emits the first "
                    f"token; decodes must be gen_len - 1)")
        else:
            add("encoding", site, f"unknown trace event {ev!r}")
    if complete:
        for rid, st in sorted(live.items(), key=lambda kv: str(kv[0])):
            add("page-lifetime", "trace end",
                f"rid {rid} still live (admitted round {st['admit']}, "
                f"never evicted) — its pages and slot leak")
    return VerifyReport(
        artifact="request_trace", schedule="serve", n_events=n_ev,
        violations=tuple(viols),
        stats={"live_at_end": len(live)})


def verify_serve_plan(plan, *, device_streams: bool = True
                      ) -> Tuple[VerifyReport, ...]:
    """Verify every compiled artifact of a
    :class:`~repro.planner.api.ServePlan`.  Returns the reports without
    raising — :func:`check_serve_plan` is the raising wrapper."""
    reports = [verify_serve_table(plan.serve_table())]
    if device_streams:
        reports.append(verify_serve_streams(plan.serve_streams()))
    return tuple(reports)


def check_serve_plan(plan, *, device_streams: bool = True) -> None:
    """Raise :class:`VerificationError` if any of the serve plan's
    compiled artifacts fails static verification."""
    for report in verify_serve_plan(plan, device_streams=device_streams):
        report.raise_on_violation()


# ===========================================================================
# mutation harness: prove the checks have power
# ===========================================================================


def _replace_rows(artifact, rows: np.ndarray):
    return dataclasses.replace(artifact, rows=np.array(rows, np.int32))


def _table_rows(table) -> np.ndarray:
    return np.array(table.rows, np.int32)


def _find_row(table, pred) -> int:
    for i, r in enumerate(np.asarray(table.rows)):
        kind, q, s = table.branches[int(r[sir.COL_BRANCH])]
        if pred(i, kind, q, s, r):
            return i
    raise LookupError("no row matches the mutation predicate")


def mutation_catalog(table: sir.EventTable,
                     streams: sir.DeviceStreams
                     ) -> Iterator[Tuple[str, str, object]]:
    """Yield ``(name, check, corrupted_artifact)`` single-row
    corruptions of valid artifacts.  Each corruption models a concrete
    allocator/lowering bug; the verifier MUST flag every one with a
    violation of the named check class — the mutation tests and
    ``--self-test`` assert exactly that."""
    C, M = table.n_chunks, table.n_microbatches
    S = streams.n_devices
    nop = len(streams.branches)

    # ---- slot-hazard ----------------------------------------------------
    rows = _table_rows(table)
    i = _find_row(table, lambda i, k, q, s, r: k == sir.FWD and q > 0)
    rows[i, sir.COL_B] = rows[i, sir.COL_A]   # output overwrites stash
    yield "table/fwd-write-clobbers-stash", "slot-hazard", \
        _replace_rows(table, rows)

    bwd_of = {}
    for i, r in enumerate(np.asarray(table.rows)):
        kind, q, _s = table.branches[int(r[sir.COL_BRANCH])]
        if kind == sir.BWD:
            bwd_of.setdefault(q, []).append(i)
    q_two = next(q for q, ix in bwd_of.items() if len(ix) >= 2)
    i, j = bwd_of[q_two][0], bwd_of[q_two][1]
    rows = _table_rows(table)
    rows[i, sir.COL_A] = rows[j, sir.COL_A]   # reads another mb's stash
    yield "table/bwd-reads-other-mb-stash", "slot-hazard", \
        _replace_rows(table, rows)

    rows = _table_rows(table)
    i = _find_row(table, lambda i, k, q, s, r: k == sir.BWD and q > 0)
    rows[i, sir.COL_C] = table.n_cot_slots    # write escapes the pool
    yield "table/cot-write-outside-pool", "slot-hazard", \
        _replace_rows(table, rows)

    rows = _table_rows(table)
    i = _find_row(table, lambda i, k, q, s, r: k == sir.BWD
                  and q == C - 1)
    rows[i, sir.COL_B] = rows[i, sir.COL_A]   # head reads stash twice
    yield "table/head-reads-wrong-slot", "slot-hazard", \
        _replace_rows(table, rows)

    # ---- comm-mismatch (device streams) ---------------------------------
    def _find_cell(pred):
        arr = np.asarray(streams.rows)
        for t in range(arr.shape[0]):
            for d in range(S):
                if pred(t, d, arr[t, d]):
                    return t, d
        raise LookupError("no stream cell matches the mutation predicate")

    srows = np.array(streams.rows, np.int32)
    t, d = _find_cell(lambda t, d, r: r[sir.DCOL_RECV_F] >= 0)
    srows[t, d, sir.DCOL_RECV_F] = -1         # payload dropped to trash
    yield "streams/fwd-payload-to-trash", "comm-mismatch", \
        _replace_rows(streams, srows)

    srows = np.array(streams.rows, np.int32)
    t, d = _find_cell(lambda t, d, r: r[sir.DCOL_RECV_B] >= 0)
    srows[t, d, sir.DCOL_RECV_B] = -1
    yield "streams/bwd-payload-to-trash", "comm-mismatch", \
        _replace_rows(streams, srows)

    def _no_fwd_sender(t, d, _r):
        if _r[sir.DCOL_RECV_F] >= 0:
            return False
        src = np.asarray(streams.rows)[t, (d - 1) % S]
        br = int(src[sir.DCOL_BRANCH])
        if br >= nop:
            return True
        kind, q, _s = streams.branches[br]
        return not (kind == sir.FWD and q < C - 1)

    srows = np.array(streams.rows, np.int32)
    t, d = _find_cell(_no_fwd_sender)
    srows[t, d, sir.DCOL_RECV_F] = 0          # armed recv, no sender
    yield "streams/recv-armed-no-sender", "comm-mismatch", \
        _replace_rows(streams, srows)

    srows = np.array(streams.rows, np.int32)
    t, d = _find_cell(lambda t, d, r: r[sir.DCOL_RECV_F] >= 0)
    srows[t, d, sir.DCOL_RECV_F] = streams.n_val_slots  # park in trash
    yield "streams/payload-parked-in-trash", "comm-mismatch", \
        _replace_rows(streams, srows)

    # ---- wv-lag ---------------------------------------------------------
    for delta, tag, which in ((1, "plus-one", sir.FWD),
                              (-1, "minus-one", sir.BWD),
                              (7, "plus-seven", sir.BWD)):
        rows = _table_rows(table)
        i = _find_row(table, lambda i, k, q, s, r: k == which)
        rows[i, sir.COL_WV] += delta          # row lag contradicts branch
        yield f"table/wv-{tag}", "wv-lag", _replace_rows(table, rows)

    # ---- double-contribution --------------------------------------------
    rows = _table_rows(table)
    i = bwd_of[q_two][1]
    rows[i, sir.COL_FIRST_G] = 1              # marker fires twice
    yield "table/first-grad-twice", "double-contribution", \
        _replace_rows(table, rows)

    rows = _table_rows(table)
    i = bwd_of[q_two][0]
    rows[i, sir.COL_FIRST_G] = 0              # marker never fires
    yield "table/first-grad-missing", "double-contribution", \
        _replace_rows(table, rows)

    rows = _table_rows(table)
    i = bwd_of[C - 1][1]
    rows[i, sir.COL_FIRST_O] = 1              # head accumulator reset
    yield "table/head-first-twice", "double-contribution", \
        _replace_rows(table, rows)

    rows = _table_rows(table)
    i = bwd_of[0][0]
    rows[i, sir.COL_FIRST_E] = 0              # embed adds into garbage
    yield "table/embed-first-missing", "double-contribution", \
        _replace_rows(table, rows)

    # ---- completeness ---------------------------------------------------
    rows = _table_rows(table)
    i = _find_row(table, lambda i, k, q, s, r: k == sir.BWD)
    rows[i, sir.COL_MB] = (int(rows[i, sir.COL_MB]) + 1) % M
    yield "table/bwd-wrong-microbatch", "completeness", \
        _replace_rows(table, rows)

    rows = _table_rows(table)
    rows[1] = rows[0]                         # duplicated event row
    yield "table/duplicated-row", "completeness", \
        _replace_rows(table, rows)

    srows = np.array(streams.rows, np.int32)
    t, d = _find_cell(lambda t, d, r: r[sir.DCOL_BRANCH] < nop)
    srows[t, d, :] = -1                       # event dropped to a NOP
    srows[t, d, sir.DCOL_BRANCH] = nop
    srows[t, d, sir.DCOL_MB] = 0
    srows[t, d, sir.DCOL_FIRST_G] = 0
    srows[t, d, sir.DCOL_FIRST_O] = 0
    srows[t, d, sir.DCOL_FIRST_E] = 0
    yield "streams/event-dropped", "completeness", \
        _replace_rows(streams, srows)

    # ---- placement (device streams) -------------------------------------
    if S > 1:
        arr = np.asarray(streams.rows)
        wrong = next(
            (t, d, b) for t in range(arr.shape[0]) for d in range(S)
            for b, (k, q, s) in enumerate(streams.branches)
            if arr[t, d, sir.DCOL_BRANCH] == nop and q % S != d)
        t, d, b = wrong
        srows = np.array(streams.rows, np.int32)
        srows[t, d, sir.DCOL_BRANCH] = b      # chunk on a foreign device
        srows[t, d, sir.DCOL_MB] = 0
        srows[t, d, sir.DCOL_A] = 0
        yield "streams/chunk-on-wrong-device", "placement", \
            _replace_rows(streams, srows)


def self_test(plan) -> Tuple[int, List[str]]:
    """Run the mutation harness over a plan's artifacts: every
    catalogued corruption must be flagged with its named check class.
    Returns ``(n_mutations, failures)``."""
    table, streams = plan.event_table(), plan.device_streams()
    kw = dict(schedule=plan.schedule, act_stash=plan.act_stash,
              w_stash_depth=plan.w_stash_depth)
    failures: List[str] = []
    n = 0
    for name, check, bad in mutation_catalog(table, streams):
        n += 1
        if isinstance(bad, sir.EventTable):
            report = verify_event_table(bad, **kw)
        else:
            report = verify_device_streams(bad, **kw)
        got = {v.check for v in report.violations}
        if check not in got:
            failures.append(
                f"{name}: expected a {check!r} violation, got "
                f"{sorted(got) or 'a clean report'}")
    return n, failures


def serve_mutation_catalog(table: sir.ServeTable,
                           streams: sir.ServeStreams
                           ) -> Iterator[Tuple[str, str, object]]:
    """Single-row corruptions of valid serving artifacts, mirroring
    :func:`mutation_catalog` — each models a concrete serve-lowering
    bug the verifier MUST flag with the named check class.  Needs
    ``max_prefill >= 2`` and ``n_chunks >= 3`` so both pools and the
    ring have room for the interesting corruptions."""
    C, F, S = table.n_chunks, table.max_prefill, streams.n_devices
    nop = len(streams.branches)

    def _find_srow(pred) -> int:
        for i, r in enumerate(np.asarray(table.rows)):
            kind, q = table.branches[int(r[sir.SCOL_BRANCH])]
            if pred(i, kind, q, r):
                return i
        raise LookupError("no serve row matches the mutation predicate")

    # ---- slot-hazard ----------------------------------------------------
    rows = _table_rows(table)
    i = _find_srow(lambda i, k, q, r: k == sir.PREFILL and q > 0)
    rows[i, sir.SCOL_A] = (int(rows[i, sir.SCOL_A]) + 1) \
        % max(table.n_pf_slots, 2)            # reads another lane's slot
    yield "serve-table/pf-reads-wrong-slot", "slot-hazard", \
        _replace_rows(table, rows)

    rows = _table_rows(table)
    i = _find_srow(lambda i, k, q, r: k == sir.PREFILL and q < C - 1)
    rows[i, sir.SCOL_B] = table.n_pf_slots    # write escapes the pool
    yield "serve-table/pf-write-outside-pool", "slot-hazard", \
        _replace_rows(table, rows)

    # ---- decode-once ----------------------------------------------------
    rows = _table_rows(table)
    dec_ix = [i for i, r in enumerate(np.asarray(table.rows))
              if table.branches[int(r[sir.SCOL_BRANCH])][0] == sir.DECODE]
    rows[dec_ix[1]] = rows[dec_ix[0]]         # chunk decoded twice
    yield "serve-table/decode-twice", "decode-once", \
        _replace_rows(table, rows)

    # ---- encoding -------------------------------------------------------
    rows = _table_rows(table)
    rows[0, sir.SCOL_T] += 1                  # off the staircase
    yield "serve-table/tick-off-staircase", "encoding", \
        _replace_rows(table, rows)

    # ---- comm-mismatch (serve streams) ----------------------------------
    def _find_cell(pred):
        arr = np.asarray(streams.rows)
        for t in range(arr.shape[0]):
            for d in range(S):
                if pred(t, d, arr[t, d]):
                    return t, d
        raise LookupError("no serve cell matches the mutation predicate")

    srows = np.array(streams.rows, np.int32)
    t, d = _find_cell(lambda t, d, r: r[sir.SDCOL_RECV_D] >= 0)
    srows[t, d, sir.SDCOL_RECV_D] = -1        # payload dropped to trash
    yield "serve-streams/decode-payload-to-trash", "comm-mismatch", \
        _replace_rows(streams, srows)

    srows = np.array(streams.rows, np.int32)
    t, d = _find_cell(
        lambda t, d, r: d > 0 and r[sir.SDCOL_RECV_P] < 0
        and np.asarray(streams.rows)[t, d - 1, sir.SDCOL_BRANCH] == nop)
    srows[t, d, sir.SDCOL_RECV_P] = 0         # armed recv, no sender
    yield "serve-streams/recv-armed-no-sender", "comm-mismatch", \
        _replace_rows(streams, srows)

    # ---- completeness ---------------------------------------------------
    srows = np.array(streams.rows, np.int32)
    t, d = _find_cell(lambda t, d, r: r[sir.SDCOL_BRANCH] < nop)
    srows[t, d, sir.SDCOL_BRANCH] = nop       # event dropped to a NOP
    srows[t, d, sir.SDCOL_MB] = 0
    srows[t, d, sir.SDCOL_A] = -1
    yield "serve-streams/event-dropped", "completeness", \
        _replace_rows(streams, srows)

    # ---- placement (serve streams) --------------------------------------
    if S > 1:
        arr = np.asarray(streams.rows)
        t, d, b = next(
            (t, d, b) for t in range(arr.shape[0]) for d in range(S)
            for b, (k, q) in enumerate(streams.branches)
            if arr[t, d, sir.SDCOL_BRANCH] == nop and q != d)
        srows = np.array(streams.rows, np.int32)
        srows[t, d, sir.SDCOL_BRANCH] = b     # chunk on a foreign device
        srows[t, d, sir.SDCOL_MB] = 0
        srows[t, d, sir.SDCOL_A] = -1
        yield "serve-streams/chunk-on-wrong-device", "placement", \
            _replace_rows(streams, srows)


def serve_self_test(plan) -> Tuple[int, List[str]]:
    """Run the serve mutation harness over a
    :class:`~repro.planner.api.ServePlan`'s artifacts.  Returns
    ``(n_mutations, failures)``; see :func:`self_test`."""
    table, streams = plan.serve_table(), plan.serve_streams()
    failures: List[str] = []
    n = 0
    for name, check, bad in serve_mutation_catalog(table, streams):
        n += 1
        if isinstance(bad, sir.ServeTable):
            report = verify_serve_table(bad)
        else:
            report = verify_serve_streams(bad)
        got = {v.check for v in report.violations}
        if check not in got:
            failures.append(
                f"{name}: expected a {check!r} violation, got "
                f"{sorted(got) or 'a clean report'}")
    return n, failures


# ===========================================================================
# CLI
# ===========================================================================

GRID_SCHEDULES = ("1f1b", "2bw", "interleaved", "gpipe")
GRID_STAGES = (2, 3, 4)
GRID_PARTITIONS = ("uniform", "ragged")
GRID_POLICIES = ("spectrain", "pipedream")


def _grid_plan(schedule: str, n_stages: int, partition: str):
    """One grid cell's plan: ragged cells use a skewed synthetic layer
    profile so the DP partitioner emits genuinely non-uniform stage
    sizes (the partition is carried by the plan and validated by the
    runtimes; the compiled round artifacts depend on schedule/S/v/M)."""
    from repro.planner import api, profiler
    v = 2 if schedule == "interleaved" else 1
    n_chunks = n_stages * v
    n_layers = 2 * n_chunks
    if partition == "ragged":
        costs = [1.0 + 0.5 * (i % 3) for i in range(n_layers)]
        prof = profiler.synthetic_profile(costs)
        return api.plan(None, n_stages=n_stages, schedule=schedule,
                        virtual_stages=v, partitioner="dp", profile=prof)
    return api.plan(None, n_stages=n_stages, schedule=schedule,
                    virtual_stages=v, n_layers=n_layers)


def iter_grid():
    """Yield ``(label, plan)`` over the CI verify grid:
    {1f1b, 2bw, interleaved, gpipe} x S in {2, 3, 4} x
    {uniform, ragged DP} x {spectrain, pipedream}.  The policy axis
    does not change the compiled artifacts (the wv lag is
    schedule-derived; the policy decides whether the runtime predicts
    across it) but keeps the verified surface aligned with what the
    runtimes execute."""
    for schedule in GRID_SCHEDULES:
        for n_stages in GRID_STAGES:
            for partition in GRID_PARTITIONS:
                plan = _grid_plan(schedule, n_stages, partition)
                for policy in GRID_POLICIES:
                    yield (f"{schedule}/S{n_stages}/{partition}/{policy}",
                           plan)


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.planner.verify",
        description="statically verify compiled pipeline schedules")
    ap.add_argument("--schedule", default="1f1b",
                    choices=sir.ROUND_SCHEDULES)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--virtual-stages", type=int, default=1,
                    dest="virtual_stages")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--ragged", action="store_true",
                    help="skewed synthetic profile + DP partitioner")
    ap.add_argument("--serve", action="store_true",
                    help="verify a serving round (ServeTable + "
                         "ServeStreams) instead of a training plan")
    ap.add_argument("--prefill", type=int, default=2,
                    help="serving: prefill lanes per round")
    ap.add_argument("--grid", action="store_true",
                    help="verify the full CI grid instead of one plan")
    ap.add_argument("--self-test", action="store_true", dest="self_test",
                    help="run the mutation harness (every catalogued "
                         "single-row corruption must be flagged)")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    from repro.planner import api, profiler

    def one(label, plan) -> int:
        reports = verify_plan(plan)
        bad = [v for r in reports for v in r.violations]
        n_ev = sum(r.n_events for r in reports)
        if not args.quiet or bad:
            status = "FAIL" if bad else "ok"
            print(f"{label}: {status} ({len(reports)} artifacts, "
                  f"{n_ev} events)")
        for v in bad:
            print(f"  {v}")
        return len(bad)

    failures = 0
    if args.serve:
        splan = api.serve_plan(
            None, n_stages=args.stages, max_prefill=args.prefill,
            n_layers=args.layers or 2 * args.stages, validate=False)
        reports = verify_serve_plan(splan)
        bad = [v for r in reports for v in r.violations]
        n_ev = sum(r.n_events for r in reports)
        status = "FAIL" if bad else "ok"
        print(f"serve/S{args.stages}F{args.prefill}: {status} "
              f"({len(reports)} artifacts, {n_ev} events)")
        for v in bad:
            print(f"  {v}")
        failures += len(bad)
        if args.self_test:
            n, fails = serve_self_test(splan)
            print(f"serve mutation self-test: {n - len(fails)}/{n} "
                  f"corruptions flagged")
            for f in fails:
                print(f"  MISSED {f}")
            failures += len(fails)
        return 1 if failures else 0
    if args.grid:
        n = 0
        for label, plan in iter_grid():
            failures += one(label, plan)
            n += 1
        print(f"verify-grid: {n} cells, "
              f"{'all clean' if not failures else f'{failures} violations'}")
    else:
        v = args.virtual_stages
        kw = {}
        if args.microbatches:
            kw["n_microbatches"] = args.microbatches
        if args.ragged:
            C = args.stages * v
            L = args.layers or 2 * C
            costs = [1.0 + 0.5 * (i % 3) for i in range(L)]
            plan = api.plan(None, n_stages=args.stages,
                            schedule=args.schedule, virtual_stages=v,
                            partitioner="dp",
                            profile=profiler.synthetic_profile(costs),
                            **kw)
        else:
            plan = api.plan(None, n_stages=args.stages,
                            schedule=args.schedule, virtual_stages=v,
                            n_layers=args.layers or 2 * args.stages * v,
                            **kw)
        label = f"{plan.schedule}/S{plan.n_stages}" + \
            (f"v{v}" if v > 1 else "")
        failures += one(label, plan)
        if args.self_test:
            n, fails = self_test(plan)
            print(f"mutation self-test: {n - len(fails)}/{n} "
                  f"corruptions flagged")
            for f in fails:
                print(f"  MISSED {f}")
            failures += len(fails)
    if args.self_test and args.grid:
        pass
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
