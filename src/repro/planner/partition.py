"""PipeDream-style stage partitioning (1806.03377 §3.1).

Splits a contiguous layer list into N pipeline stages minimizing the
*bottleneck*: the steady-state throughput of a 1F1B pipeline is set by
its slowest stage, where a stage's cost is its per-layer compute plus the
cost of receiving its input activations over the inter-GPU link.

``dp_split`` is the exact O(L²·N) dynamic program over per-layer scalar
costs; ``partition_profile`` wraps it for :mod:`repro.planner.profiler`
profiles, converting FLOPs and activation bytes to seconds with the
hardware constants of the paper's platform (4×P40 over PCIe 3.0 x16).
``uniform`` is the equal-layer-count baseline the repo used to hardcode.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

# paper platform (§4.1) — mirrored from benchmarks/_timeline.py, which is
# not importable from src/
PEAK_FLOPS = 11.76e12 * 0.35    # fp32 peak × achievable efficiency
LINK_BW = 12.0e9                # bytes/s effective per PCIe link
BWD_FWD_RATIO = 2.0             # bwd ≈ 2× fwd compute


@dataclass(frozen=True)
class Partition:
    """Contiguous stage split: stage s owns layers
    ``[boundaries[s], boundaries[s+1])``."""
    boundaries: Tuple[int, ...]

    @property
    def n_stages(self) -> int:
        return len(self.boundaries) - 1

    @property
    def n_layers(self) -> int:
        return self.boundaries[-1]

    def stages(self) -> Tuple[Tuple[int, int], ...]:
        b = self.boundaries
        return tuple((b[s], b[s + 1]) for s in range(self.n_stages))

    def sizes(self) -> Tuple[int, ...]:
        return tuple(hi - lo for lo, hi in self.stages())

    def stage_of(self, layer: int) -> int:
        for s, (lo, hi) in enumerate(self.stages()):
            if lo <= layer < hi:
                return s
        raise ValueError(f"layer {layer} outside partition")


def _check(n_layers: int, n_stages: int) -> None:
    if n_stages < 1:
        raise ValueError(f"n_stages must be >= 1, got {n_stages}")
    if n_layers < n_stages:
        raise ValueError(
            f"cannot split {n_layers} layers into {n_stages} stages")


def uniform(n_layers: int, n_stages: int) -> Partition:
    """Equal-count contiguous split (remainder spread over early stages)."""
    _check(n_layers, n_stages)
    base, rem = divmod(n_layers, n_stages)
    bounds = [0]
    for s in range(n_stages):
        bounds.append(bounds[-1] + base + (1 if s < rem else 0))
    return Partition(tuple(bounds))


def stage_cost(compute: Sequence[float], cut_cost: Sequence[float],
               lo: int, hi: int) -> float:
    """Cost of a stage covering layers [lo, hi): compute plus the
    transfer cost of its incoming activation cut (0 for stage 0)."""
    c = sum(compute[lo:hi])
    if lo > 0:
        c += cut_cost[lo - 1]
    return c


def bottleneck(compute: Sequence[float], cut_cost: Sequence[float],
               part: Partition) -> float:
    return max(stage_cost(compute, cut_cost, lo, hi)
               for lo, hi in part.stages())


def dp_split(compute: Sequence[float], cut_cost: Sequence[float],
             n_stages: int) -> Partition:
    """Exact bottleneck-minimizing contiguous split.

    ``compute[j]``  — cost of executing layer j on a stage;
    ``cut_cost[j]`` — cost of cutting *after* layer j (transferring its
    output activations, fwd + cotangents bwd, to the next stage).

    DP over (prefix length, stage count):
      T[m][j] = min over i of max(T[m−1][i], stage_cost(i, j))
    with prefix sums making each stage_cost O(1).
    """
    L = len(compute)
    _check(L, n_stages)
    if len(cut_cost) not in (L, L - 1):
        raise ValueError(f"cut_cost length {len(cut_cost)} for {L} layers")

    prefix = [0.0]
    for c in compute:
        prefix.append(prefix[-1] + float(c))

    def cost(lo: int, hi: int) -> float:
        c = prefix[hi] - prefix[lo]
        if lo > 0:
            c += float(cut_cost[lo - 1])
        return c

    INF = float("inf")
    # T[m][j]: best bottleneck splitting layers [0, j) into m stages
    T = [[INF] * (L + 1) for _ in range(n_stages + 1)]
    arg = [[-1] * (L + 1) for _ in range(n_stages + 1)]
    T[0][0] = 0.0
    for m in range(1, n_stages + 1):
        for j in range(m, L + 1):
            best, best_i = INF, -1
            for i in range(m - 1, j):
                if T[m - 1][i] == INF:
                    continue
                v = max(T[m - 1][i], cost(i, j))
                if v < best:
                    best, best_i = v, i
            T[m][j] = best
            arg[m][j] = best_i
    bounds = [L]
    j = L
    for m in range(n_stages, 0, -1):
        j = arg[m][j]
        bounds.append(j)
    return Partition(tuple(reversed(bounds)))


# ---------------------------------------------------------------------------
# profile-level wrappers


def _costs_from_profile(profile, *, peak_flops: float = PEAK_FLOPS,
                        link_bw: float = LINK_BW
                        ) -> Tuple[Tuple[float, ...], Tuple[float, ...]]:
    """(compute seconds per layer, cut seconds after each layer).

    Compute counts fwd + bwd (≈3× fwd cost); measured wall time
    (``time_s``, the ``timed`` profile method) is preferred over the
    FLOPs/peak model when present — PipeDream's "profile, don't model".
    A cut moves the boundary activations forward and their cotangents
    backward (2× the bytes).
    """
    compute = tuple(
        (1.0 + BWD_FWD_RATIO) * (lp.time_s if lp.time_s > 0.0
                                 else lp.flops / peak_flops)
        for lp in profile.layers)
    cut = tuple(2.0 * lp.act_bytes / link_bw for lp in profile.layers)
    return compute, cut


def partition_profile(profile, n_stages: int, *, method: str = "dp",
                      peak_flops: float = PEAK_FLOPS,
                      link_bw: float = LINK_BW) -> Partition:
    compute, cut = _costs_from_profile(profile, peak_flops=peak_flops,
                                       link_bw=link_bw)
    if method == "uniform":
        return uniform(len(compute), n_stages)
    if method == "dp":
        return dp_split(compute, cut, n_stages)
    raise ValueError(f"unknown partition method {method!r}")


def profile_bottleneck(profile, part: Partition, *,
                       peak_flops: float = PEAK_FLOPS,
                       link_bw: float = LINK_BW) -> float:
    compute, cut = _costs_from_profile(profile, peak_flops=peak_flops,
                                       link_bw=link_bw)
    return bottleneck(compute, cut, part)


def profile_stage_costs(profile, part: Partition, *,
                        peak_flops: float = PEAK_FLOPS,
                        link_bw: float = LINK_BW) -> Tuple[float, ...]:
    """Modelled per-stage seconds (compute + incoming cut) for a
    partition — the realized per-stage cost a run under this plan pays;
    its max is :func:`profile_bottleneck`."""
    compute, cut = _costs_from_profile(profile, peak_flops=peak_flops,
                                       link_bw=link_bw)
    return tuple(stage_cost(compute, cut, lo, hi)
                 for lo, hi in part.stages())
