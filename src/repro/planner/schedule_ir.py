"""Event-timeline IR for pipeline schedules.

A :class:`Schedule` is an ordered list of typed events — ``fwd``/``bwd``
compute events and ``update`` events (each update names the stages whose
weights it touches and the minibatches whose gradients it applies).  Time
is discrete: events carry a tick ``t`` plus a deterministic sub-tick order
(fwd by ascending stage, then bwd by descending stage, then updates), so
every weight read happens before the same tick's weight writes.

Six emitters cover the schedules in this repo:

  * :func:`round_robin_1f1b` — the paper's §3.1 round-robin schedule (one
    global update per time unit, minibatch round trip of N−1 units).
  * :func:`gpipe` — fill/drain with gradient accumulation and a single
    update per round (the sync pipeline, ``core/pipeline_sync.py``).
  * :func:`streaming` — the tick schedule of ``core/pipeline_stream.py``
    (per-stage updates every tick, zero bubble after warm-up).
  * :func:`one_f_one_b` — PipeDream-flush 1F1B: per-stage warm-up
    forwards, steady one-forward-one-backward alternation, per-round
    flush update.  Staleness-free like GPipe, but stage k stashes only
    N−k activations instead of M.
  * :func:`pipedream_2bw` — PipeDream-2BW: continuous 1F1B with
    per-stage updates every ``m`` microbatches and double-buffered
    weights; every read is pinned one version behind its own update
    (uniform staleness 1).
  * :func:`interleaved_1f1b` — Megatron-style interleaved 1F1B: each of
    ``S`` devices hosts ``v ≥ 2`` virtual chunk-stages (device ``d``
    holds chunk-stages ``d, d+S, …``), shrinking the flush bubble from
    (S−1)/(M+S−1) to (S−1)/(M·v+S−1) per round.

The point of the IR is that weight-version differences are **derived**,
not assumed: :meth:`Schedule.staleness` counts the update events landing
on a stage's weights between a minibatch's weight-read event and that
minibatch's own gradient-apply event.  The closed forms in
``core/spectrain.py`` (Eqs. 5–6, the streaming variant, and the
1F1B-flush / 2BW constants) become checked properties of the
corresponding emitters instead of trusted constants.

Events may carry a *pinned read version* ``wv`` (a count of updates
already applied to that stage's weights) when the schedule dictates a
specific weight version rather than "whatever is current" — 2BW's
double-buffering is expressed this way, and the required weight-stash
ring depth per stage is derived (:meth:`Schedule.weight_stash_depth`)
instead of hardcoded.

Besides the event-object timeline, this module can **lower** one round
of a schedule to a dense, array-encoded :class:`EventTable`
(:func:`round_compute_program` → :func:`compile_event_table`): int32
columns carrying opcode, chunk-stage, microbatch slot, weight-version
lag and register-allocated activation/cotangent buffer slots.  The
table is what ``core/pipeline_stream.py``'s ``lax.scan`` interpreter
backend consumes — trace size O(#distinct branch bodies) instead of
O(M·C) unrolled events.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

FWD, BWD, UPDATE = "fwd", "bwd", "update"
_KIND_RANK = {FWD: 0, BWD: 1, UPDATE: 2}


@dataclass(frozen=True)
class Event:
    """One schedule event.

    ``stage``/``mb`` identify compute events; update events instead carry
    ``stages`` (weights written) and ``mbs`` (gradients applied) and keep
    ``stage = mb = -1``.  A compute event may pin its weight read to a
    specific version ``wv`` (the number of updates already applied to its
    stage's weights); ``wv = None`` means "read whatever is current" —
    the only read semantic the pre-2BW emitters needed.
    """
    kind: str
    t: int
    stage: int = -1
    mb: int = -1
    stages: Tuple[int, ...] = ()
    mbs: Tuple[int, ...] = ()
    wv: Optional[int] = None

    def sort_key(self):
        rank = _KIND_RANK[self.kind]
        # fwd consumes activations from the previous stage (ascending);
        # bwd consumes cotangents from the next stage (descending).
        sub = self.stage if self.kind == FWD else -self.stage
        return (self.t, rank, sub)


@dataclass
class Schedule:
    """Event timeline for ``n_stages`` logical pipeline stages.

    ``n_devices`` is the number of physical devices executing them
    (``None`` → one device per stage).  Interleaved/virtual-stage
    schedules set ``n_devices < n_stages``: device ``d`` hosts the
    chunk-stages ``{q : q % n_devices == d}`` (Megatron's round-robin
    chunk placement), and at most one compute event per device runs per
    tick.  Emitters of round-based schedules also set
    ``round_microbatches`` — the number of microbatches per flush round
    (1F1B, GPipe, interleaved) or per accumulation group (2BW)."""
    name: str
    n_stages: int
    events: List[Event] = field(default_factory=list)
    n_devices: Optional[int] = None
    round_microbatches: int = 0

    def __post_init__(self):
        self.events = sorted(self.events, key=Event.sort_key)
        self._index: Dict[Tuple[str, int, int], int] = {}
        self._own_update: Dict[Tuple[int, int], int] = {}
        self._ver_prefix: Dict[int, List[int]] = {}
        for i, e in enumerate(self.events):
            if e.kind == UPDATE:
                for k in e.stages:
                    for m in e.mbs:
                        self._own_update[(m, k)] = i
            else:
                self._index[(e.kind, e.mb, e.stage)] = i

    # ------------------------------------------------------------ queries
    def makespan(self) -> int:
        return max(e.t for e in self.events) + 1 if self.events else 0

    def minibatches(self) -> Tuple[int, ...]:
        return tuple(sorted({e.mb for e in self.events if e.kind == FWD}))

    def device_of(self, stage: int) -> int:
        """Physical device hosting a (chunk-)stage."""
        return stage % (self.n_devices or self.n_stages)

    def _versions(self, stage: int) -> List[int]:
        """Prefix counts: versions[i] = #updates on ``stage`` in
        events[:i] (cached — version_at is hot in metric derivation)."""
        if stage not in self._ver_prefix:
            pre = [0]
            for e in self.events:
                pre.append(pre[-1] + (1 if e.kind == UPDATE
                                      and stage in e.stages else 0))
            self._ver_prefix[stage] = pre
        return self._ver_prefix[stage]

    def version_at(self, event_idx: int, stage: int) -> int:
        """#updates touching ``stage``'s weights strictly before an event."""
        return self._versions(stage)[event_idx]

    def read_version(self, event_idx: int, stage: int) -> int:
        """Weight version a compute event reads: its pinned ``wv`` when
        the schedule dictates one, else the current version."""
        e = self.events[event_idx]
        if e.kind != UPDATE and e.wv is not None:
            return e.wv
        return self.version_at(event_idx, stage)

    def complete_minibatches(self) -> Tuple[int, ...]:
        """Minibatches with fwd+bwd on every stage and an applied update."""
        out = []
        for m in self.minibatches():
            ok = all((FWD, m, k) in self._index and (BWD, m, k) in self._index
                     for k in range(self.n_stages))
            ok = ok and all((m, k) in self._own_update
                            for k in range(self.n_stages))
            if ok:
                out.append(m)
        return tuple(out)

    def steady_minibatch(self) -> int:
        """A minibatch past warm-up (reads never truncated to version 0).

        The closed forms of ``core/spectrain.py`` describe steady state;
        early minibatches read the initial weights more often than the
        formulas say.  Any complete minibatch injected after the pipeline
        has filled (index ≥ 2·N) is in steady state for every schedule
        emitted here.
        """
        complete = self.complete_minibatches()
        if not complete:
            raise ValueError(f"{self.name}: no complete minibatch in IR")
        steady = [m for m in complete if m >= 2 * self.n_stages]
        if not steady:
            raise ValueError(
                f"{self.name}: timeline too short for steady state "
                f"(complete={complete[:4]}...); emit more minibatches")
        return steady[len(steady) // 2]

    # ---------------------------------------------------------- staleness
    def staleness(self, stage: int, phase: str, mb: Optional[int] = None
                  ) -> int:
        """Derived weight-version difference s for (stage, phase).

        s = #updates landing on ``stage``'s weights between the weight
        read of minibatch ``mb``'s fwd/bwd event and ``mb``'s own
        gradient-apply on that stage — the generic form of the paper's
        Eqs. 5–6.
        """
        if phase not in ("forward", "backward"):
            raise ValueError(phase)
        if not 0 <= stage < self.n_stages:
            raise ValueError(f"stage {stage} out of range for "
                             f"{self.n_stages} stages")
        if mb is None:
            mb = self.steady_minibatch()
        kind = FWD if phase == "forward" else BWD
        read = self._index.get((kind, mb, stage))
        own = self._own_update.get((mb, stage))
        if read is None or own is None:
            raise ValueError(f"minibatch {mb} incomplete on stage {stage}")
        return self.version_at(own, stage) - self.read_version(read, stage)

    def staleness_vector(self, phase: str, mb: Optional[int] = None
                         ) -> Tuple[int, ...]:
        if mb is None:
            mb = self.steady_minibatch()
        return tuple(self.staleness(k, phase, mb)
                     for k in range(self.n_stages))

    def bwd_lag(self, stage: int, mb: Optional[int] = None) -> int:
        """Ticks between a minibatch's injection (stage-0 forward) and its
        stage-k backward — how long gradients for stage k are in flight."""
        if mb is None:
            mb = self.steady_minibatch()
        bwd = self._index.get((BWD, mb, stage))
        fwd0 = self._index.get((FWD, mb, 0))
        if bwd is None or fwd0 is None:
            raise ValueError(f"minibatch {mb} incomplete on stage {stage}")
        return self.events[bwd].t - self.events[fwd0].t

    def fwd_bwd_gap(self, stage: int, mb: Optional[int] = None) -> int:
        """Ticks between a minibatch's stage-k forward and its stage-k
        backward — how long stage k must stash that minibatch's input
        activation (the streaming runtime's ring gather offset)."""
        if mb is None:
            mb = self.steady_minibatch()
        bwd = self._index.get((BWD, mb, stage))
        fwd = self._index.get((FWD, mb, stage))
        if bwd is None or fwd is None:
            raise ValueError(f"minibatch {mb} incomplete on stage {stage}")
        return self.events[bwd].t - self.events[fwd].t

    # ------------------------------------------------------------ metrics
    def bubble_fraction(self) -> float:
        """Idle fraction of device·tick slots over the whole timeline —
        the schedule-family cost axis (1F1B pays (S−1)/(M+S−1) per
        round, interleaved (S−1)/(M·v+S−1), streaming ~0 past warm-up).

        Slot width per tick is inferred as the peak per-(device, tick)
        occupancy: the unit-time emitters (streaming, round-robin) fit
        one fwd + one bwd per time unit, the list-scheduled families one
        op per tick."""
        D = self.n_devices or self.n_stages
        per_slot: Dict[Tuple[int, int], int] = {}
        for e in self.events:
            if e.kind == UPDATE:
                continue
            key = (self.device_of(e.stage), e.t)
            per_slot[key] = per_slot.get(key, 0) + 1
        if not per_slot:
            return 0.0
        width = max(per_slot.values())
        busy = sum(per_slot.values())
        return 1.0 - busy / (D * self.makespan() * width)

    def peak_activation_stash(self, stage: int) -> int:
        """Max #microbatches simultaneously holding a stashed input
        activation on ``stage`` (forward issued, backward not yet done) —
        the activation-memory axis: M for GPipe, S−k for 1F1B."""
        cur = peak = 0
        for e in self.events:
            if e.stage != stage:
                continue
            if e.kind == FWD:
                cur += 1
                peak = max(peak, cur)
            elif e.kind == BWD:
                cur -= 1
        return peak

    def weight_stash_depth(self, stage: int) -> int:
        """Weight versions ``stage`` must retain: 1 + the max distance
        between an event's current version and the (possibly pinned)
        version it reads.  1 for every always-read-current schedule,
        2 for 2BW's double buffering — the runtime sizes its weight
        rings from this instead of a hardcoded constant."""
        depth = 1
        for i, e in enumerate(self.events):
            if e.kind == UPDATE or e.stage != stage:
                continue
            lag = self.version_at(i, stage) - self.read_version(i, stage)
            depth = max(depth, lag + 1)
        return depth

    # ----------------------------------------------------------- validity
    def validate(self) -> None:
        """Dataflow sanity: activations and cotangents exist when read.

        * fwd(m, k) strictly after fwd(m, k−1)
        * bwd(m, N−1) strictly after fwd(m, N−1)
        * bwd(m, k) strictly after bwd(m, k+1)
        * m's update on stage k strictly after bwd(m, k)
        * every update references an emitted fwd and bwd for each
          (minibatch, stage) it applies — a gradient with no backward
          is a malformed timeline, not an incomplete one
        * a pinned read version exists when read (wv ≤ current version)
        * at most one compute event per (device, kind) per tick — the
          unit-time emitters (streaming, round-robin) model a time unit
          as one fwd slot plus one bwd slot
        """
        N = self.n_stages
        for i, e in enumerate(self.events):
            if e.kind != UPDATE:
                continue
            for k in e.stages:
                for m in e.mbs:
                    for kind in (FWD, BWD):
                        j = self._index.get((kind, m, k))
                        if j is None:
                            raise ValueError(
                                f"{self.name}: update at t={e.t} applies "
                                f"minibatch {m} on stage {k} with no "
                                f"{kind}({m},{k}) event")
                        if kind == BWD and not j < i:
                            raise ValueError(
                                f"{self.name}: update of {m} before "
                                f"bwd({m},{k})")
        for m in self.complete_minibatches():
            f = [self._index[(FWD, m, k)] for k in range(N)]
            b = [self._index[(BWD, m, k)] for k in range(N)]
            for k in range(1, N):
                if not f[k - 1] < f[k]:
                    raise ValueError(
                        f"{self.name}: fwd({m},{k}) before fwd({m},{k-1})")
            if not f[N - 1] < b[N - 1]:
                raise ValueError(f"{self.name}: bwd({m}) before fwd({m})")
            for k in range(N - 1):
                if not b[k + 1] < b[k]:
                    raise ValueError(
                        f"{self.name}: bwd({m},{k}) before bwd({m},{k+1})")
            for k in range(N):
                if not b[k] < self._own_update[(m, k)]:
                    raise ValueError(
                        f"{self.name}: update of {m} before bwd({m},{k})")
        busy: Dict[Tuple[int, int, str], Tuple[str, int]] = {}
        for i, e in enumerate(self.events):
            if e.kind == UPDATE:
                continue
            if e.wv is not None and e.wv > self.version_at(i, e.stage):
                raise ValueError(
                    f"{self.name}: {e.kind}({e.mb},{e.stage}) pins "
                    f"version {e.wv}, only "
                    f"{self.version_at(i, e.stage)} exist")
            slot = (self.device_of(e.stage), e.t, e.kind)
            if slot in busy:
                raise ValueError(
                    f"{self.name}: device {slot[0]} double-booked at "
                    f"t={e.t}: {busy[slot]} and ({e.kind},{e.mb})")
            busy[slot] = (e.kind, e.mb)

    # ------------------------------------------------------------- render
    def render(self, max_ticks: int = 24) -> str:
        """ASCII timeline: one row per *device*, ``f<mb>``/``b<mb>``
        cells.  With virtual stages (n_devices < n_stages) a cell is
        ``f<mb>.<c>`` where ``c`` is the device-local chunk index."""
        D = self.n_devices or self.n_stages
        v = self.n_stages // D
        grid: Dict[Tuple[int, int], List[str]] = {}
        for e in self.events:
            if e.kind == UPDATE:
                for k in e.stages:
                    grid.setdefault((self.device_of(k), e.t), []).append("u")
            else:
                cell = f"{e.kind[0]}{e.mb}"
                if v > 1:
                    cell += f".{e.stage // D}"
                grid.setdefault((self.device_of(e.stage), e.t),
                                []).append(cell)
        T = min(self.makespan(), max_ticks)
        width = max([len("+".join(grid.get((d, t), [])))
                     for d in range(D) for t in range(T)] + [2])
        rows = []
        for d in range(D):
            cells = ["+".join(grid.get((d, t), [])).ljust(width)
                     for t in range(T)]
            rows.append(f"d{d} |" + "|".join(cells) + "|")
        return "\n".join(rows)


# ===========================================================================
# emitters
# ===========================================================================


def _default_mbs(n_stages: int) -> int:
    # enough for fill, a steady-state region past 2N, and drain
    return 6 * n_stages + 4


def round_robin_1f1b(n_stages: int, n_minibatches: Optional[int] = None
                     ) -> Schedule:
    """The paper's round-robin schedule (§3.1, Figs. 4/7).

    Each time unit every GPU runs one forward and one backward slot;
    minibatch i runs fwd on stage k at unit ``i + ⌈k/2⌉`` and bwd at unit
    ``i + N − 1 − ⌊k/2⌋``; its round trip completes in N−1 units and its
    gradient updates all stages at the end of unit ``i + N − 1`` (one
    global weight version per unit).
    """
    N = n_stages
    M = n_minibatches or _default_mbs(N)
    ev: List[Event] = []
    all_stages = tuple(range(N))
    for i in range(M):
        for k in range(N):
            ev.append(Event(FWD, i + (k + 1) // 2, stage=k, mb=i))
            ev.append(Event(BWD, i + N - 1 - k // 2, stage=k, mb=i))
        ev.append(Event(UPDATE, i + N - 1, stages=all_stages, mbs=(i,)))
    return Schedule("1f1b_rr", N, ev)


def gpipe(n_stages: int, n_microbatches: Optional[int] = None,
          n_rounds: int = 3) -> Schedule:
    """GPipe fill/drain: all microbatches forward, then all backward, then
    one accumulated update — staleness-free (s_fwd = s_bwd = 0) at the
    cost of a 2(N−1)-slot bubble per round."""
    N = n_stages
    M = n_microbatches or max(2, 2 * N)
    ev: List[Event] = []
    all_stages = tuple(range(N))
    span = 2 * (M + N - 1) + 1
    for r in range(n_rounds):
        base = r * span
        mbs = tuple(r * M + m for m in range(M))
        for m in range(M):
            for k in range(N):
                ev.append(Event(FWD, base + m + k, stage=k, mb=r * M + m))
                ev.append(Event(
                    BWD, base + (M + N - 1) + (M - 1 - m) + (N - 1 - k),
                    stage=k, mb=r * M + m))
        ev.append(Event(UPDATE, base + span - 1, stages=all_stages, mbs=mbs))
    return Schedule("gpipe", N, ev, round_microbatches=M)


def streaming(n_stages: int, n_ticks: Optional[int] = None) -> Schedule:
    """The streaming tick schedule (``core/pipeline_stream.py``).

    Per tick t, stage k forwards the minibatch injected k ticks ago and
    backwards the one injected 2(N−1)−k ticks ago, then applies that
    minibatch's gradient to **its own** weights — per-stage, per-tick
    updates (minibatch id == injection tick).
    """
    N = n_stages
    T = n_ticks or (_default_mbs(N) + 2 * (N - 1))
    ev: List[Event] = []
    for t in range(T):
        for k in range(N):
            if t - k >= 0:
                ev.append(Event(FWD, t, stage=k, mb=t - k))
            mb_b = t - 2 * (N - 1) + k
            if mb_b >= 0 and mb_b <= t:
                ev.append(Event(BWD, t, stage=k, mb=mb_b))
                ev.append(Event(UPDATE, t, stages=(k,), mbs=(mb_b,)))
    return Schedule("stream", N, ev)


# ---------------------------------------------------------------------------
# 1F1B family: PipeDream-flush, PipeDream-2BW, Megatron interleaved.
#
# All three share one construction: a fixed Megatron-style op order per
# device (warm-up forwards, steady fwd/bwd alternation, cool-down
# backwards) turned into a tick timeline by deterministic list
# scheduling — each tick, each device runs its next op iff the op's
# dataflow inputs were produced at a strictly earlier tick.


def _device_op_order(S: int, v: int, M: int, d: int
                     ) -> List[Tuple[str, int, int]]:
    """Op sequence ``(kind, mb, chunk_stage)`` for device ``d`` over one
    round of ``M`` microbatches across ``v`` chunks per device.

    Chunk-stage ``q = c·S + d`` is device ``d``'s ``c``-th chunk
    (Megatron placement); forwards walk microbatches in groups of S per
    chunk, backwards the same groups with chunks reversed.  Warm-up
    depth is Megatron's: S−d−1 for v = 1, else 2(S−d−1) + (v−1)·S.
    """
    n = M * v

    def fwd_op(i):
        if v == 1:
            mb, c = i, 0
        else:
            g, r = divmod(i, S * v)
            c, mb = r // S, g * S + r % S
        return (FWD, mb, c * S + d)

    def bwd_op(j):
        if v == 1:
            mb, c = j, 0
        else:
            g, r = divmod(j, S * v)
            c, mb = v - 1 - r // S, g * S + r % S
        return (BWD, mb, c * S + d)

    warmup = min(n, (S - d - 1) if v == 1 else 2 * (S - d - 1) + (v - 1) * S)
    ops = [fwd_op(i) for i in range(warmup)]
    for j in range(n - warmup):
        ops.append(fwd_op(warmup + j))
        ops.append(bwd_op(j))
    ops.extend(bwd_op(j) for j in range(n - warmup, n))
    return ops


def _list_schedule(S: int, v: int, M: int, *, mb_base: int = 0,
                   t_base: int = 0) -> Dict[Tuple[str, int, int], int]:
    """Tick assignment ``(kind, mb, chunk_stage) -> t`` for one round.

    Time-stepped: each tick every device runs its next queued op iff
    that op's producer finished at a strictly earlier tick (fwd needs
    the previous chunk-stage's fwd, bwd the next chunk-stage's bwd, the
    last chunk-stage's bwd its own fwd).
    """
    C = S * v
    queues = [_device_op_order(S, v, M, d) for d in range(S)]
    heads = [0] * S
    done: Dict[Tuple[str, int, int], int] = {}
    t = t_base
    while any(heads[d] < len(queues[d]) for d in range(S)):
        progressed = False
        for d in range(S):
            if heads[d] >= len(queues[d]):
                continue
            kind, mb, q = queues[d][heads[d]]
            if kind == FWD:
                ready = q == 0 or done.get((FWD, mb, q - 1), t) < t
            elif q == C - 1:
                ready = done.get((FWD, mb, q), t) < t
            else:
                ready = done.get((BWD, mb, q + 1), t) < t
            if ready:
                done[(kind, mb, q)] = t
                heads[d] += 1
                progressed = True
        if not progressed:
            # nothing ran this tick ⇒ `done` is unchanged ⇒ nothing can
            # ever become ready: the fixed per-device op order is cyclic
            raise RuntimeError(
                f"list scheduler deadlocked at t={t} "
                f"(S={S}, v={v}, M={M})")
        t += 1
    return {(k, mb + mb_base, q): tt for (k, mb, q), tt in done.items()}


def _flush_rounds(name: str, S: int, v: int, M: int, n_rounds: int
                  ) -> Schedule:
    """Rounds of M microbatches, per-stage flush update at each stage's
    last backward of the round — staleness-free by construction."""
    if v > 1 and M % S:
        raise ValueError(
            f"interleaved needs n_microbatches % n_stages == 0, got "
            f"M={M}, S={S}")
    C = S * v
    ev: List[Event] = []
    t_base = 0
    for r in range(n_rounds):
        ticks = _list_schedule(S, v, M, mb_base=r * M, t_base=t_base)
        mbs = tuple(range(r * M, (r + 1) * M))
        for (kind, mb, q), t in ticks.items():
            ev.append(Event(kind, t, stage=q, mb=mb))
        for q in range(C):
            last_b = max(t for (k, mb, qq), t in ticks.items()
                         if k == BWD and qq == q)
            ev.append(Event(UPDATE, last_b, stages=(q,), mbs=mbs))
        t_base = max(ticks.values()) + 1
    return Schedule(name, C, ev, n_devices=S, round_microbatches=M)


def _rounds_for(C: int, M: int, n_rounds: Optional[int]) -> int:
    # enough rounds that a steady minibatch (index ≥ 2C) exists
    if n_rounds is not None:
        return n_rounds
    need = 2 * C + 2
    return max(3, -(-need // M) + 1)


def one_f_one_b(n_stages: int, n_microbatches: Optional[int] = None,
                n_rounds: Optional[int] = None) -> Schedule:
    """PipeDream-flush 1F1B: stage k runs S−1−k warm-up forwards, then
    one-forward-one-backward steady state, then drains; gradients
    accumulate across the round's M microbatches and flush in one
    per-stage update.  Staleness-free (s_fwd = s_bwd = 0) at the same
    (S−1)/(M+S−1) bubble as GPipe, but stage k stashes only S−k
    activations instead of M."""
    S = n_stages
    M = n_microbatches or max(2, 2 * S)
    return _flush_rounds("1f1b", S, 1, M, _rounds_for(S, M, n_rounds))


def interleaved_1f1b(n_stages: int, n_microbatches: Optional[int] = None,
                     *, v: int = 2, n_rounds: Optional[int] = None
                     ) -> Schedule:
    """Megatron-style interleaved 1F1B: device d hosts the v chunk-stages
    ``d, d+S, …``; the round's bubble shrinks to (S−1)/(M·v+S−1) at the
    price of v× more in-flight chunk activations and p2p traffic.  Still
    staleness-free (flush update per round)."""
    if v < 1:
        raise ValueError(f"virtual stages v must be >= 1, got {v}")
    S = n_stages
    M = n_microbatches if n_microbatches is not None else max(2 * S, 2)
    return _flush_rounds("interleaved", S, v, M,
                         _rounds_for(S * v, M, n_rounds))


def pipedream_2bw(n_stages: int, n_microbatches: Optional[int] = None,
                  n_groups: Optional[int] = None) -> Schedule:
    """PipeDream-2BW: continuous 1F1B (no flush) with per-stage updates
    every ``m = n_microbatches`` microbatches and double-buffered
    weights.  Group g's fwd *and* bwd reads are pinned (``wv``) to the
    version with g−1 updates applied — the newest version every stage is
    guaranteed to have when the group's first forward arrives, given the
    paper's m ≥ S constraint.  Derived staleness is therefore a uniform
    1 and the derived weight-stash depth 2 (the "2-buffered weights")."""
    S = n_stages
    m = n_microbatches or max(2, S)
    if m < S:
        raise ValueError(
            f"2bw needs n_microbatches >= n_stages for 2 weight buffers "
            f"to suffice, got m={m}, S={S}")
    G = n_groups or max(3, -(-(2 * S + 2) // m) + 1)
    ticks = _list_schedule(S, 1, m * G)
    ev: List[Event] = []
    for (kind, mb, q), t in ticks.items():
        ev.append(Event(kind, t, stage=q, mb=mb,
                        wv=max(0, mb // m - 1)))
    for g in range(G):
        mbs = tuple(range(g * m, (g + 1) * m))
        for q in range(S):
            last_b = max(ticks[(BWD, mb, q)] for mb in mbs)
            ev.append(Event(UPDATE, last_b, stages=(q,), mbs=mbs))
    return Schedule("2bw", S, ev, round_microbatches=m)


EMITTERS = {
    "1f1b_rr": round_robin_1f1b,
    "gpipe": gpipe,
    "stream": streaming,
    "1f1b": one_f_one_b,
    "2bw": pipedream_2bw,
    "interleaved": interleaved_1f1b,
}

# schedules whose emitters take a per-round/group microbatch count and
# which core/pipeline_stream.py executes through the IR interpreter —
# the single source for planner/api.py and the runtimes
ROUND_SCHEDULES = ("gpipe", "1f1b", "2bw", "interleaved")


def emit(name: str, n_stages: int, **kw) -> Schedule:
    if name not in EMITTERS:
        raise KeyError(f"unknown schedule {name!r}; known: {sorted(EMITTERS)}")
    return EMITTERS[name](n_stages, **kw)


# ===========================================================================
# lowering: one round of compute events -> a dense int32 event table
# ===========================================================================
#
# The scan interpreter in ``core/pipeline_stream.py`` executes one table
# row per ``lax.scan`` iteration, dispatching on a *branch id* that
# statically encodes (opcode, chunk-stage, weight-version lag) — the
# three facts that pick a traced branch body (ragged chunk weights make
# per-chunk dispatch unavoidable; the lag picks a predicted weight
# tree).  Everything dynamic per event lives in int32 columns:

# row columns (COL_* indices into EventTable.rows[i])
COL_BRANCH = 0   # index into EventTable.branches (lax.switch arm)
COL_OP = 1       # 0 = fwd, 1 = bwd (informational: branch id implies it)
COL_CHUNK = 2    # chunk-stage q (informational: branch id implies it)
COL_MB = 3       # microbatch slot m within the round, 0..M-1
COL_WV = 4       # weight-version lag s of the event's read (in branch id)
COL_A = 5        # fwd q==0: write slot of v(m,0) (the embed output)
#                  fwd q>0:  read slot of v(m,q) (the chunk input)
#                  bwd:      read slot of v(m,q) (the stashed activation)
COL_B = 6        # fwd:       write slot of v(m,q+1) (the chunk output)
#                  bwd q==C-1: read slot of v(m,C) (the head input)
#                  bwd q<C-1:  read cot slot of c(m,q+1) (output cotangent)
COL_C = 7        # bwd q>0: write cot slot of c(m,q); else -1
COL_FIRST_G = 8  # 1 iff this bwd event is chunk q's first grad contribution
COL_FIRST_O = 9  # 1 iff this is the first *head* outer-grad contribution
COL_FIRST_E = 10  # 1 iff this is the first *embed* outer-grad contribution
N_COLS = 11

OP_FWD, OP_BWD = 0, 1


@dataclass(frozen=True, eq=False)
class EventTable:
    """Dense array encoding of one schedule round.

    ``branches[b] = (kind, chunk_stage, wv_lag)`` — the static facts a
    ``lax.switch`` arm closes over; ``rows`` is ``[2·M·C, N_COLS]``
    int32 (column semantics above).  Buffer slots are register-allocated
    over the round (greedy lowest-free-slot over value lifetimes), so
    ``n_val_slots`` / ``n_cot_slots`` are the schedule's true peak
    in-flight activation / cotangent counts — buffer memory is set by
    the schedule, trace size by ``len(branches)`` (≤ 2·C, independent
    of M).

    Value naming: ``v(m, q)`` is microbatch m's input to chunk q (the
    embed output for q = 0) for q in 0..C-1, and ``v(m, C)`` the last
    chunk's output consumed by the loss head; ``c(m, q)`` is the
    cotangent w.r.t. ``v(m, q)``, buffered only for 0 < q < C (the head
    produces c(m, C) in-branch; the embed backward consumes c(m, 0)
    in-branch).
    """
    n_chunks: int
    n_microbatches: int
    branches: Tuple[Tuple[str, int, int], ...]
    rows: np.ndarray
    n_val_slots: int
    n_cot_slots: int

    def __post_init__(self):
        self.rows.setflags(write=False)


def round_compute_events(sched: Schedule, *, base: int = 0
                         ) -> List[Tuple[str, int, int, int, int]]:
    """One round's compute events ``(kind, local_mb, chunk_stage, s, t)``
    in timeline order, with ``s`` the IR-derived weight-version lag of
    each event's read and ``t`` the event's schedule tick (raw — callers
    normalize).  The tick is what :func:`compile_device_streams` needs
    to slice the round into per-device event streams; callers that only
    interpret the global timeline use :func:`round_compute_program`.

    ``base`` selects the round's first minibatch: flush schedules repeat
    identically from round 0, 2BW's group 0 still reads the initial
    weights (warm-up truncation), so its callers pass ``base = m`` to
    lower a steady group.
    """
    M = sched.round_microbatches
    if M < 1:
        raise ValueError(
            f"{sched.name}: not a round schedule (round_microbatches={M})")
    prog = []
    for e in sched.events:
        if e.kind == UPDATE or not base <= e.mb < base + M:
            continue
        phase = "forward" if e.kind == FWD else "backward"
        prog.append((e.kind, e.mb - base, e.stage,
                     sched.staleness(e.stage, phase, e.mb), e.t))
    want = 2 * M * sched.n_stages
    if len(prog) != want:
        raise ValueError(
            f"{sched.name}: round at base {base} has {len(prog)} compute "
            f"events, expected {want}")
    return prog


def round_compute_program(sched: Schedule, *, base: int = 0
                          ) -> List[Tuple[str, int, int, int]]:
    """One round's compute events ``(kind, local_mb, chunk_stage, s)``
    in timeline order — :func:`round_compute_events` with the ticks
    dropped (the global-timeline interpreters don't need them)."""
    return [(kind, m, q, s)
            for kind, m, q, s, _t in round_compute_events(sched, base=base)]


def compile_event_table(prog: List[Tuple[str, int, int, int]],
                        n_chunks: int, n_microbatches: int) -> EventTable:
    """Lower a round program (:func:`round_compute_program`) to an
    :class:`EventTable`.

    Walks the program once, allocating buffer slots over value
    lifetimes: ``v(m, q)`` is born at its producing forward and dies at
    chunk q's backward (the head input ``v(m, C)`` at chunk C-1's
    backward); ``c(m, q)`` is born at chunk q's backward and dies at
    chunk q-1's.  Slots freed by an event may be reused by the same
    event's write — the interpreter reads all inputs before writing.
    """
    C, M = n_chunks, n_microbatches
    if len(prog) != 2 * M * C:
        raise ValueError(f"program has {len(prog)} events, expected "
                         f"{2 * M * C} (= 2·{M}·{C})")
    specs: List[Tuple[str, int, int]] = []
    spec_ix: Dict[Tuple[str, int, int], int] = {}
    rows = []
    val_slot: Dict[Tuple[int, int], int] = {}
    cot_slot: Dict[Tuple[int, int], int] = {}
    free: List[List[int]] = [[], []]      # min-heaps: [values, cotangents]
    hwm = [0, 0]                          # slot high-water marks

    def alloc(pool: int) -> int:
        if free[pool]:
            return heapq.heappop(free[pool])
        hwm[pool] += 1
        return hwm[pool] - 1

    seen_g = set()
    head_seen = embed_seen = False
    for kind, m, q, s in prog:
        if not (0 <= m < M and 0 <= q < C):
            raise ValueError(f"event ({kind},{m},{q}) out of range for "
                             f"M={M}, C={C}")
        key = (kind, q, s)
        if key not in spec_ix:
            spec_ix[key] = len(specs)
            specs.append(key)
        fg = fo = fe = 0
        if kind == FWD:
            op = OP_FWD
            if (m, q + 1) in val_slot:
                raise ValueError(f"fwd({m},{q}) emitted twice")
            if q == 0:
                a = alloc(0)
                val_slot[(m, 0)] = a
            else:
                if (m, q) not in val_slot:
                    raise ValueError(f"fwd({m},{q}) before fwd({m},{q-1})")
                a = val_slot[(m, q)]
            b = alloc(0)
            val_slot[(m, q + 1)] = b
            c = -1
        else:
            op = OP_BWD
            if (m, q) not in val_slot:
                raise ValueError(f"bwd({m},{q}) before fwd({m},{q}) or "
                                 f"emitted twice")
            a = val_slot.pop((m, q))
            heapq.heappush(free[0], a)
            if q == C - 1:
                b = val_slot.pop((m, C))
                heapq.heappush(free[0], b)
            else:
                if (m, q + 1) not in cot_slot:
                    raise ValueError(f"bwd({m},{q}) before bwd({m},{q+1})")
                b = cot_slot.pop((m, q + 1))
                heapq.heappush(free[1], b)
            c = -1
            if q > 0:
                c = alloc(1)
                cot_slot[(m, q)] = c
            if q not in seen_g:
                seen_g.add(q)
                fg = 1
            # the outer grad is accumulated as two independent streams
            # (head contributions at chunk C-1, embed contributions at
            # chunk 0) combined once at the end of the round — the
            # association the MPMD backend can reproduce without
            # per-event cross-device traffic
            if q == C - 1 and not head_seen:
                head_seen = True
                fo = 1
            if q == 0 and not embed_seen:
                embed_seen = True
                fe = 1
        rows.append((spec_ix[key], op, q, m, s, a, b, c, fg, fo, fe))
    if val_slot or cot_slot:
        raise ValueError(
            f"round leaves in-flight values: "
            f"{sorted(val_slot) + sorted(cot_slot)}")
    return EventTable(
        n_chunks=C, n_microbatches=M, branches=tuple(specs),
        rows=np.asarray(rows, np.int32),
        n_val_slots=hwm[0], n_cot_slots=hwm[1])


# ===========================================================================
# lowering: one round -> per-device event streams (the MPMD execution
# path: each pipe device runs its own stream inside shard_map, and
# activations/cotangents cross stage cuts via ppermute)
# ===========================================================================
#
# Device-stream rows are tick-indexed: ``rows[t, d]`` is what device
# ``d`` does at synchronous tick ``t`` — at most one compute event (the
# lax.switch branch id) plus up to one incoming forward activation and
# one incoming backward cotangent, written into *device-local*
# value/cotangent pools.  Transfers happen on the producing tick: a
# forward output crosses to device d+1 (ring), a backward cotangent to
# device d-1, and the receiver's row says which local slot to park the
# payload in (−1 → a trash slot; the ring carries garbage on idle
# ticks so the program stays SPMD).

# row columns (DCOL_* indices into DeviceStreams.rows[t, d])
DCOL_BRANCH = 0   # lax.switch arm; -1 in the np array is re-written to
#                   the NOP arm (= len(branches)) before freezing
DCOL_MB = 1       # microbatch slot m within the round
DCOL_A = 2        # fwd q==0: write slot of v(m,0); fwd q>0 and bwd:
#                   read slot of v(m,q) (the chunk input / stashed act)
DCOL_B = 3        # fwd q==C-1: write slot of v(m,C) (the head input);
#                   bwd q==C-1: read slot of v(m,C); else -1
DCOL_C = 4        # bwd q<C-1: read slot of the incoming cotangent
DCOL_RECV_F = 5   # local val slot for this tick's incoming fwd payload
DCOL_RECV_B = 6   # local cot slot for this tick's incoming bwd payload
DCOL_FIRST_G = 7  # 1 iff chunk q's first grad contribution
DCOL_FIRST_O = 8  # 1 iff the first head outer-grad contribution
DCOL_FIRST_E = 9  # 1 iff the first embed outer-grad contribution
DN_COLS = 10


@dataclass(frozen=True, eq=False)
class DeviceStreams:
    """Per-device tick streams of one schedule round.

    ``rows`` is ``[T, S, DN_COLS]`` int32 — slicing column ``d`` with a
    ``PartitionSpec(None, 'pipe')`` hands each device exactly its own
    stream.  Buffer slots are register-allocated **per device**, so
    ``n_val_slots`` / ``n_cot_slots`` (the max over devices — pools are
    uniform so the program stays SPMD) are per-device peaks: a chunk's
    activation stash is spread across the devices that host it instead
    of replicated, the PR 5 follow-up.  Every device executes the same
    branch list (a device's rows only ever select its own chunks'
    branches); arm ``len(branches)`` is the NOP.
    """
    n_chunks: int
    n_microbatches: int
    n_devices: int
    branches: Tuple[Tuple[str, int, int], ...]
    rows: np.ndarray
    n_val_slots: int
    n_cot_slots: int

    def __post_init__(self):
        self.rows.setflags(write=False)


def compile_device_streams(events: List[Tuple[str, int, int, int, int]],
                           n_chunks: int, n_microbatches: int,
                           n_devices: int) -> DeviceStreams:
    """Lower a round's compute events (:func:`round_compute_events`) to
    per-device tick streams (:class:`DeviceStreams`).

    Chunk ``q`` lives on device ``q % n_devices`` (Megatron round-robin
    folding); ticks are the rank-compressed distinct event start times,
    so cross-device dependencies are always separated by at least one
    tick (an event's consumers start strictly after it).  Value
    lifetimes: a received activation is born on the consumer's device
    at the *producer's* tick and dies at the consumer chunk's backward;
    in-branch values (the embed output on device 0, the head input on
    the last chunk's device) are born at their forward.  Slots freed by
    a tick's compute may be reused by the same tick's writes — the
    interpreter reads all branch inputs before writing, and payload
    receives land after the branch runs.
    """
    C, M, S = n_chunks, n_microbatches, n_devices
    if len(events) != 2 * M * C:
        raise ValueError(f"program has {len(events)} events, expected "
                         f"{2 * M * C} (= 2·{M}·{C})")
    if S < 1 or C % S:
        raise ValueError(f"{C} chunks do not fold onto {S} devices "
                         f"(n_chunks % n_devices != 0)")
    ranks = {t: i for i, t in enumerate(sorted({e[4] for e in events}))}
    T = len(ranks)
    by_tick: Dict[int, List[Tuple[str, int, int, int]]] = {}
    seen_dev: set = set()
    for kind, m, q, s, t in events:
        if not (0 <= m < M and 0 <= q < C):
            raise ValueError(f"event ({kind},{m},{q}) out of range for "
                             f"M={M}, C={C}")
        r, d = ranks[t], q % S
        if (r, d) in seen_dev:
            raise ValueError(
                f"device {d} has two compute events at tick {t} — the "
                f"schedule is not one-event-per-(device, tick)")
        seen_dev.add((r, d))
        by_tick.setdefault(r, []).append((kind, m, q, s))

    specs: List[Tuple[str, int, int]] = []
    spec_ix: Dict[Tuple[str, int, int], int] = {}
    rows = np.full((T, S, DN_COLS), -1, np.int32)
    rows[:, :, DCOL_MB] = 0
    rows[:, :, DCOL_FIRST_G] = 0
    rows[:, :, DCOL_FIRST_O] = 0
    rows[:, :, DCOL_FIRST_E] = 0

    # per-device register allocators: [device][pool] min-heap + hwm
    free = [[[], []] for _ in range(S)]
    hwm = [[0, 0] for _ in range(S)]

    def alloc(d: int, pool: int) -> int:
        if free[d][pool]:
            return heapq.heappop(free[d][pool])
        hwm[d][pool] += 1
        return hwm[d][pool] - 1

    val_slot: Dict[Tuple[int, int], int] = {}   # x(m,q) on device q%S
    out_slot: Dict[int, int] = {}               # v(m,C) on device (C-1)%S
    cot_slot: Dict[Tuple[int, int], int] = {}   # cot read by bwd(m,q)
    seen_g: set = set()
    head_seen = embed_seen = False

    for r in range(T):
        evs = sorted(by_tick.get(r, ()), key=lambda e: e[2] % S)
        # phase 1: frees from this tick's reads (before any allocation)
        for kind, m, q, s in evs:
            d = q % S
            if kind != BWD:
                continue
            if (m, q) not in val_slot:
                raise ValueError(f"bwd({m},{q}) before fwd({m},{q}) or "
                                 f"emitted twice")
            heapq.heappush(free[d][0], val_slot[(m, q)])
            if q == C - 1:
                if m not in out_slot:
                    raise ValueError(f"bwd({m},{q}) before fwd({m},{q})")
                heapq.heappush(free[d][0], out_slot[m])
            elif (m, q) not in cot_slot:
                raise ValueError(f"bwd({m},{q}) before bwd({m},{q+1})")
            else:
                heapq.heappush(free[d][1], cot_slot[(m, q)])
        # phase 2: the events' own rows + in-branch writes
        for kind, m, q, s in evs:
            d = q % S
            key = (kind, q, s)
            if key not in spec_ix:
                spec_ix[key] = len(specs)
                specs.append(key)
            row = rows[r, d]
            row[DCOL_BRANCH] = spec_ix[key]
            row[DCOL_MB] = m
            if kind == FWD:
                if q == 0:
                    if (m, 0) in val_slot:
                        raise ValueError(f"fwd({m},0) emitted twice")
                    val_slot[(m, 0)] = alloc(d, 0)
                elif (m, q) not in val_slot:
                    raise ValueError(f"fwd({m},{q}) before fwd({m},{q-1})")
                row[DCOL_A] = val_slot[(m, q)]
                if q == C - 1:
                    if m in out_slot:
                        raise ValueError(f"fwd({m},{q}) emitted twice")
                    out_slot[m] = alloc(d, 0)
                    row[DCOL_B] = out_slot[m]
            else:
                row[DCOL_A] = val_slot.pop((m, q))
                if q == C - 1:
                    row[DCOL_B] = out_slot.pop(m)
                else:
                    row[DCOL_C] = cot_slot.pop((m, q))
                if q not in seen_g:
                    seen_g.add(q)
                    row[DCOL_FIRST_G] = 1
                if q == C - 1 and not head_seen:
                    head_seen = True
                    row[DCOL_FIRST_O] = 1
                if q == 0 and not embed_seen:
                    embed_seen = True
                    row[DCOL_FIRST_E] = 1
        # phase 3: payload receives on the ring neighbors (land after
        # the neighbors' branch bodies ran, so freed slots are reusable)
        for kind, m, q, s in evs:
            d = q % S
            if kind == FWD and q < C - 1:
                nd = (d + 1) % S
                if (m, q + 1) in val_slot:
                    raise ValueError(f"fwd({m},{q}) emitted twice")
                slot = alloc(nd, 0)
                val_slot[(m, q + 1)] = slot
                rows[r, nd, DCOL_RECV_F] = slot
            elif kind == BWD and q > 0:
                nd = (d - 1) % S
                slot = alloc(nd, 1)
                cot_slot[(m, q - 1)] = slot
                rows[r, nd, DCOL_RECV_B] = slot

    if val_slot or out_slot or cot_slot:
        raise ValueError(
            f"round leaves in-flight values: "
            f"{sorted(val_slot) + sorted(out_slot) + sorted(cot_slot)}")
    # un-filled branch column -> the NOP arm (a valid switch index)
    br = rows[:, :, DCOL_BRANCH]
    br[br < 0] = len(specs)
    return DeviceStreams(
        n_chunks=C, n_microbatches=M, n_devices=S, branches=tuple(specs),
        rows=rows,
        n_val_slots=max(h[0] for h in hwm),
        n_cot_slots=max(h[1] for h in hwm))


# ===========================================================================
# serving round lowering: prefill/decode opcodes -> a dense serve table
# (SPMD scan backend) and per-device serve streams (MPMD backend)
# ===========================================================================
#
# A serving round is forward-only: one batched **decode wave** (every
# live request slot advances one token, its per-stage KV pages updated
# in place) plus up to ``F = max_prefill`` **prefill lanes** (each lane
# runs one freshly admitted prompt through every stage, writing that
# request's KV pages from scratch).  The round is a pure staircase —
# the decode wave occupies device q at tick q, prefill lane j at tick
# 1 + j + q — so exactly one event runs per (device, tick) and every
# cut transfer crosses to device q+1 on the producing tick, the same
# one-event-per-(device, tick) invariant the training streams hold.
# Serving folds one chunk per device (C == S, no virtual stages):
# decode state is the KV pages themselves, which live where their
# chunk's weights live.

PREFILL, DECODE = "prefill", "decode"
OP_DECODE, OP_PREFILL = 2, 3          # extends OP_FWD/OP_BWD's numbering

# row columns (SCOL_* indices into ServeTable.rows[i])
SCOL_BRANCH = 0  # index into ServeTable.branches (lax.switch arm)
SCOL_OP = 1      # OP_DECODE / OP_PREFILL (informational: branch implies it)
SCOL_CHUNK = 2   # chunk-stage q (informational: branch implies it)
SCOL_MB = 3      # prefill lane j, 0..F-1; 0 for the decode wave
SCOL_A = 4       # q>0: read slot of the lane's incoming hidden; -1 at q==0
SCOL_B = 5       # q<C-1: write slot of the outgoing hidden; -1 at q==C-1
SCOL_T = 6       # staircase tick (q + lane offset; verifier-checked)
SN_COLS = 7


@dataclass(frozen=True, eq=False)
class ServeTable:
    """Dense array encoding of one serving round.

    ``branches[b] = (kind, chunk_stage)`` with ``kind`` in
    {``decode``, ``prefill``} — the static facts a ``lax.switch`` arm
    closes over (chunk picks the weights and KV-page buffer; kind picks
    the single-token wave vs. the masked whole-prompt scan).  ``rows``
    is ``[(1+F)·C, SN_COLS]`` int32.  Hidden-state slots are
    register-allocated over the round exactly like the training
    table's activation slots: the decode wave's [R, 1, d] hiddens and
    the prefill lanes' [1, P, d] hiddens live in two separate pools
    (different shapes), so ``n_dec_slots`` / ``n_pf_slots`` are each
    pool's true peak — 1 and min(F, C-1) for the staircase, but
    derived, not assumed.
    """
    n_chunks: int
    max_prefill: int
    branches: Tuple[Tuple[str, int], ...]
    rows: np.ndarray
    n_dec_slots: int
    n_pf_slots: int

    def __post_init__(self):
        self.rows.setflags(write=False)


def serve_round_events(n_chunks: int, max_prefill: int
                       ) -> List[Tuple[str, int, int, int]]:
    """One serving round's compute events ``(kind, lane, chunk, t)`` in
    timeline order: the decode wave (lane 0) enters at tick 0, prefill
    lane ``j`` at tick ``1 + j``, each advancing one chunk per tick.
    The resulting staircase runs exactly one event per (device, tick)
    with every stage cut crossed on the producing tick."""
    C, F = n_chunks, max_prefill
    if C < 1:
        raise ValueError(f"serving needs n_chunks >= 1, got {C}")
    if F < 0:
        raise ValueError(f"max_prefill must be >= 0, got {F}")
    ev = [(DECODE, 0, q, q) for q in range(C)]
    for j in range(F):
        ev.extend((PREFILL, j, q, 1 + j + q) for q in range(C))
    return sorted(ev, key=lambda e: (e[3], e[2]))


def compile_serve_table(events: List[Tuple[str, int, int, int]],
                        n_chunks: int, max_prefill: int) -> ServeTable:
    """Lower a serving round (:func:`serve_round_events`) to a
    :class:`ServeTable`.

    Walks the events once, allocating hidden-state slots over value
    lifetimes: a lane's hidden is born at chunk q's event and dies at
    chunk q+1's (the last chunk emits the token in-branch; the first
    chunk embeds in-branch) — the same greedy lowest-free-slot
    allocator the training table uses, one pool per opcode because the
    decode wave's and the prefill lanes' hiddens have different shapes.
    """
    C, F = n_chunks, max_prefill
    if len(events) != (1 + F) * C:
        raise ValueError(f"program has {len(events)} events, expected "
                         f"{(1 + F) * C} (= (1+{F})·{C})")
    specs: List[Tuple[str, int]] = []
    spec_ix: Dict[Tuple[str, int], int] = {}
    rows = []
    slot: Dict[Tuple[str, int], int] = {}      # (kind, lane) -> live slot
    free: Dict[str, List[int]] = {DECODE: [], PREFILL: []}
    hwm: Dict[str, int] = {DECODE: 0, PREFILL: 0}

    def alloc(kind: str) -> int:
        if free[kind]:
            return heapq.heappop(free[kind])
        hwm[kind] += 1
        return hwm[kind] - 1

    for kind, j, q, t in events:
        if kind not in (DECODE, PREFILL):
            raise ValueError(f"unknown serve opcode {kind!r}")
        if not (0 <= q < C) or (kind == PREFILL and not 0 <= j < F) \
                or (kind == DECODE and j != 0):
            raise ValueError(f"event ({kind},{j},{q}) out of range for "
                             f"F={F}, C={C}")
        key = (kind, q)
        if key not in spec_ix:
            spec_ix[key] = len(specs)
            specs.append(key)
        if q == 0:
            if (kind, j) in slot:
                raise ValueError(f"{kind}({j},0) emitted twice")
            a = -1
        else:
            if (kind, j) not in slot:
                raise ValueError(
                    f"{kind}({j},{q}) before {kind}({j},{q - 1})")
            a = slot.pop((kind, j))
            heapq.heappush(free[kind], a)
        if q < C - 1:
            b = alloc(kind)
            slot[(kind, j)] = b
        else:
            b = -1
        op = OP_DECODE if kind == DECODE else OP_PREFILL
        rows.append((spec_ix[key], op, q, j, a, b, t))
    if slot:
        raise ValueError(
            f"serving round leaves in-flight values: {sorted(slot)}")
    return ServeTable(
        n_chunks=C, max_prefill=F, branches=tuple(specs),
        rows=np.asarray(rows, np.int32).reshape(-1, SN_COLS),
        n_dec_slots=hwm[DECODE], n_pf_slots=hwm[PREFILL])


# per-device serve stream columns (SDCOL_* indices into
# ServeStreams.rows[t, d]).  Both payload rings (decode [R,1,d] and
# prefill [1,P,d] hiddens) run every tick; a row's RECV column says
# which local slot parks the incoming payload (-1 -> the trash slot).
SDCOL_BRANCH = 0  # lax.switch arm; -1 rewritten to the NOP arm
SDCOL_MB = 1      # prefill lane j; 0 for the decode wave
SDCOL_A = 2       # q>0: read slot of the incoming hidden; -1 at q==0
SDCOL_RECV_D = 3  # local decode-pool slot for this tick's payload
SDCOL_RECV_P = 4  # local prefill-pool slot for this tick's payload
SDN_COLS = 5


@dataclass(frozen=True, eq=False)
class ServeStreams:
    """Per-device tick streams of one serving round.

    ``rows`` is ``[T, S, SDN_COLS]`` int32, ``T = C + F`` staircase
    ticks — slicing column ``d`` with ``PartitionSpec(None, 'pipe')``
    hands each device exactly its own stream, as in the training
    :class:`DeviceStreams`.  Hidden-state slots are register-allocated
    per device and per pool; pool sizes are the max over devices so the
    pools stay SPMD-uniform.  Arm ``len(branches)`` is the NOP.
    """
    n_chunks: int
    max_prefill: int
    n_devices: int
    branches: Tuple[Tuple[str, int], ...]
    rows: np.ndarray
    n_dec_slots: int
    n_pf_slots: int

    def __post_init__(self):
        self.rows.setflags(write=False)


def compile_serve_streams(events: List[Tuple[str, int, int, int]],
                          n_chunks: int, max_prefill: int,
                          n_devices: int) -> ServeStreams:
    """Lower a serving round (:func:`serve_round_events`) to per-device
    tick streams (:class:`ServeStreams`).

    Serving folds one chunk per device: the decode wave's state is the
    per-stage KV pages, which live with their chunk's weights, so
    ``n_chunks == n_devices`` is required (no Megatron chunk folding —
    two chunks of one device would interleave page updates within one
    tick).  A hidden crossing a stage cut is born on the consumer's
    device at the producer's tick and dies when the consumer reads it.
    """
    C, F, S = n_chunks, max_prefill, n_devices
    if C != S:
        raise ValueError(
            f"serving folds one chunk per device: {C} chunks need "
            f"{C} devices, got {S}")
    if len(events) != (1 + F) * C:
        raise ValueError(f"program has {len(events)} events, expected "
                         f"{(1 + F) * C} (= (1+{F})·{C})")
    T = max(t for _k, _j, _q, t in events) + 1
    by_tick: Dict[int, List[Tuple[str, int, int]]] = {}
    seen_dev: set = set()
    for kind, j, q, t in events:
        if kind not in (DECODE, PREFILL) or not 0 <= q < C:
            raise ValueError(f"event ({kind},{j},{q}) out of range for "
                             f"F={F}, C={C}")
        d = q                     # one chunk per device
        if (t, d) in seen_dev:
            raise ValueError(
                f"device {d} has two serve events at tick {t} — the "
                f"round is not one-event-per-(device, tick)")
        seen_dev.add((t, d))
        by_tick.setdefault(t, []).append((kind, j, q))

    specs: List[Tuple[str, int]] = []
    spec_ix: Dict[Tuple[str, int], int] = {}
    rows = np.full((T, S, SDN_COLS), -1, np.int32)
    rows[:, :, SDCOL_MB] = 0

    # per-device register allocators: [device][kind] min-heap + hwm
    free = [{DECODE: [], PREFILL: []} for _ in range(S)]
    hwm = [{DECODE: 0, PREFILL: 0} for _ in range(S)]

    def alloc(d: int, kind: str) -> int:
        if free[d][kind]:
            return heapq.heappop(free[d][kind])
        hwm[d][kind] += 1
        return hwm[d][kind] - 1

    pending: Dict[Tuple[str, int], int] = {}   # in-flight (kind, lane)
    done: set = set()                          # lanes that left the pipe
    for t in range(T):
        evs = sorted(by_tick.get(t, ()), key=lambda e: e[2])
        # phase 1: frees from this tick's reads (before any allocation)
        for kind, j, q in evs:
            if q == 0:
                if (kind, j) in pending or (kind, j) in done:
                    raise ValueError(f"{kind}({j},0) emitted twice")
                continue
            if (kind, j) not in pending:
                raise ValueError(
                    f"{kind}({j},{q}) before {kind}({j},{q - 1})")
            heapq.heappush(free[q][kind], pending[(kind, j)])
        # phase 2: the events' own rows
        for kind, j, q in evs:
            key = (kind, q)
            if key not in spec_ix:
                spec_ix[key] = len(specs)
                specs.append(key)
            row = rows[t, q]
            row[SDCOL_BRANCH] = spec_ix[key]
            row[SDCOL_MB] = j
            if q > 0:
                row[SDCOL_A] = pending.pop((kind, j))
            if q == C - 1:
                done.add((kind, j))
        # phase 3: payload receives on the next device (land after the
        # neighbor's branch ran, so freed slots are reusable)
        for kind, j, q in evs:
            if q == C - 1:
                continue
            nd = q + 1
            s = alloc(nd, kind)
            pending[(kind, j)] = s
            rows[t, nd, SDCOL_RECV_D if kind == DECODE
                 else SDCOL_RECV_P] = s

    if pending:
        raise ValueError(
            f"serving round leaves in-flight values: {sorted(pending)}")
    # un-filled branch column -> the NOP arm (a valid switch index)
    br = rows[:, :, SDCOL_BRANCH]
    br[br < 0] = len(specs)
    return ServeStreams(
        n_chunks=C, max_prefill=F, n_devices=S, branches=tuple(specs),
        rows=rows,
        n_dec_slots=max(h[DECODE] for h in hwm) if S else 0,
        n_pf_slots=max(h[PREFILL] for h in hwm) if S else 0)
