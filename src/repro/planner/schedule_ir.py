"""Event-timeline IR for pipeline schedules.

A :class:`Schedule` is an ordered list of typed events — ``fwd``/``bwd``
compute events and ``update`` events (each update names the stages whose
weights it touches and the minibatches whose gradients it applies).  Time
is discrete: events carry a tick ``t`` plus a deterministic sub-tick order
(fwd by ascending stage, then bwd by descending stage, then updates), so
every weight read happens before the same tick's weight writes.

Three emitters cover the schedules in this repo:

  * :func:`round_robin_1f1b` — the paper's §3.1 round-robin schedule (one
    global update per time unit, minibatch round trip of N−1 units).
  * :func:`gpipe` — fill/drain with gradient accumulation and a single
    update per round (the sync pipeline, ``core/pipeline_sync.py``).
  * :func:`streaming` — the tick schedule of ``core/pipeline_stream.py``
    (per-stage updates every tick, zero bubble after warm-up).

The point of the IR is that weight-version differences are **derived**,
not assumed: :meth:`Schedule.staleness` counts the update events landing
on a stage's weights between a minibatch's weight-read event and that
minibatch's own gradient-apply event.  The closed forms in
``core/spectrain.py`` (Eqs. 5–6 and the streaming variant) become checked
properties of the corresponding emitters instead of trusted constants.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

FWD, BWD, UPDATE = "fwd", "bwd", "update"
_KIND_RANK = {FWD: 0, BWD: 1, UPDATE: 2}


@dataclass(frozen=True)
class Event:
    """One schedule event.

    ``stage``/``mb`` identify compute events; update events instead carry
    ``stages`` (weights written) and ``mbs`` (gradients applied) and keep
    ``stage = mb = -1``.
    """
    kind: str
    t: int
    stage: int = -1
    mb: int = -1
    stages: Tuple[int, ...] = ()
    mbs: Tuple[int, ...] = ()

    def sort_key(self):
        rank = _KIND_RANK[self.kind]
        # fwd consumes activations from the previous stage (ascending);
        # bwd consumes cotangents from the next stage (descending).
        sub = self.stage if self.kind == FWD else -self.stage
        return (self.t, rank, sub)


@dataclass
class Schedule:
    name: str
    n_stages: int
    events: List[Event] = field(default_factory=list)

    def __post_init__(self):
        self.events = sorted(self.events, key=Event.sort_key)
        self._index: Dict[Tuple[str, int, int], int] = {}
        self._own_update: Dict[Tuple[int, int], int] = {}
        for i, e in enumerate(self.events):
            if e.kind == UPDATE:
                for k in e.stages:
                    for m in e.mbs:
                        self._own_update[(m, k)] = i
            else:
                self._index[(e.kind, e.mb, e.stage)] = i

    # ------------------------------------------------------------ queries
    def makespan(self) -> int:
        return max(e.t for e in self.events) + 1 if self.events else 0

    def minibatches(self) -> Tuple[int, ...]:
        return tuple(sorted({e.mb for e in self.events if e.kind == FWD}))

    def version_at(self, event_idx: int, stage: int) -> int:
        """#updates touching ``stage``'s weights strictly before an event."""
        return sum(1 for e in self.events[:event_idx]
                   if e.kind == UPDATE and stage in e.stages)

    def complete_minibatches(self) -> Tuple[int, ...]:
        """Minibatches with fwd+bwd on every stage and an applied update."""
        out = []
        for m in self.minibatches():
            ok = all((FWD, m, k) in self._index and (BWD, m, k) in self._index
                     for k in range(self.n_stages))
            ok = ok and all((m, k) in self._own_update
                            for k in range(self.n_stages))
            if ok:
                out.append(m)
        return tuple(out)

    def steady_minibatch(self) -> int:
        """A minibatch past warm-up (reads never truncated to version 0).

        The closed forms of ``core/spectrain.py`` describe steady state;
        early minibatches read the initial weights more often than the
        formulas say.  Any complete minibatch injected after the pipeline
        has filled (index ≥ 2·N) is in steady state for every schedule
        emitted here.
        """
        complete = self.complete_minibatches()
        if not complete:
            raise ValueError(f"{self.name}: no complete minibatch in IR")
        steady = [m for m in complete if m >= 2 * self.n_stages]
        if not steady:
            raise ValueError(
                f"{self.name}: timeline too short for steady state "
                f"(complete={complete[:4]}...); emit more minibatches")
        return steady[len(steady) // 2]

    # ---------------------------------------------------------- staleness
    def staleness(self, stage: int, phase: str, mb: Optional[int] = None
                  ) -> int:
        """Derived weight-version difference s for (stage, phase).

        s = #updates landing on ``stage``'s weights between the weight
        read of minibatch ``mb``'s fwd/bwd event and ``mb``'s own
        gradient-apply on that stage — the generic form of the paper's
        Eqs. 5–6.
        """
        if phase not in ("forward", "backward"):
            raise ValueError(phase)
        if not 0 <= stage < self.n_stages:
            raise ValueError(f"stage {stage} out of range for "
                             f"{self.n_stages} stages")
        if mb is None:
            mb = self.steady_minibatch()
        kind = FWD if phase == "forward" else BWD
        read = self._index.get((kind, mb, stage))
        own = self._own_update.get((mb, stage))
        if read is None or own is None:
            raise ValueError(f"minibatch {mb} incomplete on stage {stage}")
        return self.version_at(own, stage) - self.version_at(read, stage)

    def staleness_vector(self, phase: str, mb: Optional[int] = None
                         ) -> Tuple[int, ...]:
        if mb is None:
            mb = self.steady_minibatch()
        return tuple(self.staleness(k, phase, mb)
                     for k in range(self.n_stages))

    def bwd_lag(self, stage: int, mb: Optional[int] = None) -> int:
        """Ticks between a minibatch's injection (stage-0 forward) and its
        stage-k backward — how long gradients for stage k are in flight."""
        if mb is None:
            mb = self.steady_minibatch()
        bwd = self._index.get((BWD, mb, stage))
        fwd0 = self._index.get((FWD, mb, 0))
        if bwd is None or fwd0 is None:
            raise ValueError(f"minibatch {mb} incomplete on stage {stage}")
        return self.events[bwd].t - self.events[fwd0].t

    def fwd_bwd_gap(self, stage: int, mb: Optional[int] = None) -> int:
        """Ticks between a minibatch's stage-k forward and its stage-k
        backward — how long stage k must stash that minibatch's input
        activation (the streaming runtime's ring gather offset)."""
        if mb is None:
            mb = self.steady_minibatch()
        bwd = self._index.get((BWD, mb, stage))
        fwd = self._index.get((FWD, mb, stage))
        if bwd is None or fwd is None:
            raise ValueError(f"minibatch {mb} incomplete on stage {stage}")
        return self.events[bwd].t - self.events[fwd].t

    # ----------------------------------------------------------- validity
    def validate(self) -> None:
        """Dataflow sanity: activations and cotangents exist when read.

        * fwd(m, k) strictly after fwd(m, k−1)
        * bwd(m, N−1) strictly after fwd(m, N−1)
        * bwd(m, k) strictly after bwd(m, k+1)
        * m's update on stage k strictly after bwd(m, k)
        """
        N = self.n_stages
        for m in self.complete_minibatches():
            f = [self._index[(FWD, m, k)] for k in range(N)]
            b = [self._index[(BWD, m, k)] for k in range(N)]
            for k in range(1, N):
                if not f[k - 1] < f[k]:
                    raise ValueError(
                        f"{self.name}: fwd({m},{k}) before fwd({m},{k-1})")
            if not f[N - 1] < b[N - 1]:
                raise ValueError(f"{self.name}: bwd({m}) before fwd({m})")
            for k in range(N - 1):
                if not b[k + 1] < b[k]:
                    raise ValueError(
                        f"{self.name}: bwd({m},{k}) before bwd({m},{k+1})")
            for k in range(N):
                if not b[k] < self._own_update[(m, k)]:
                    raise ValueError(
                        f"{self.name}: update of {m} before bwd({m},{k})")

    # ------------------------------------------------------------- render
    def render(self, max_ticks: int = 24) -> str:
        """ASCII timeline: one row per stage, ``f<mb>``/``b<mb>`` cells."""
        grid: Dict[Tuple[int, int], List[str]] = {}
        for e in self.events:
            if e.kind == UPDATE:
                for k in e.stages:
                    grid.setdefault((k, e.t), []).append("u")
            else:
                grid.setdefault((e.stage, e.t), []).append(
                    f"{e.kind[0]}{e.mb}")
        T = min(self.makespan(), max_ticks)
        width = max([len("+".join(grid.get((k, t), [])))
                     for k in range(self.n_stages) for t in range(T)] + [2])
        rows = []
        for k in range(self.n_stages):
            cells = ["+".join(grid.get((k, t), [])).ljust(width)
                     for t in range(T)]
            rows.append(f"s{k} |" + "|".join(cells) + "|")
        return "\n".join(rows)


# ===========================================================================
# emitters
# ===========================================================================


def _default_mbs(n_stages: int) -> int:
    # enough for fill, a steady-state region past 2N, and drain
    return 6 * n_stages + 4


def round_robin_1f1b(n_stages: int, n_minibatches: Optional[int] = None
                     ) -> Schedule:
    """The paper's round-robin schedule (§3.1, Figs. 4/7).

    Each time unit every GPU runs one forward and one backward slot;
    minibatch i runs fwd on stage k at unit ``i + ⌈k/2⌉`` and bwd at unit
    ``i + N − 1 − ⌊k/2⌋``; its round trip completes in N−1 units and its
    gradient updates all stages at the end of unit ``i + N − 1`` (one
    global weight version per unit).
    """
    N = n_stages
    M = n_minibatches or _default_mbs(N)
    ev: List[Event] = []
    all_stages = tuple(range(N))
    for i in range(M):
        for k in range(N):
            ev.append(Event(FWD, i + (k + 1) // 2, stage=k, mb=i))
            ev.append(Event(BWD, i + N - 1 - k // 2, stage=k, mb=i))
        ev.append(Event(UPDATE, i + N - 1, stages=all_stages, mbs=(i,)))
    return Schedule("1f1b_rr", N, ev)


def gpipe(n_stages: int, n_microbatches: Optional[int] = None,
          n_rounds: int = 3) -> Schedule:
    """GPipe fill/drain: all microbatches forward, then all backward, then
    one accumulated update — staleness-free (s_fwd = s_bwd = 0) at the
    cost of a 2(N−1)-slot bubble per round."""
    N = n_stages
    M = n_microbatches or max(2, 2 * N)
    ev: List[Event] = []
    all_stages = tuple(range(N))
    span = 2 * (M + N - 1) + 1
    for r in range(n_rounds):
        base = r * span
        mbs = tuple(r * M + m for m in range(M))
        for m in range(M):
            for k in range(N):
                ev.append(Event(FWD, base + m + k, stage=k, mb=r * M + m))
                ev.append(Event(
                    BWD, base + (M + N - 1) + (M - 1 - m) + (N - 1 - k),
                    stage=k, mb=r * M + m))
        ev.append(Event(UPDATE, base + span - 1, stages=all_stages, mbs=mbs))
    return Schedule("gpipe", N, ev)


def streaming(n_stages: int, n_ticks: Optional[int] = None) -> Schedule:
    """The streaming tick schedule (``core/pipeline_stream.py``).

    Per tick t, stage k forwards the minibatch injected k ticks ago and
    backwards the one injected 2(N−1)−k ticks ago, then applies that
    minibatch's gradient to **its own** weights — per-stage, per-tick
    updates (minibatch id == injection tick).
    """
    N = n_stages
    T = n_ticks or (_default_mbs(N) + 2 * (N - 1))
    ev: List[Event] = []
    for t in range(T):
        for k in range(N):
            if t - k >= 0:
                ev.append(Event(FWD, t, stage=k, mb=t - k))
            mb_b = t - 2 * (N - 1) + k
            if mb_b >= 0 and mb_b <= t:
                ev.append(Event(BWD, t, stage=k, mb=mb_b))
                ev.append(Event(UPDATE, t, stages=(k,), mbs=(mb_b,)))
    return Schedule("stream", N, ev)


EMITTERS = {
    "1f1b_rr": round_robin_1f1b,
    "gpipe": gpipe,
    "stream": streaming,
}


def emit(name: str, n_stages: int, **kw) -> Schedule:
    if name not in EMITTERS:
        raise KeyError(f"unknown schedule {name!r}; known: {sorted(EMITTERS)}")
    return EMITTERS[name](n_stages, **kw)
