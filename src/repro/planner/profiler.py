"""Per-layer cost profiles feeding the stage partitioner.

Three acquisition methods, best-effort in this order under ``"auto"``:

  * ``"hlo"``    — lower + compile one transformer block for the config
                   and run the trip-count-aware HLO counters of
                   ``runtime/hlo_cost.py`` over the compiled text (exact
                   FLOPs/bytes for what XLA will actually execute).
  * ``"timed"``  — execute the block and measure wall time (the
                   PipeDream approach: profile, don't model); FLOPs are
                   then back-filled analytically so the partitioner's
                   compute terms stay populated.
  * ``"analytic"`` — closed-form FLOPs from ``ArchConfig.param_count``
                   (2·params·tokens per matmul-dominated layer); always
                   available, used as the fallback of last resort.

All blocks of one config are identical, so one representative block is
profiled and replicated ``n_layers`` times; per-layer overrides (for
heterogeneous stacks, e.g. hybrid SSM+attention) can scale individual
entries via ``scale``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class LayerProfile:
    name: str
    flops: float            # forward FLOPs for one (batch, seq) slab
    param_bytes: float
    act_bytes: float        # output activation bytes (cut cost if split here)
    time_s: float = 0.0     # measured fwd wall time (timed method only)


@dataclass(frozen=True)
class ModelProfile:
    arch: str
    method: str
    batch: int
    seq: int
    layers: Tuple[LayerProfile, ...]

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    def total_flops(self) -> float:
        return sum(lp.flops for lp in self.layers)

    def scaled(self, scale: Sequence[float]) -> "ModelProfile":
        """Per-layer compute multipliers (heterogeneous-stack modelling)."""
        if len(scale) != self.n_layers:
            raise ValueError(f"{len(scale)} scales for {self.n_layers} layers")
        return replace(self, layers=tuple(
            replace(lp, flops=lp.flops * s, time_s=lp.time_s * s)
            for lp, s in zip(self.layers, scale)))


def synthetic_profile(compute: Sequence[float], *, act_bytes: float = 0.0,
                      name: str = "synthetic") -> ModelProfile:
    """Profile from raw per-layer compute costs (tests / benchmarks).

    ``act_bytes`` defaults to 0 so abstract unit-cost profiles don't get
    dominated by the bytes→seconds hardware conversion; pass real byte
    counts to make transfer terms meaningful."""
    return ModelProfile(name, "synthetic", 1, 1, tuple(
        LayerProfile(f"layer{j}", float(c), 0.0, float(act_bytes))
        for j, c in enumerate(compute)))


# ---------------------------------------------------------------------------
# analytic


def _per_layer_params(cfg) -> float:
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    body = max(0, cfg.param_count() - emb)
    return body / max(1, cfg.n_layers)


def _analytic_layer(cfg, batch: int, seq: int) -> LayerProfile:
    p = _per_layer_params(cfg)
    pdt = jnp.dtype(cfg.param_dtype).itemsize
    cdt = jnp.dtype(cfg.compute_dtype).itemsize
    tokens = batch * seq
    # matmul-dominated: 2 FLOPs per param per token, plus O(s²d) attention
    flops = 2.0 * p * tokens
    if cfg.ssm is None:
        flops += 4.0 * batch * seq * seq * cfg.n_heads * cfg.hd
    act = float(batch * seq * cfg.d_model * cdt)
    return LayerProfile("block", flops, p * pdt, act)


# ---------------------------------------------------------------------------
# hlo / timed (profile one representative block)


def _block_fn_and_args(cfg, batch: int, seq: int):
    from repro.models.layers import init_params
    from repro.models.transformer import block_apply, block_specs

    params = init_params(block_specs(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    x = jnp.zeros((batch, seq, cfg.d_model), jnp.dtype(cfg.compute_dtype))

    def f(p, x):
        y, aux, _, _ = block_apply(cfg, p, x)
        return y, aux

    return f, params, x


def _hlo_layer(cfg, batch: int, seq: int) -> LayerProfile:
    from repro.runtime.hlo_cost import analyze

    f, params, x = _block_fn_and_args(cfg, batch, seq)
    compiled = jax.jit(f).lower(params, x).compile()
    hc = analyze(compiled.as_text())
    pdt = jnp.dtype(cfg.param_dtype).itemsize
    cdt = jnp.dtype(cfg.compute_dtype).itemsize
    pbytes = sum(p.size for p in jax.tree.leaves(params)) * pdt
    return LayerProfile("block", float(hc["flops"]), float(pbytes),
                        float(batch * seq * cfg.d_model * cdt))


def _timed_layer(cfg, batch: int, seq: int, *, iters: int = 3
                 ) -> LayerProfile:
    f, params, x = _block_fn_and_args(cfg, batch, seq)
    jf = jax.jit(f)
    jax.block_until_ready(jf(params, x))       # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jf(params, x)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / iters
    base = _analytic_layer(cfg, batch, seq)
    return replace(base, time_s=dt)


METHODS = ("auto", "hlo", "timed", "analytic")


def profile_model(cfg, *, batch: int = 1, seq: int = 32,
                  method: str = "auto") -> ModelProfile:
    """Per-layer profile for an ArchConfig (one entry per layer)."""
    if method not in METHODS:
        raise ValueError(f"unknown profile method {method!r}")
    used = method
    if method in ("auto", "hlo"):
        try:
            layer = _hlo_layer(cfg, batch, seq)
            used = "hlo"
        except Exception:
            if method == "hlo":
                raise
            layer = _analytic_layer(cfg, batch, seq)
            used = "analytic"
    elif method == "timed":
        layer = _timed_layer(cfg, batch, seq)
    else:
        layer = _analytic_layer(cfg, batch, seq)
    layers = tuple(replace(layer, name=f"block{j}")
                   for j in range(cfg.n_layers))
    return ModelProfile(cfg.name, used, batch, seq, layers)
