"""`plan()` — the planner's front door.

Combines a cost profile (:mod:`profiler`), a stage split
(:mod:`partition`) and an emitted schedule timeline (:mod:`schedule_ir`)
into one :class:`PipelinePlan` that the simulator, the streaming pipeline
runtime and the training launcher all consume.  The per-stage weight
prediction distances ``s_fwd``/``s_bwd`` are *derived* from the IR by
counting update events, never assumed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.planner import partition as pt
from repro.planner import profiler as pf
from repro.planner import schedule_ir as ir

SCHEDULES = tuple(ir.EMITTERS)


@dataclass(frozen=True)
class PipelinePlan:
    """Everything the runtimes need to execute one pipeline layout.

    ``s_fwd``/``s_bwd`` are the IR-derived weight-version differences per
    (chunk-)stage (SpecTrain's prediction distances, Eqs. 5–6
    generalized); ``bwd_lag`` is the injection→backward tick distance per
    stage (how long a minibatch's gradient is in flight); ``fb_gap`` is
    the same-stage forward→backward distance (how long each stage stashes
    an input activation — the streaming runtime's ring gather offsets);
    ``partition`` maps layers to (chunk-)stages — an *executable*
    artifact: the runtimes regroup stage weights by its layer ranges
    (``stage_ranges``) and validate them against the model at state
    construction, so non-uniform (DP) partitions are run, not just
    logged; ``stage_costs_s`` is the modelled per-stage time under that
    partition and ``bottleneck_s`` its max (the slowest stage).

    ``virtual_stages`` (interleaved schedules) is the chunk count v per
    device: the plan then describes ``n_chunks = n_stages·v``
    chunk-stages executed on ``n_stages`` devices, device d hosting
    chunks ``d, d+S, …``, and every per-stage vector has length
    ``n_chunks``.  ``round_microbatches`` is the microbatch count per
    flush round (1f1b/gpipe/interleaved) or accumulation group (2bw);
    ``bubble_frac``, ``act_stash``, and ``w_stash_depth`` are derived
    from the IR timeline — the bubble-vs-memory axes the schedule family
    trades on (see docs/SCHEDULES.md).
    """
    n_stages: int
    schedule: str
    s_fwd: Tuple[int, ...]
    s_bwd: Tuple[int, ...]
    bwd_lag: Tuple[int, ...]
    fb_gap: Tuple[int, ...]
    partition: pt.Partition
    partitioner: str = "uniform"
    bottleneck_s: float = 0.0
    uniform_bottleneck_s: float = 0.0
    stage_costs_s: Tuple[float, ...] = ()
    virtual_stages: int = 1
    round_microbatches: int = 0
    bubble_frac: float = 0.0
    act_stash: Tuple[int, ...] = ()
    w_stash_depth: Tuple[int, ...] = ()
    profile: Optional[pf.ModelProfile] = field(default=None, repr=False)
    ir: Optional[ir.Schedule] = field(default=None, repr=False, hash=False,
                                      compare=False)

    @property
    def n_chunks(self) -> int:
        """Logical pipeline depth: chunk-stages a microbatch traverses."""
        return self.n_stages * self.virtual_stages

    @property
    def n_devices(self) -> int:
        """Physical devices (= n_stages; chunks fold onto them)."""
        return self.n_stages

    @property
    def stage_ranges(self) -> Tuple[Tuple[int, int], ...]:
        """Per-(chunk-)stage [lo, hi) layer ranges the runtime executes."""
        return self.partition.stages()

    @property
    def stage_sizes(self) -> Tuple[int, ...]:
        return self.partition.sizes()

    def staleness(self, stage: int, phase: str) -> int:
        vec = self.s_fwd if phase == "forward" else self.s_bwd
        if phase not in ("forward", "backward"):
            raise ValueError(phase)
        if not 0 <= stage < self.n_chunks:
            raise ValueError(f"stage {stage} out of range for "
                             f"{self.n_chunks} stages")
        return vec[stage]

    @property
    def ring_slots(self) -> int:
        """In-flight slots the streaming runtime must hold."""
        return max(max(self.bwd_lag), max(self.fb_gap)) + 1

    # ---------------------------------------------- round-schedule lowering
    def round_ir(self) -> ir.Schedule:
        """The schedule timeline backing this plan — ``self.ir``, or a
        deterministic re-emission when the plan was built with
        ``keep_ir=False`` (same emitter, same kwargs)."""
        if self.ir is not None:
            return self.ir
        kw = {}
        if self.schedule == "interleaved":
            kw["v"] = self.virtual_stages
        if self.round_microbatches:
            kw["n_microbatches"] = self.round_microbatches
        return ir.emit(self.schedule, self.n_stages, **kw)

    def round_program(self):
        """One canonical round of compute events ``(kind, local_mb,
        chunk_stage, s)`` in timeline order (round schedules only).

        Flush schedules lower round 0 — every round is identical; 2BW
        lowers a steady accumulation group (group 0's pinned reads are
        still truncated to the initial weights)."""
        if self.schedule not in ROUND_SCHEDULES:
            raise ValueError(
                f"{self.schedule!r} is not a round schedule; only "
                f"{ROUND_SCHEDULES} lower to a round program")
        base = self.round_microbatches if self.schedule == "2bw" else 0
        return ir.round_compute_program(self.round_ir(), base=base)

    def event_table(self) -> ir.EventTable:
        """Dense int32 lowering of :meth:`round_program` — what the
        ``lax.scan`` interpreter backend executes (O(1) trace size in
        the round's microbatch count)."""
        return ir.compile_event_table(self.round_program(), self.n_chunks,
                                      self.round_microbatches)

    def device_streams(self) -> ir.DeviceStreams:
        """Per-device tick streams of one round — what the shard_map
        MPMD execution path runs: device ``d`` executes chunks
        ``d, d+S, …`` from stage-local weights, activations and
        cotangents cross the stage cuts via ``ppermute``."""
        base = self.round_microbatches if self.schedule == "2bw" else 0
        return ir.compile_device_streams(
            ir.round_compute_events(self.round_ir(), base=base),
            self.n_chunks, self.round_microbatches, self.n_devices)

    def verify(self, *, device_streams: bool = True) -> None:
        """Statically verify this plan's compiled artifacts (slot
        dataflow, ring comm matching, staleness closed forms,
        completeness, resource bounds — see ``planner/verify.py``).
        Round schedules verify the event table and, by default, the
        device streams; non-round schedules re-validate the timeline.
        Raises :class:`~repro.planner.verify.VerificationError`."""
        from repro.planner import verify as pv
        pv.check_plan(self, device_streams=device_streams)

    def summary(self) -> str:
        v = (f" v={self.virtual_stages}" if self.virtual_stages > 1 else "")
        return (f"plan[{self.schedule} x{self.n_stages}{v} "
                f"part={self.partitioner}:{self.partition.sizes()} "
                f"s_fwd={self.s_fwd} s_bwd={self.s_bwd} "
                f"bottleneck={self.bottleneck_s:.2e}s]")


def plan(config=None, n_stages: int = 2, *, schedule: str = "1f1b_rr",
         partitioner: str = "dp", profile: Optional[pf.ModelProfile] = None,
         profile_method: str = "analytic", batch: int = 1, seq: int = 32,
         n_layers: Optional[int] = None, virtual_stages: int = 1,
         n_microbatches: Optional[int] = None,
         keep_ir: bool = True, validate: bool = True) -> PipelinePlan:
    """Build a :class:`PipelinePlan`.

    ``config`` is an ``ArchConfig`` (profiled via ``profile_method`` at
    the run's ``batch``/``seq`` shape), or None with an explicit
    ``profile`` or bare ``n_layers`` (uniform unit costs).
    ``schedule`` ∈ {"1f1b_rr", "gpipe", "stream", "1f1b", "2bw",
    "interleaved"}.  ``virtual_stages`` (interleaved only) is the chunk
    count v per device; the partition then splits layers into
    ``n_stages·v`` chunk-stages.  ``n_microbatches`` overrides the
    schedule's default round/group size (must divide the run's batch for
    the IR-interpreter runtime).
    """
    if schedule not in ir.EMITTERS:
        raise KeyError(
            f"unknown schedule {schedule!r}; known: {sorted(ir.EMITTERS)}")
    if virtual_stages < 1:
        raise ValueError(f"virtual_stages must be >= 1, got {virtual_stages}")
    if virtual_stages > 1 and schedule != "interleaved":
        raise ValueError(
            f"virtual_stages={virtual_stages} requires "
            f"schedule='interleaved', got {schedule!r}")
    n_chunks = n_stages * virtual_stages
    if profile is None:
        if config is not None:
            profile = pf.profile_model(config, method=profile_method,
                                       batch=batch, seq=seq)
        else:
            L = n_layers if n_layers is not None else n_chunks
            profile = pf.synthetic_profile([1.0] * L)
    if profile.n_layers < n_chunks:
        raise ValueError(f"{profile.n_layers} layers cannot fill "
                         f"{n_chunks} (chunk-)stages")

    part = pt.partition_profile(profile, n_chunks, method=partitioner)
    costs = pt.profile_stage_costs(profile, part)
    cost = max(costs)
    ucost = pt.profile_bottleneck(
        profile, pt.uniform(profile.n_layers, n_chunks))

    kw = {}
    if schedule == "interleaved":
        kw["v"] = virtual_stages
    if n_microbatches is not None and schedule in ROUND_SCHEDULES:
        kw["n_microbatches"] = n_microbatches
    sched = ir.emit(schedule, n_stages, **kw)
    if validate:
        sched.validate()
    mb = sched.steady_minibatch()
    s_fwd = sched.staleness_vector("forward", mb)
    s_bwd = sched.staleness_vector("backward", mb)
    bwd_lag = tuple(sched.bwd_lag(k, mb) for k in range(n_chunks))
    fb_gap = tuple(sched.fwd_bwd_gap(k, mb) for k in range(n_chunks))

    return PipelinePlan(
        n_stages=n_stages, schedule=schedule, s_fwd=s_fwd, s_bwd=s_bwd,
        bwd_lag=bwd_lag, fb_gap=fb_gap,
        partition=part, partitioner=partitioner,
        bottleneck_s=cost, uniform_bottleneck_s=ucost,
        stage_costs_s=costs, virtual_stages=virtual_stages,
        round_microbatches=sched.round_microbatches,
        bubble_frac=sched.bubble_fraction(),
        act_stash=tuple(sched.peak_activation_stash(k)
                        for k in range(n_chunks)),
        w_stash_depth=tuple(sched.weight_stash_depth(k)
                            for k in range(n_chunks)),
        profile=profile, ir=sched if keep_ir else None)


# re-exported from the IR: the round/group schedule families the
# pipeline_stream IR interpreter executes
ROUND_SCHEDULES = ir.ROUND_SCHEDULES


@dataclass(frozen=True)
class ServePlan:
    """Everything the serving engine needs to execute one continuous-
    batching layout: the stage partition plus the round geometry — how
    many live decode slots (``n_slots``), how many prompts may be
    admitted per round (``max_prefill``), the padded per-lane prompt
    budget (``prompt_budget``) and the per-stage KV paging
    (``n_pages`` pages of ``page_seq`` positions each; one page per
    request per stage, so a request's total length is capped at
    ``page_seq``).

    Serving is forward-only and folds one chunk per device (the decode
    state *is* the KV pages, which live with their chunk's weights), so
    ``n_chunks == n_devices == n_stages``.
    """
    n_stages: int
    partition: pt.Partition
    n_slots: int
    max_prefill: int
    prompt_budget: int
    n_pages: int
    page_seq: int
    schedule: str = "serve"
    partitioner: str = "uniform"

    @property
    def n_chunks(self) -> int:
        return self.n_stages

    @property
    def n_devices(self) -> int:
        return self.n_stages

    @property
    def stage_ranges(self) -> Tuple[Tuple[int, int], ...]:
        return self.partition.stages()

    @property
    def stage_sizes(self) -> Tuple[int, ...]:
        return self.partition.sizes()

    def serve_events(self):
        """The round's staircase events ``(kind, lane, chunk, t)``."""
        return ir.serve_round_events(self.n_chunks, self.max_prefill)

    def serve_table(self) -> ir.ServeTable:
        """Dense int32 lowering of one serving round — what the
        ``lax.scan`` serving backend executes."""
        return ir.compile_serve_table(self.serve_events(), self.n_chunks,
                                      self.max_prefill)

    def serve_streams(self) -> ir.ServeStreams:
        """Per-device tick streams of one serving round — what the
        shard_map MPMD serving backend runs."""
        return ir.compile_serve_streams(
            self.serve_events(), self.n_chunks, self.max_prefill,
            self.n_devices)

    def verify(self, *, device_streams: bool = True) -> None:
        """Statically verify the serving round's compiled artifacts
        (KV/hidden slot dataflow, one decode wave per round, staircase
        encoding, cut-transfer matching — see ``planner/verify.py``).
        Raises :class:`~repro.planner.verify.VerificationError`."""
        from repro.planner import verify as pv
        pv.check_serve_plan(self, device_streams=device_streams)

    def summary(self) -> str:
        return (f"serve_plan[x{self.n_stages} "
                f"part={self.partitioner}:{self.partition.sizes()} "
                f"slots={self.n_slots} prefill={self.max_prefill} "
                f"P={self.prompt_budget} pages={self.n_pages}"
                f"x{self.page_seq}]")


def serve_plan(config=None, n_stages: int = 2, *, n_slots: int = 4,
               max_prefill: int = 1, prompt_budget: int = 16,
               n_pages: Optional[int] = None, page_seq: int = 64,
               n_layers: Optional[int] = None,
               partitioner: str = "uniform",
               profile: Optional[pf.ModelProfile] = None,
               profile_method: str = "analytic",
               validate: bool = True) -> ServePlan:
    """Build a :class:`ServePlan`.

    ``config`` is an ``ArchConfig`` (profiled like :func:`plan` when
    ``partitioner="dp"``), or None with bare ``n_layers`` (uniform
    split).  ``n_pages`` defaults to ``n_slots`` (every live request
    owns one page per stage); ``page_seq`` caps each request's
    prompt + generation length and must cover ``prompt_budget``.
    """
    if n_slots < 1:
        raise ValueError(f"n_slots must be >= 1, got {n_slots}")
    if max_prefill < 0:
        raise ValueError(f"max_prefill must be >= 0, got {max_prefill}")
    if prompt_budget < 1:
        raise ValueError(f"prompt_budget must be >= 1, got {prompt_budget}")
    if page_seq < prompt_budget:
        raise ValueError(f"page_seq={page_seq} cannot hold a "
                         f"prompt_budget={prompt_budget} prompt")
    if n_pages is None:
        n_pages = n_slots
    if n_pages < n_slots:
        raise ValueError(f"n_pages={n_pages} < n_slots={n_slots}: a live "
                         f"request needs a page on every stage")
    if profile is None:
        if config is not None:
            profile = pf.profile_model(config, method=profile_method,
                                       batch=n_slots, seq=page_seq)
        else:
            L = n_layers if n_layers is not None else n_stages
            profile = pf.synthetic_profile([1.0] * L)
    if profile.n_layers < n_stages:
        raise ValueError(f"{profile.n_layers} layers cannot fill "
                         f"{n_stages} stages")
    part = pt.partition_profile(profile, n_stages, method=partitioner)
    splan = ServePlan(
        n_stages=n_stages, partition=part, n_slots=n_slots,
        max_prefill=max_prefill, prompt_budget=prompt_budget,
        n_pages=n_pages, page_seq=page_seq, partitioner=partitioner)
    if validate:
        splan.verify()
    return splan


def check_against_closed_forms(p: PipelinePlan) -> None:
    """Assert IR-derived staleness equals ``core/spectrain.py``'s closed
    forms — the property this subsystem exists to make checkable."""
    from repro.core import spectrain as st
    closed = {"1f1b_rr": st.version_difference_paper,
              "stream": st.version_difference_stream,
              "1f1b": st.version_difference_1f1b,
              "interleaved": st.version_difference_1f1b,
              "2bw": st.version_difference_2bw}
    if p.schedule == "gpipe":
        if any(p.s_fwd) or any(p.s_bwd):
            raise AssertionError(f"gpipe must be staleness-free, got {p}")
        return
    fn = closed[p.schedule]
    for k in range(p.n_chunks):
        for phase, vec in (("forward", p.s_fwd), ("backward", p.s_bwd)):
            want = fn(k, p.n_chunks, phase)
            if vec[k] != want:
                raise AssertionError(
                    f"{p.schedule} stage {k} {phase}: IR-derived {vec[k]} "
                    f"!= closed form {want}")
