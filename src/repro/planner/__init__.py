"""Profile-guided pipeline planner.

The repo's pipeline runtimes used to hardcode two planning decisions:

  * **where to cut the model** — every runtime assumed the uniform
    layers-divided-by-stages split, and
  * **how stale each stage's weights are** — the SpecTrain prediction
    distances s_fwd/s_bwd were trusted closed forms (Eqs. 5–6 in
    ``core/spectrain.py``) valid for exactly one schedule.

This subsystem makes both explicit and checkable:

  ``profiler``      per-layer compute / activation / parameter cost
                    profiles — compiled-HLO counters
                    (``runtime/hlo_cost.py``) with timed-execution and
                    analytic fallbacks.
  ``partition``     PipeDream-style dynamic program splitting the layer
                    list into N stages minimizing the bottleneck of
                    per-stage compute + activation-transfer cost, plus
                    the ``uniform`` baseline.
  ``schedule_ir``   an event-timeline IR (typed fwd / bwd / update
                    events) emitting the paper's round-robin 1F1B
                    schedule, GPipe fill-drain, the streaming tick
                    schedule, PipeDream-flush 1F1B, PipeDream-2BW, and
                    Megatron-style interleaved 1F1B (virtual stages);
                    weight-version differences are *derived* by counting
                    update events between a weight read and the
                    minibatch's own gradient apply, and the bubble
                    fraction / activation-stash / weight-stash-depth
                    axes every family trades on are derived from the
                    same timeline.
  ``verify``        static analyzer over the compiled artifacts — slot
                    dataflow/WAR/WAW safety, ppermute ring matching,
                    closed-form staleness, first-contribution
                    uniqueness, completeness, and exact resource
                    bounds; run by default at runtime construction
                    (``PipelinePlan.verify()``), as a CLI
                    (``python -m repro.planner.verify``), and proven
                    to have power by a mutation harness.
  ``api``           ``plan(config, n_stages) -> PipelinePlan``, consumed
                    by ``core/simulator.py`` (arbitrary-schedule
                    staleness), ``core/pipeline_stream.py`` (prediction
                    distances + ring offsets, and the partition itself —
                    the runtime regroups stage weights into ragged
                    per-stage trees by the plan's layer ranges, so DP
                    splits execute) and ``launch/train.py``.

Quick start::

    from repro.planner import plan
    p = plan(cfg, n_stages=4, schedule="stream", partitioner="dp")
    print(p.summary())          # partition, s_fwd/s_bwd, bottleneck
"""
from repro.planner.api import (PipelinePlan, ROUND_SCHEDULES, SCHEDULES,
                               ServePlan, check_against_closed_forms, plan,
                               serve_plan)
from repro.planner.partition import (Partition, dp_split,
                                     profile_stage_costs, uniform)
from repro.planner.profiler import (LayerProfile, ModelProfile,
                                    profile_model, synthetic_profile)
from repro.planner.schedule_ir import (DeviceStreams, Event, EventTable,
                                       Schedule, ServeStreams, ServeTable,
                                       compile_device_streams,
                                       compile_event_table,
                                       compile_serve_streams,
                                       compile_serve_table, emit, gpipe,
                                       interleaved_1f1b, one_f_one_b,
                                       pipedream_2bw, round_compute_events,
                                       round_compute_program,
                                       round_robin_1f1b, serve_round_events,
                                       streaming)
from repro.planner.verify import (VerificationError, VerifyReport,
                                  Violation, check_plan, check_serve_plan,
                                  verify_device_streams,
                                  verify_event_table, verify_plan,
                                  verify_request_trace,
                                  verify_serve_streams, verify_serve_table)

__all__ = [
    "PipelinePlan", "SCHEDULES", "ROUND_SCHEDULES", "plan",
    "ServePlan", "serve_plan",
    "check_against_closed_forms",
    "Partition", "dp_split", "profile_stage_costs", "uniform",
    "LayerProfile", "ModelProfile", "profile_model", "synthetic_profile",
    "Event", "Schedule", "emit", "gpipe", "round_robin_1f1b", "streaming",
    "one_f_one_b", "pipedream_2bw", "interleaved_1f1b",
    "EventTable", "compile_event_table", "round_compute_program",
    "DeviceStreams", "compile_device_streams", "round_compute_events",
    "ServeTable", "ServeStreams", "serve_round_events",
    "compile_serve_table", "compile_serve_streams",
    "VerificationError", "VerifyReport", "Violation", "check_plan",
    "verify_event_table", "verify_device_streams", "verify_plan",
    "check_serve_plan", "verify_serve_table", "verify_serve_streams",
    "verify_request_trace",
]
