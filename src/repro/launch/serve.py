"""Batched serving driver: prefill a batch of prompts, then decode.

CPU-scale by default (reduced config); the decode step is the same
``serve_step`` the dry-run lowers for the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import Model
from repro.obs import MetricsRegistry


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true")
    ap.add_argument("--metrics-out", default="", dest="metrics_out",
                    help="append request/latency telemetry JSONL to this "
                         "path (per-token decode latency histogram)")
    args = ap.parse_args(argv)

    registry = MetricsRegistry(jsonl_path=args.metrics_out or None)
    try:
        if args.metrics_out:
            from repro.kernels import ops as kernel_ops
            kernel_ops.set_timing_hook(registry.kernel_hook())
        cfg = smoke_config(get_config(args.arch))
        model = Model(cfg)
        key = jax.random.PRNGKey(args.seed)
        params = model.init(key)
        B = args.batch
        max_seq = args.prompt_len + args.gen

        prompt = jax.random.randint(key, (B, args.prompt_len), 0,
                                    cfg.vocab_size)
        decode = jax.jit(model.decode_step, donate_argnums=1)

        # warm up on a throwaway cache (decode donates its cache
        # argument) so the reported prefill/decode rates measure
        # steady-state steps, not XLA compilation
        t0 = time.time()
        warm = model.init_cache(B, max_seq)
        logits, warm = decode(params, warm, prompt[:, :1],
                              jnp.asarray(0, jnp.int32))
        jax.block_until_ready(logits)
        del warm
        compile_s = time.time() - t0

        # prefill by stepping the decoder over the prompt (works
        # uniformly for attention, SSM and hybrid caches)
        cache = model.init_cache(B, max_seq)
        t0 = time.time()
        for p in range(args.prompt_len):
            logits, cache = decode(params, cache, prompt[:, p:p + 1],
                                   jnp.asarray(p, jnp.int32))
        jax.block_until_ready(logits)
        prefill_s = time.time() - t0

        tok_hist = registry.histogram("serve/decode_token_ms")
        out = []
        t0 = time.time()
        last = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
        for g in range(args.gen):
            out.append(np.asarray(last))
            tt = time.time()
            logits, cache = decode(
                params, cache, last.astype(jnp.int32),
                jnp.asarray(args.prompt_len + g, jnp.int32))
            last = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
            jax.block_until_ready(last)
            tok_hist.observe((time.time() - tt) * 1e3)
        decode_s = time.time() - t0

        toks = np.concatenate(out, axis=1)
        registry.gauge("serve/compile_s").set(compile_s)
        registry.gauge("serve/prefill_tok_per_s").set(
            args.prompt_len * B / prefill_s)
        registry.gauge("serve/decode_tok_per_s").set(args.gen * B / decode_s)
        registry.emit("serve_request", arch=cfg.name, batch=B,
                      prompt_len=args.prompt_len, gen=args.gen,
                      compile_s=compile_s, prefill_s=prefill_s,
                      decode_s=decode_s,
                      decode_token_ms=tok_hist.snapshot())
        print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
              f"gen={args.gen}")
        print(f"compile: {compile_s:.2f}s   "
              f"prefill: {args.prompt_len * B / prefill_s:.1f} tok/s   "
              f"decode: {args.gen * B / decode_s:.1f} tok/s")
        print("sample:", toks[0, :16].tolist())
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        return 0
    finally:
        registry.close()


if __name__ == "__main__":
    raise SystemExit(main())
