"""Continuous-batching serving driver on the pipelined runtime.

A seeded Poisson arrival trace (``serve/trace.py``) is driven through
the ``repro.api.Runtime`` facade: the default pipelined engine
compiles each serving round to the planner's schedule IR and executes
it under ``--execution spmd`` (the ``lax.scan`` interpreter) or
``--execution mpmd`` (stage-local shard_map over the pipe mesh axis) —
the emitted tokens are bitwise-identical across the two.  ``--engine
simple`` (auto-selected for hybrid / enc-dec archs, whose decode state
the stage split cannot page) serves each request independently through
the whole-model ``decode_step``; its prefill consumes the whole prompt
in one jitted call, not one dispatch per token.

Reported rates exclude XLA compilation (both engines warm up on
throwaway caches first); ``--metrics-out`` appends the scheduler's
admit/decode/evict event log, per-token latency histograms and the
summary record as JSONL.

Example (two stages, 32 requests):
    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b \
        --pipe 2 --layers 4 --requests 32 --rate 1.5
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.api import add_runtime_args, runtime_config_from_args, Runtime
from repro.configs import get_config, smoke_config
from repro.models import Model
from repro.obs import MetricsRegistry
from repro.planner import serve_plan
from repro.serve import SimpleEngine, poisson_trace


def _pair(s: str):
    lo, hi = (int(x) for x in s.split(","))
    return lo, hi


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--pipe", type=int, default=2,
                    help="pipeline stages the serving rounds fold over")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "pipelined", "simple"),
                    help="'pipelined' runs rounds through the schedule "
                         "IR; 'simple' serves each request through the "
                         "whole-model decode_step; 'auto' picks "
                         "pipelined except for hybrid/enc-dec archs")
    ap.add_argument("--requests", type=int, default=8,
                    help="trace length (seeded Poisson arrivals)")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="mean arrivals per round")
    ap.add_argument("--prompt-lens", type=_pair, default=(2, 12),
                    dest="prompt_lens", metavar="LO,HI",
                    help="inclusive prompt-length range")
    ap.add_argument("--gen-lens", type=_pair, default=(1, 8),
                    dest="gen_lens", metavar="LO,HI",
                    help="inclusive generation-length range")
    ap.add_argument("--slots", type=int, default=4,
                    help="live-request slots (decode wave width)")
    ap.add_argument("--max-prefill", type=int, default=2,
                    dest="max_prefill",
                    help="prompts admitted per round (prefill lanes)")
    ap.add_argument("--prompt-budget", type=int, default=16,
                    dest="prompt_budget",
                    help="padded per-lane prompt buffer")
    ap.add_argument("--page-seq", type=int, default=64, dest="page_seq",
                    help="KV positions per page (caps prompt + gen)")
    ap.add_argument("--pages", type=int, default=0,
                    help="KV pages per stage (default: --slots)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-rounds", type=int, default=0,
                    dest="max_rounds",
                    help="abort if the trace does not drain in this "
                         "many rounds (0: auto bound)")
    ap.add_argument("--metrics-out", default="", dest="metrics_out",
                    help="append scheduler events, latency histograms "
                         "and the summary record as JSONL to this path")
    add_runtime_args(ap, serving=True)
    args = ap.parse_args(argv)
    try:
        rc = runtime_config_from_args(args)
    except ValueError as e:
        raise SystemExit(str(e))

    registry = MetricsRegistry(jsonl_path=args.metrics_out or None)
    try:
        if args.metrics_out:
            from repro.kernels import ops as kernel_ops
            kernel_ops.set_timing_hook(registry.kernel_hook())
        cfg = smoke_config(get_config(args.arch))
        kw = {}
        if args.layers:
            kw["n_layers"] = args.layers
        import dataclasses
        kw["mesh_plan"] = dataclasses.replace(cfg.mesh_plan,
                                              pipe=args.pipe, tensor=1)
        cfg = cfg.replace(**kw)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(args.seed))

        engine_kind = args.engine
        if engine_kind == "auto":
            engine_kind = ("simple" if cfg.is_encdec or model.hybrid
                           else "pipelined")
        if engine_kind == "pipelined" and (cfg.is_encdec or model.hybrid):
            raise SystemExit(
                f"--engine pipelined cannot serve {cfg.name}: hybrid/"
                f"enc-dec decode state is not per-layer pageable; use "
                f"--engine simple (or auto)")

        splan = serve_plan(
            cfg, n_stages=args.pipe, n_slots=args.slots,
            max_prefill=args.max_prefill,
            prompt_budget=args.prompt_budget,
            n_pages=args.pages or None, page_seq=args.page_seq,
            n_layers=cfg.n_layers, validate=engine_kind == "pipelined")
        trace = poisson_trace(
            args.requests, rate=args.rate, seed=args.seed,
            prompt_lens=args.prompt_lens, gen_lens=args.gen_lens,
            vocab=cfg.vocab_size)
        print(f"# {splan.summary()}")
        print(f"# arch={cfg.name} engine={engine_kind} "
              f"execution={rc.execution} requests={len(trace)} "
              f"rate={args.rate} seed={args.seed}")

        if engine_kind == "pipelined":
            rt = Runtime(splan, model, rc, registry=registry)
            engine = rt.serve_engine(params)
        else:
            engine = SimpleEngine(model, params, splan,
                                  registry=registry)
        t0 = time.time()
        results = engine.run(trace,
                             max_rounds=args.max_rounds or None)
        wall_s = time.time() - t0

        served = {r: t for r, t in results.items() if t}
        rejected = sorted(r for r, t in results.items() if not t)
        n_tokens = sum(len(t) for t in served.values())
        hist = registry.histogram("serve/token_ms")
        p50 = hist.percentile(50.0)
        p99 = hist.percentile(99.0)
        compile_s = registry.gauge("serve/compile_s").value or 0.0
        tok_per_s = n_tokens / max(wall_s, 1e-9)
        registry.gauge("serve/wall_s").set(wall_s)
        registry.gauge("serve/tok_per_s").set(tok_per_s)
        registry.emit(
            "serve_run", arch=cfg.name, engine=engine_kind,
            execution=rc.execution, n_requests=len(trace),
            n_served=len(served), n_rejected=len(rejected),
            n_tokens=n_tokens, rate=args.rate, seed=args.seed,
            wall_s=wall_s, compile_s=compile_s,
            tok_per_s=tok_per_s, token_ms_p50=p50, token_ms_p99=p99)
        print(f"compile: {compile_s:.2f}s   "
              f"decode: {tok_per_s:.1f} tok/s   "
              f"p50: {p50:.2f} ms/tok   p99: {p99:.2f} ms/tok")
        print(f"served {len(served)}/{len(trace)} requests "
              f"({len(rejected)} rejected), {n_tokens} tokens "
              f"in {wall_s:.2f}s")
        first = min(served) if served else None
        if first is not None:
            print(f"sample (rid {first}):",
                  list(served[first])[:16])
        assert all(np.isfinite(v) for v in (tok_per_s, p50, p99))
        return 0
    finally:
        registry.close()


if __name__ == "__main__":
    raise SystemExit(main())
