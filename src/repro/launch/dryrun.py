import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must run before ANY jax import — jax locks device count on first init.

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (SHAPES, get_config, list_archs,  # noqa: E402
                           shape_applicable, smoke_config)
from repro.configs.base import MeshPlan  # noqa: E402
from repro.core import pipeline_stream, pipeline_sync  # noqa: E402
from repro.launch.mesh import (make_production_mesh,  # noqa: E402
                               make_smoke_mesh)
from repro.models import Model, input_specs  # noqa: E402
from repro.models.layers import use_rules  # noqa: E402
from repro.models.model import cache_axes  # noqa: E402
from repro.runtime import sharding as sh  # noqa: E402
from repro.runtime.hlo_cost import analyze as hlo_analyze  # noqa: E402
from repro.runtime.mesh_utils import axis_sizes, refine_mesh  # noqa: E402

# TPU v5e-class hardware constants (per chip)
HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
                "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> Dict[str, Any]:
    """Per-device collective inventory from compiled HLO text.

    Returns counts, result bytes, and ring-model wire-bytes per op kind.
    """
    out: Dict[str, Any] = {}
    wire_total = 0.0
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        res_txt, op = m.groups()
        op = op.replace("-start", "")
        rbytes = _shape_bytes(res_txt)
        # group size n
        n = None
        g = _GROUPS_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            g2 = _GROUPS_IOTA_RE.search(line)
            if g2:
                n = int(g2.group(2))
        n = n or 2
        frac = (n - 1) / n
        if op == "all-gather":
            wire = rbytes * frac
        elif op == "all-reduce":
            wire = 2.0 * rbytes * frac
        elif op == "reduce-scatter":
            wire = rbytes * (n - 1)
        elif op == "all-to-all":
            wire = rbytes * frac
        else:  # collective-permute
            wire = rbytes
        d = out.setdefault(op, {"count": 0, "result_bytes": 0.0,
                                "wire_bytes": 0.0})
        d["count"] += 1
        d["result_bytes"] += rbytes
        d["wire_bytes"] += wire
        wire_total += wire
    out["total_wire_bytes"] = wire_total
    return out


def _cost(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


def _mem(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    return {"argument_bytes": float(ma.argument_size_in_bytes),
            "output_bytes": float(ma.output_size_in_bytes),
            "temp_bytes": float(ma.temp_size_in_bytes),
            "alias_bytes": float(ma.alias_size_in_bytes)}


def model_flops(cfg, shape) -> float:
    """Useful FLOPs per step: 6·N_active·tokens (train), 2·N_active·tokens
    (prefill/decode)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: 1 token


def min_bytes(cfg, shape, cache_bytes: float = 0.0) -> float:
    """Unavoidable HBM traffic per step (global): weights read once per
    token-batch pass (+3x for train: grad write + momentum/update), and
    for decode the KV-cache/state read."""
    wbytes = cfg.active_param_count() * 2.0          # bf16 weights
    if shape.kind == "train":
        return 4.0 * cfg.param_count() * 2.0         # w, g, v, w'
    if shape.kind == "prefill":
        return wbytes
    return wbytes + cache_bytes                       # decode


def ideal_time(cfg, shape, n_chips: int, cache_bytes: float = 0.0) -> float:
    """Roofline-ideal step time: max of the compute floor and the
    unavoidable-memory floor (the right floor for decode)."""
    tc = model_flops(cfg, shape) / (n_chips * HW["peak_flops"])
    tm = min_bytes(cfg, shape, cache_bytes) / (n_chips * HW["hbm_bw"])
    return max(tc, tm)


def build_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               runtime: str = "stream", mode: str = "spectrain",
               smoke: bool = False, rules_override=None,
               plan_override: Optional[MeshPlan] = None,
               fused_predict: bool = False, bwd_bf16: bool = False,
               ticks: Optional[int] = None,
               serve_bf16: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi_pod else "16x16",
                           "runtime": runtime, "mode": mode}
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skip", skip_reason=reason)
        return rec

    if smoke:
        cfg = smoke_config(cfg).replace(
            n_layers=4, mesh_plan=MeshPlan(pipe=2, tensor=2,
                                           num_microbatches=2))
        shape = type(shape)(shape.name, 64, 8, shape.kind)
        phys = make_smoke_mesh(data=2, model=4)
    else:
        phys = make_production_mesh(multi_pod=multi_pod)
    if plan_override is not None:
        cfg = cfg.replace(mesh_plan=plan_override)
    plan = cfg.mesh_plan
    n_ticks = ticks or plan.num_microbatches
    rec["opts"] = {"fused_predict": fused_predict, "bwd_bf16": bwd_bf16,
                   "ticks": n_ticks, "serve_bf16": serve_bf16}
    mesh = refine_mesh(phys, plan.pipe, plan.tensor)
    sizes = axis_sizes(mesh)
    n_chips = int(np.prod(list(sizes.values())))
    rec["chips"] = n_chips
    rec["logical_mesh"] = dict(sizes)

    model = Model(cfg)
    if shape.kind == "decode":
        rules = sh.decode_rules(cfg, mesh, global_batch=shape.global_batch)
    else:
        rules = sh.logical_rules(cfg, mesh)
    if rules_override:
        rules.update(rules_override)

    ins = input_specs(cfg, shape)
    param_sds = model.param_sds()
    param_sh = sh.shardings_for(model.param_axes(), param_sds, mesh, rules)

    t0 = time.time()
    with mesh, use_rules(rules, sizes):
        if shape.kind == "train":
            batch_sds = ins["batch"]
            batch_sh = sh.batch_specs(cfg, batch_sds, mesh, rules)
            if runtime == "stream":
                step = pipeline_stream.make_train_step(
                    model, mode=mode, lr=1e-3,
                    ticks_per_step=n_ticks, fused_predict=fused_predict,
                    bwd_dtype="bfloat16" if bwd_bf16 else None)
                state_sds = jax.eval_shape(
                    lambda: pipeline_stream.make_state(
                        model, jax.tree.map(
                            lambda s: jnp.zeros(s.shape, s.dtype), param_sds),
                        batch_sds, mode=mode,
                        ticks_per_step=n_ticks,
                        fused_predict=fused_predict))
            else:
                step = pipeline_sync.make_train_step(
                    model, lr=1e-3,
                    num_microbatches=plan.num_microbatches)
                state_sds = {"params": param_sds,
                             "momentum": jax.tree.map(
                                 lambda s: jax.ShapeDtypeStruct(
                                     s.shape, jnp.float32), param_sds),
                             "step": jax.ShapeDtypeStruct((), jnp.int32)}
            state_sh = sh.stream_state_shardings(model, state_sds, mesh,
                                                 rules)
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
            ).lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            batch_sds = ins["batch"]
            batch_sh = sh.batch_specs(cfg, batch_sds, mesh, rules)

            def prefill(params, batch):
                logits, _ = model.prefill_logits(params, batch)
                return logits
            lowered = jax.jit(
                prefill, in_shardings=(param_sh, batch_sh),
                out_shardings=None).lower(param_sds, batch_sds)
        else:  # decode
            if serve_bf16:  # deployment format: bf16 serving weights
                param_sds = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
                    if s.dtype == jnp.float32 else s, param_sds)
            cache_sds = ins["cache"]
            cache_sh = sh.shardings_for(cache_axes(model), cache_sds, mesh,
                                        rules)
            tok_sh = sh.batch_specs(cfg, {"t": ins["token"]}, mesh, rules)["t"]
            rep = NamedSharding(mesh, P())

            def serve_step(params, cache, token, pos):
                return model.decode_step(params, cache, token, pos)
            lowered = jax.jit(
                serve_step,
                in_shardings=(param_sh, cache_sh, tok_sh, rep),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),   # in-place cache update
            ).lower(param_sds, cache_sds, ins["token"], ins["pos"])
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

    # trip-count-aware HLO accounting (per-device module); the XLA
    # cost_analysis numbers are recorded too but undercount loop bodies.
    hc = hlo_analyze(compiled.as_text())
    cost = _cost(compiled)
    mem = _mem(compiled)
    rec.update(status="ok", xla_cost=cost, memory=mem,
               collectives=hc["collectives"])
    rec["wire_bytes_per_dev"] = hc["wire_bytes"]
    rec["cost"] = {"flops": hc["flops"], "bytes_raw": hc["bytes"],
                   "bytes": hc["bytes_fused"],
                   "transcendentals": hc["transcendentals"]}

    # ---- roofline terms (global = per-device x chips for flops/bytes) -----
    # memory term uses the fused-bytes estimate: the raw per-op count
    # reflects CPU-grade fusion, not what XLA:TPU emits.
    mf = model_flops(cfg, shape)
    flops_g = hc["flops"] * n_chips
    bytes_g = hc["bytes_fused"] * n_chips
    terms = {
        "compute_s": flops_g / (n_chips * HW["peak_flops"]),
        "memory_s": bytes_g / (n_chips * HW["hbm_bw"]),
        "collective_s": hc["wire_bytes"] / HW["ici_bw"],
    }
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    cache_bytes = 0.0
    if shape.kind == "decode":
        cache_bytes = sum(
            float(np.prod(s.shape)) * s.dtype.itemsize
            for s in jax.tree.leaves(ins["cache"]))
    ideal = ideal_time(cfg, shape, n_chips, cache_bytes)
    rec.update(
        model_flops=mf, hlo_flops_global=flops_g, hlo_bytes_global=bytes_g,
        useful_flops_ratio=(mf / flops_g if flops_g else 0.0),
        terms=terms, dominant=dom, ideal_s=ideal,
        roofline_fraction=(ideal / bound if bound else 0.0),
    )
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--runtime", default="stream",
                    choices=("stream", "sync"))
    ap.add_argument("--mode", default="spectrain",
                    choices=pipeline_stream.MODES)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny mesh (CI)")
    ap.add_argument("--all", action="store_true",
                    help="all (arch x shape) cells")
    ap.add_argument("--out", default=None, help="append JSONL here")
    # perf-iteration knobs (§Perf hillclimbing)
    ap.add_argument("--fused-predict", action="store_true")
    ap.add_argument("--bwd-bf16", action="store_true")
    ap.add_argument("--ticks", type=int, default=0)
    ap.add_argument("--serve-bf16", action="store_true")
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence parallelism: residual stream sharded "
                         "over the tensor axis (AR -> RS+AG)")
    ap.add_argument("--ssm-chunk", type=int, default=0)
    ap.add_argument("--no-ring-tp", action="store_true",
                    help="replicate the in-flight ring buffers over the "
                         "tensor axis (trade memory for fewer gathers)")
    args = ap.parse_args(argv)
    if args.no_ring_tp:
        from repro.runtime import sharding as _sh
        _sh._RING_TP = False
    if args.ssm_chunk:
        from repro.models import ssm as _ssm
        _ssm.USE_CHUNKED = True
        _ssm.CHUNK = args.ssm_chunk

    cells = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ([False, True] if (args.both_meshes or args.all)
              else [args.multipod])
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = build_cell(arch, shape, multi_pod=mp,
                                     runtime=args.runtime, mode=args.mode,
                                     smoke=args.smoke,
                                     fused_predict=args.fused_predict,
                                     bwd_bf16=args.bwd_bf16,
                                     ticks=args.ticks or None,
                                     serve_bf16=args.serve_bf16,
                                     rules_override=(
                                         {"act_seq": "tensor"}
                                         if args.seq_shard else None))
                    if args.seq_shard:
                        rec.setdefault("opts", {})["seq_shard"] = True
                    if args.ssm_chunk:
                        rec.setdefault("opts", {})["ssm_chunk"] = \
                            args.ssm_chunk
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "fail",
                           "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                cells.append(rec)
                line = {k: v for k, v in rec.items()
                        if k not in ("collectives",)}
                print(json.dumps(line), flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    print(f"# {len(cells)} cells, {failures} failures", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
