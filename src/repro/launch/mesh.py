"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:   (pod=2, data=16, model=16) = 512 chips."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    return jax.make_mesh(shape, axes, devices=devices)


def make_smoke_mesh(*, data: int = 2, model: int = 4):
    """Tiny mesh for CI-scale dry-run tests (subset of forced host devices)."""
    import jax
    n = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:n])
