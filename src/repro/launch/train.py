"""End-to-end training driver.

CPU-scale runs use reduced configs (``--smoke``) or an explicit size
override; the same code path drives the production mesh on real hardware.
Supports all four schemes (sync / vanilla / pipedream / spectrain),
checkpoint/restart (``--resume auto``), gradient compression, fault
injection, and exact-resume determinism.

Example (the 8-deliverable end-to-end run):
    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
        --pipe 2 --layers 4 --steps 100 --lr 2e-2 --mode spectrain

``--schedule {stream,gpipe,1f1b,2bw,interleaved}`` selects the pipeline
schedule (round schedules run through the IR interpreter, one flush
round / 2BW group per step); ``--virtual-stages v`` gives each device v
chunk-stages under ``--schedule interleaved``; ``--ir-backend
{scan,unrolled}`` picks the interpreter's round body (the default scan
backend keeps trace size O(1) in the round's microbatch count);
``--execution {spmd,mpmd}`` picks the execution backend (``mpmd``
keeps each stage's weights resident only on its pipe device — bitwise
the same training, 1/S the per-device weight memory; ``--exec`` is the
deprecated alias).  See docs/SCHEDULES.md.

The execution knobs flow through one ``repro.api.RuntimeConfig``
(built by ``repro.api.runtime_config_from_args``, the wiring shared
with ``launch/serve.py``) and the steps through the ``repro.api.
Runtime`` facade.

``--layers`` need not divide ``--pipe``: stage params are ragged
per-stage trees (e.g. ``--layers 7 --pipe 3`` runs sizes (3,2,2) under
the default partitioner, or whatever split ``--partitioner dp``
computes), and checkpoints written by any partition restore onto any
other via the flat layer order.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.api import (Runtime, add_runtime_args,
                       runtime_config_from_args)
from repro.configs import get_config, smoke_config
from repro.core import pipeline_stream, pipeline_sync
from repro.data import DataConfig, SyntheticLM
from repro.models import Model
from repro.obs import (MetricsRegistry, PipelineTracer,
                       device_stream_tick_groups, drift_report,
                       format_drift, format_step, probe_stage_costs,
                       write_trace)
from repro.planner import check_against_closed_forms, plan as make_plan
from repro.runtime import checkpoint as ckpt


def build(args):
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    kw = {}
    if args.layers:
        kw["n_layers"] = args.layers
    if args.d_model:
        kw["d_model"] = args.d_model
        kw["head_dim"] = max(8, args.d_model // cfg.n_heads)
        kw["d_ff"] = args.d_model * 4
    if args.vocab:
        kw["vocab_size"] = args.vocab
    kw["mesh_plan"] = dataclasses.replace(
        cfg.mesh_plan, pipe=args.pipe, tensor=1,
        num_microbatches=args.ticks)
    kw["param_dtype"] = "float32"
    kw["compute_dtype"] = args.dtype
    cfg = cfg.replace(**kw)
    return cfg


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0, dest="d_model")
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--pipe", type=int, default=2)
    ap.add_argument("--ticks", type=int, default=1)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    add_runtime_args(ap)
    ap.add_argument("--virtual-stages", type=int, default=1,
                    dest="virtual_stages",
                    help="chunks per device for --schedule interleaved "
                         "(v >= 2 shrinks the flush bubble ~v x)")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--resume", default="", choices=("", "auto"))
    ap.add_argument("--compress", default="", choices=("", "topk", "int8"))
    ap.add_argument("--partitioner", default="dp", choices=("dp", "uniform"),
                    help="stage-partition method for the planner")
    ap.add_argument("--profile-method", default="analytic",
                    choices=("auto", "hlo", "timed", "analytic"),
                    dest="profile_method",
                    help="per-layer cost acquisition for the planner")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--json", action="store_true",
                    help="emit one JSON line per logged step")
    ap.add_argument("--trace", default="",
                    help="write a Perfetto/Chrome trace JSON (per-device "
                         "measured + IR-predicted lanes) to this path and "
                         "print the predicted-vs-measured drift report")
    ap.add_argument("--metrics-out", default="", dest="metrics_out",
                    help="append structured JSONL telemetry (step records, "
                         "heartbeat/restate events, summary) to this path")
    args = ap.parse_args(argv)
    try:
        rc = runtime_config_from_args(args,
                                      ticks_per_step=max(args.ticks, 1))
    except ValueError as e:
        raise SystemExit(str(e))

    cfg = build(args)
    model = Model(cfg)
    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch,
                                  seed=args.seed))
    key = jax.random.PRNGKey(args.seed)
    batch0 = data.batch_at(0)
    batch_sds = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch0)

    # profile-guided plan: partition + IR-derived staleness for the
    # schedule this run executes (gpipe for the sync fill/drain pipeline,
    # --schedule otherwise).  The partition is executed: the runtimes
    # regroup stage weights into ragged per-(chunk-)stage trees by its
    # layer ranges, so --partitioner dp changes which layers each stage
    # runs, not just the printed bottleneck.
    if args.mode == "sync" and args.schedule != "stream":
        raise SystemExit(
            f"--mode sync runs the fill/drain pipeline and cannot honor "
            f"--schedule {args.schedule}; drop one of the two flags")
    if args.trace and args.mode == "sync":
        raise SystemExit("--trace instruments the streaming/IR runtimes; "
                         "--mode sync is not traceable")
    if args.trace and args.pipe < 2:
        raise SystemExit("--trace needs a real pipeline (--pipe >= 2)")
    if args.virtual_stages > 1 and args.schedule != "interleaved":
        raise SystemExit(
            f"--virtual-stages {args.virtual_stages} requires "
            f"--schedule interleaved, got --schedule {args.schedule}")
    if rc.execution == "mpmd" and args.mode == "sync":
        raise SystemExit(
            f"--execution mpmd runs IR round schedules "
            f"({'/'.join(pipeline_stream.IR_SCHEDULES)}); got "
            f"--mode sync")
    schedule = "gpipe" if args.mode == "sync" else args.schedule
    plan_kw = {}
    if schedule in pipeline_stream.IR_SCHEDULES and args.mode != "sync":
        # round size: --ticks when given, else the largest batch divisor
        # compatible with the schedule (interleaved groups microbatches
        # by S, 2bw needs m >= S for its two weight buffers); the
        # interpreter splits the global batch into the round's
        # microbatches, so M must divide the batch
        S, v = args.pipe, args.virtual_stages

        def legal(M):
            if args.batch % M:
                return False
            if schedule == "interleaved":
                return M % S == 0
            if schedule == "2bw":
                return M >= S
            return True

        M = args.ticks if args.ticks > 1 else next(
            (c for c in range(min(2 * S * v, args.batch), 0, -1)
             if legal(c)), 0)
        if not M or not legal(M):
            raise SystemExit(
                f"no round size for --schedule {schedule}: need a "
                f"divisor of --batch {args.batch} that is "
                + ('a multiple of' if schedule == 'interleaved'
                   else 'at least') + " "
                f"--pipe {S}" + (f" (got --ticks {M})" if M else ""))
        plan_kw["n_microbatches"] = M
    pplan = make_plan(
        cfg, n_stages=model.n_stages, schedule=schedule,
        virtual_stages=args.virtual_stages,
        partitioner=args.partitioner, profile_method=args.profile_method,
        batch=args.batch, seq=args.seq, **plan_kw)
    check_against_closed_forms(pplan)
    print(f"# {pplan.summary()}")
    stage_desc = " ".join(
        f"s{k}:L[{lo}:{hi})={c:.2e}s"
        for k, ((lo, hi), c) in enumerate(zip(pplan.stage_ranges,
                                              pplan.stage_costs_s)))
    print(f"# realized stages: {stage_desc}  "
          f"bottleneck={pplan.bottleneck_s:.2e}s "
          f"(uniform would be {pplan.uniform_bottleneck_s:.2e}s)")
    if schedule in pipeline_stream.IR_SCHEDULES and args.mode != "sync":
        print(f"# schedule {schedule}: round={pplan.round_microbatches} "
              f"microbatches, bubble={pplan.bubble_frac:.3f}, "
              f"act_stash={pplan.act_stash}, "
              f"w_stash_depth={pplan.w_stash_depth}")

    registry = MetricsRegistry(jsonl_path=args.metrics_out or None)
    if args.metrics_out:
        from repro.kernels import ops as kernel_ops
        kernel_ops.set_timing_hook(registry.kernel_hook())
    tracer = PipelineTracer(pplan) if args.trace else None

    if args.mode == "sync":
        state = pipeline_sync.init_state(model, key)
        step_fn = pipeline_sync.make_train_step(
            model, lr=args.lr, gamma=args.gamma,
            num_microbatches=cfg.mesh_plan.num_microbatches,
            clip=args.clip or None)
        step_fn = jax.jit(step_fn, donate_argnums=0)
        if tracer is not None:
            step_fn = tracer.wrap_step(step_fn)
    else:
        # the Runtime facade owns jit/donation (and the traced-mpmd
        # per-tick exception) for both schedule families
        rt = Runtime(pplan, model, rc, tracer=tracer)
        state = rt.init_state(model.init(key), batch_sds)
        if tracer is not None and rc.execution == "mpmd":
            # the mpmd round runs T device-stream ticks, not one host
            # mark per compute event — map tick marks back onto the
            # per-event timeline
            tracer.set_tick_groups(device_stream_tick_groups(pplan))
        if tracer is not None and schedule == "stream":
            # the fused tick step is not separable per stage -- probe
            # each stage's cost in isolation (PipeDream-style) for the
            # per-device attribution in the trace and drift report
            tracer.set_probed(probe_stage_costs(
                model, state["params"]["stages"],
                mb=max(1, args.batch // args.ticks), seq=args.seq))
        step_fn = rt.train_step

    start = 0
    if args.resume == "auto" and args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            state, last = ckpt.restore(args.ckpt_dir, state)
            start = last + 1
            print(f"# resumed from step {last}")

    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree.leaves(state["params"]))
    print(f"# arch={cfg.name} params={n_params:,} mode={args.mode} "
          f"pipe={model.n_stages} opt_floor={data.optimal_loss():.4f}")

    t0 = time.time()
    tokens = 0
    bg_save = None
    interrupted = False
    try:
        for s in range(start, args.steps):
            batch = data.batch_at(s)
            state, metrics = step_fn(state, batch)
            tokens += args.batch * args.seq
            if args.ckpt_dir and (s + 1) % args.save_every == 0:
                if bg_save is not None:
                    bg_save.join()  # never two writers on the same dir
                bg_save = ckpt.save(args.ckpt_dir, state, s,
                                    background=True)
            if (s + 1) % args.log_every == 0 or s == args.steps - 1:
                loss = float(metrics["loss"])
                dt = time.time() - t0
                rec = registry.log_step(
                    step=s + 1, loss=round(loss, 4),
                    tok_per_s=round(tokens / max(dt, 1e-9), 1))
                print(json.dumps(rec) if args.json else format_step(rec))
    except KeyboardInterrupt:
        interrupted = True
        print("# interrupted -- metrics flushed")
    finally:
        registry.close()
    if args.ckpt_dir:
        if bg_save is not None:
            bg_save.join()
        if not interrupted:
            ckpt.save(args.ckpt_dir, state, args.steps - 1)
    if tracer is not None and tracer.n_steps():
        write_trace(args.trace, tracer)
        print(f"# trace written to {args.trace} "
              f"({tracer.n_steps()} steps recorded)")
        print(format_drift(drift_report(tracer)))
    return 1 if interrupted else 0


if __name__ == "__main__":
    raise SystemExit(main())
