"""Gradient compression for the data/pod-axis all-reduce (beyond-paper;
from the paper's related-work menu: Aji&Heafield'17 / Lin et al.'17 /
Seide et al.'14).

* ``topk``  — magnitude top-k sparsification with error feedback: the
  residual of what wasn't transmitted is added back next step, so the
  compressed series telescopes to the true gradient sum (property-tested).
* ``int8``  — per-tensor scale quantization with stochastic rounding
  (unbiased), the all-reduce-friendly analogue of 1-bit SGD.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# top-k with error feedback


def topk_init(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def topk_compress(grads, residual, *, frac: float = 0.01
                  ) -> Tuple[Any, Any, Dict[str, Any]]:
    """Returns (transmitted_dense, new_residual, stats).

    transmitted_dense is the sparsified gradient densified again (what the
    receiving side reconstructs); new_residual = carry for error feedback.
    """
    stats = {"kept": 0, "total": 0}

    def leaf(g, r):
        acc = g.astype(jnp.float32) + r
        flat = acc.reshape(-1)
        k = max(1, int(frac * flat.size))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        sent = jnp.zeros_like(flat).at[idx].set(flat[idx])
        stats["kept"] += k
        stats["total"] += flat.size
        return sent.reshape(g.shape), acc - sent.reshape(g.shape)

    flat, treedef = jax.tree.flatten(grads)
    rflat = treedef.flatten_up_to(residual)
    out = [leaf(g, r) for g, r in zip(flat, rflat)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]), stats)


# ---------------------------------------------------------------------------
# int8 stochastic-rounding quantization


def int8_roundtrip(grads, key) -> Any:
    """Quantize to int8 with per-tensor scale + stochastic rounding, then
    dequantize (unbiased: E[deq] = g).  Models the wire format of an int8
    all-reduce (4x fewer bytes than fp32)."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))

    def leaf(g, k):
        gf = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        x = gf / scale
        lo = jnp.floor(x)
        p = x - lo
        up = jax.random.uniform(k, x.shape) < p
        q = jnp.clip(lo + up.astype(jnp.float32), -127, 127)
        return (q * scale).astype(g.dtype)

    return treedef.unflatten([leaf(g, k) for g, k in zip(leaves, keys)])
