from repro.optim import adam, sgd  # noqa: F401
from repro.optim.sgd import MomentumState, clip_by_global_norm  # noqa: F401
