"""Momentum SGD exactly as the paper uses it (§3.2, Eq. 1):

  v_t     = γ·v_{t−1} + (1−γ)·g_t
  W_{t+1} = W_t − η·v_t

Momentum lives in fp32 regardless of param dtype (mixed-precision master
update happens in fp32 and is cast back).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class MomentumState(NamedTuple):
    v: Any                      # smoothed gradient, fp32


def init(params) -> MomentumState:
    return MomentumState(
        v=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def update(params, state: MomentumState, grads, *, lr, gamma: float = 0.9
           ) -> Tuple[Any, MomentumState]:
    lr = jnp.asarray(lr, jnp.float32)

    def upd(p, v, g):
        v2 = gamma * v + (1.0 - gamma) * g.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * v2
        return p2.astype(p.dtype), v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_v = treedef.flatten_up_to(state.v)
    flat_g = treedef.flatten_up_to(grads)
    out = [upd(p, v, g) for p, v, g in zip(flat_p, flat_v, flat_g)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    return new_p, MomentumState(new_v)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (n + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), n
