"""Momentum SGD exactly as the paper uses it (§3.2, Eq. 1):

  v_t     = γ·v_{t−1} + (1−γ)·g_t
  W_{t+1} = W_t − η·v_t

Momentum lives in fp32 regardless of param dtype (mixed-precision master
update happens in fp32 and is cast back).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class MomentumState(NamedTuple):
    v: Any                      # smoothed gradient, fp32


def init(params) -> MomentumState:
    return MomentumState(
        v=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def update(params, state: MomentumState, grads, *, lr, gamma: float = 0.9
           ) -> Tuple[Any, MomentumState]:
    lr = jnp.asarray(lr, jnp.float32)

    def upd(p, v, g):
        v2 = gamma * v + (1.0 - gamma) * g.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * v2
        return p2.astype(p.dtype), v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_v = treedef.flatten_up_to(state.v)
    flat_g = treedef.flatten_up_to(grads)
    out = [upd(p, v, g) for p, v, g in zip(flat_p, flat_v, flat_g)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    return new_p, MomentumState(new_v)


def _canonical_sumsq(tree) -> jnp.ndarray:
    """Layout-canonical sum of squares over a gradient/param tree.

    The ragged per-stage canonical layout and the legacy stacked
    ``[S, Lps, ...]`` layout group the *same* layer parameters into
    differently-shaped leaves, so a naive per-leaf-then-python-sum
    reduction associates the additions differently and the two layouts
    drift bitwise — the one layout-sensitive numeric in the codebase.

    Canonical order: every leaf is reduced to per-layer-granularity
    float32 partials (ragged stage leaves ``[L_k, ...]`` per leading
    index; stacked ``stages`` leaves per ``(stage, layer)`` pair, which
    is the identical partial multiset in the identical stage-major
    order; other leaves whole), partial vectors are grouped by their
    tree path with sequence indices stripped (so stage k and stage j of
    one parameter share a group, ordered by stage), groups are sorted
    by path, and ONE reduction runs over the concatenated vector.  Any
    stage grouping of the same layers therefore reduces the exact same
    vector in the exact same order."""
    from jax.tree_util import SequenceKey, tree_flatten_with_path
    groups: dict = {}
    for path, leaf in tree_flatten_with_path(tree)[0]:
        names, idxs, in_seq = [], [], False
        for p in path:
            if isinstance(p, SequenceKey):
                in_seq = True
                idxs.append(p.idx)
            else:
                names.append(str(getattr(p, "key", p)))
        x = jnp.square(jnp.asarray(leaf).astype(jnp.float32))
        if x.ndim == 0:
            part = x[None]
        elif in_seq:
            # ragged stage tree leaf [L_k, ...]: per-layer partials
            part = jnp.sum(x.reshape((x.shape[0], -1)), axis=1)
        elif "stages" in names and x.ndim >= 2:
            # legacy stacked [S, Lps, ...]: per-(stage, layer) partials,
            # stage-major == the ragged per-stage concatenation order
            part = jnp.sum(x.reshape((x.shape[0] * x.shape[1], -1)), axis=1)
        else:
            part = jnp.sum(x)[None]
        groups.setdefault("/".join(names), []).append((tuple(idxs), part))
    vecs = [part
            for key in sorted(groups)
            for _, part in sorted(groups[key], key=lambda kv: kv[0])]
    if not vecs:
        return jnp.zeros(())
    # sequential accumulation via scan: XLA cannot reassociate it, so
    # the canonical order survives jit.  A fused jnp.sum over the
    # concatenation does NOT suffice even though the concatenated
    # vector is identical across layouts: XLA fissions concat+reduce
    # into per-operand partial reductions, and the operand structure
    # (one [L] vector vs S smaller ones) differs per layout — measured
    # as a bitwise mismatch under jit before this scan was introduced.
    total, _ = jax.lax.scan(lambda c, x: (c + x, None), jnp.zeros(()),
                            jnp.concatenate(vecs))
    return total


def global_norm(tree) -> jnp.ndarray:
    """Canonical-order global L2 norm: bitwise layout-independent
    between the ragged per-stage and stacked stage-param layouts (see
    :func:`_canonical_sumsq`)."""
    return jnp.sqrt(_canonical_sumsq(tree))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (n + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), n
