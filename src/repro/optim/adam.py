"""AdamW (beyond-paper option) with a SpecTrain-compatible prediction hook.

The paper's prediction (Eq. 4) is exact for momentum SGD.  For Adam the
analogous predicted displacement per step is the preconditioned first
moment: Ŵ_{t+s} ≈ W_t − s·η·m̂/(√v̂+ε).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def init(params) -> AdamState:
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(m=jax.tree.map(z, params), v=jax.tree.map(z, params),
                     count=jnp.zeros((), jnp.int32))


def update(params, state: AdamState, grads, *, lr, b1=0.9, b2=0.999,
           eps=1e-8, weight_decay=0.0) -> Tuple[Any, AdamState]:
    c = state.count + 1
    cf = c.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    def upd(p, m, v, g):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        step = lr * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        p2 = (p.astype(jnp.float32) - step
              - lr * weight_decay * p.astype(jnp.float32))
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_g = treedef.flatten_up_to(grads)
    out = [upd(p, m, v, g)
           for p, m, v, g in zip(flat_p, flat_m, flat_v, flat_g)]
    return (treedef.unflatten([o[0] for o in out]),
            AdamState(treedef.unflatten([o[1] for o in out]),
                      treedef.unflatten([o[2] for o in out]), c))


def predict(params, state: AdamState, *, lr, s, eps=1e-8):
    s = jnp.asarray(s, jnp.float32)

    def leaf(p, m, v):
        disp = m / (jnp.sqrt(v) + eps)
        return (p.astype(jnp.float32) - s * lr * disp).astype(p.dtype)

    return jax.tree.map(leaf, params, state.m, state.v)
