#!/usr/bin/env python
"""Docs rot check: intra-repo markdown links must resolve and fenced
``python``/``bash``/``sh`` code blocks must at least parse.

Run from anywhere inside the repo:

    python tools/check_docs.py            # checks the default doc set
    python tools/check_docs.py README.md  # or explicit files

Checks, per markdown file:

  * every ``[text](target)`` link whose target is not an URL or a pure
    anchor points at an existing file/directory (anchors on existing
    files are accepted; anchor validity itself is not checked);
  * every fenced code block tagged ``python`` compiles
    (``compile(..., "exec")``);
  * every fenced code block tagged ``bash``/``sh`` passes ``bash -n``
    (skipped with a notice if bash is unavailable).

Exit code 0 = clean, 1 = at least one problem (listed on stderr).
"""
from __future__ import annotations

import re
import shutil
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

DEFAULT_DOCS = [
    "README.md",
    "EXPERIMENTS.md",
    "ROADMAP.md",
    "docs/ARCHITECTURE.md",
    "docs/SCHEDULES.md",
    "docs/OBSERVABILITY.md",
    "docs/SERVING.md",
]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\w*)\s*$")
_BASH = shutil.which("bash")


def iter_code_blocks(text: str):
    """Yield (language, first_line_number, code) for fenced blocks."""
    lang, start, buf = None, 0, []
    for i, line in enumerate(text.splitlines(), 1):
        m = FENCE_RE.match(line.strip())
        if m and lang is None:
            lang, start, buf = m.group(1).lower(), i, []
        elif line.strip() == "```" and lang is not None:
            yield lang, start, "\n".join(buf)
            lang = None
        elif lang is not None:
            buf.append(line)


def check_links(path: Path, text: str, problems: list) -> None:
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        target = target.split("#", 1)[0]
        if not target:
            continue
        resolved = (path.parent / target).resolve()
        if not resolved.exists():
            problems.append(f"{path}: broken link -> {m.group(1)}")


def check_code(path: Path, text: str, problems: list) -> None:
    for lang, line, code in iter_code_blocks(text):
        if lang == "python":
            try:
                compile(code, f"{path}:{line}", "exec")
            except SyntaxError as e:
                problems.append(
                    f"{path}:{line}: python block does not compile: {e}")
        elif lang in ("bash", "sh"):
            if _BASH is None:
                print(f"note: bash unavailable, skipping shell block "
                      f"at {path}:{line}")
                continue
            r = subprocess.run([_BASH, "-n"], input=code, text=True,
                               capture_output=True)
            if r.returncode != 0:
                problems.append(
                    f"{path}:{line}: shell block does not parse: "
                    f"{r.stderr.strip()}")


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    files = [Path(a) for a in args] if args else \
        [REPO / d for d in DEFAULT_DOCS]
    problems: list = []
    for f in files:
        if not f.exists():
            problems.append(f"{f}: file missing")
            continue
        text = f.read_text(encoding="utf-8")
        check_links(f, text, problems)
        check_code(f, text, problems)
    for p in problems:
        print(p, file=sys.stderr)
    n = sum(1 for f in files if f.exists())
    print(f"checked {n} doc file(s): "
          f"{'OK' if not problems else f'{len(problems)} problem(s)'}")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
