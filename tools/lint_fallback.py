#!/usr/bin/env python
"""Dependency-free mirror of the repo's ruff configuration.

The authoritative linter is ruff (``pyproject.toml [tool.ruff]``,
installed by the CI lint job via ``pip install -e .[lint]``); this tool
re-implements the *stable* pycodestyle/pyflakes rules that config
selects using only the standard library, so air-gapped containers (no
pip) can keep the tree lint-clean before pushing:

    python tools/lint_fallback.py             # lint src tests benchmarks tools
    python tools/lint_fallback.py path.py …   # explicit files

Implemented rules (ruff codes):

  E401  multiple imports on one line          E711  ``== None``
  E402  module import not at top of file      E712  ``== True/False``
  E501  line too long (79, from pyproject)    E722  bare ``except:``
  E741  ambiguous variable name ``l O I``     F401  unused import
  W191  tab indentation                       F541  f-string w/o fields
  W291/W293  trailing whitespace              F632  ``is`` with literal
  W292  missing newline at end of file        F811  redefined name

``# noqa`` comments are honored, bare or with codes, like ruff's.
E731 is ignored to match the config.  The subtler pyflakes analyses
(F821 undefined names, F841 unused locals) are left to ruff — this
mirror never flags what ruff would not.

Exit code 0 = clean, 1 = at least one violation (listed on stdout).
"""
from __future__ import annotations

import ast
import re
import sys
import tokenize
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
MAX_LEN = 79
DEFAULT_DIRS = ("src", "tests", "benchmarks", "tools")
NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<codes>[A-Z0-9, ]+))?",
                     re.IGNORECASE)
AMBIGUOUS = {"l", "O", "I"}


class FileLint:
    def __init__(self, path: Path, text: str):
        self.path = path
        self.lines = text.splitlines()
        self.noqa: dict = {}
        for i, line in enumerate(self.lines, 1):
            m = NOQA_RE.search(line)
            if m:
                codes = m.group("codes")
                self.noqa[i] = (set(c.strip() for c in codes.split(","))
                                if codes else None)   # None = bare noqa
        self.problems: list = []

    def add(self, line: int, code: str, msg: str) -> None:
        if line in self.noqa:
            codes = self.noqa[line]
            if codes is None or code in codes:
                return
        self.problems.append((line, code, msg))


def _iter_names(target):
    """Yield Name nodes bound by an assignment/loop target."""
    for node in ast.walk(target):
        if isinstance(node, ast.Name):
            yield node


def check_lines(fl: FileLint) -> None:
    for i, line in enumerate(fl.lines, 1):
        if len(line) > MAX_LEN:
            fl.add(i, "E501",
                   f"line too long ({len(line)} > {MAX_LEN})")
        if line != line.rstrip():
            fl.add(i, "W291" if line.strip() else "W293",
                   "trailing whitespace")
        if line[:1] == "\t" or line.lstrip(" ")[:1] == "\t":
            fl.add(i, "W191", "indentation contains tabs")


def check_tokens(fl: FileLint, text: str) -> None:
    comparisons = {"None": "E711", "True": "E712", "False": "E712"}
    try:
        toks = list(tokenize.generate_tokens(iter(text.splitlines(
            keepends=True)).__next__))
    except tokenize.TokenError:
        return
    for a, b in zip(toks, toks[1:]):
        if a.type == tokenize.OP and a.string in ("==", "!=") and \
                b.type == tokenize.NAME and b.string in comparisons:
            code = comparisons[b.string]
            fl.add(a.start[0], code,
                   f"comparison to {b.string} (use "
                   f"{'is' if code == 'E711' else 'truthiness/is'})")


def _module_prefix_ok(node) -> bool:
    """Statements E402 permits above imports."""
    if isinstance(node, ast.Expr) and isinstance(node.value,
                                                 ast.Constant):
        return True   # docstring
    if isinstance(node, ast.ImportFrom) and node.module == "__future__":
        return True
    if isinstance(node, (ast.Assign, ast.AnnAssign)):
        targets = node.targets if isinstance(node, ast.Assign) else \
            [node.target]
        return all(isinstance(t, ast.Name) and t.id.startswith("__")
                   and t.id.endswith("__") for t in targets)
    return False


def check_ast(fl: FileLint, tree: ast.Module, is_init: bool) -> None:
    # ---- E402 + module import inventory for F401/F811 ----------------
    code_seen = False
    imports: list = []          # (alias name, line, is_star)
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            future = (isinstance(node, ast.ImportFrom)
                      and node.module == "__future__")
            if code_seen and not future:
                fl.add(node.lineno, "E402",
                       "module level import not at top of file")
            if isinstance(node, ast.Import) and len(node.names) > 1:
                fl.add(node.lineno, "E401",
                       "multiple imports on one line")
            if future:
                continue
            for alias in node.names:
                if alias.name == "*":
                    continue
                bound = alias.asname or alias.name.split(".")[0]
                imports.append((bound, node.lineno))
        elif not _module_prefix_ok(node):
            code_seen = True

    # ---- F401: unused imports (skip when __all__ re-exports) ---------
    used = set()
    explicit_all = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            pass
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__" and \
                        isinstance(node.value, (ast.List, ast.Tuple)):
                    for elt in node.value.elts:
                        if isinstance(elt, ast.Constant):
                            explicit_all.add(elt.value)
    for name, line in imports:
        if name in used or name in explicit_all:
            continue
        if is_init and not explicit_all:
            continue   # __init__ re-export convention without __all__
        fl.add(line, "F401", f"{name!r} imported but unused")

    # ---- F811: same top-level name imported/defined twice ------------
    defined: dict = {}
    for node in tree.body:
        names = []
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            names = [(a.asname or a.name.split(".")[0], node.lineno)
                     for a in node.names if a.name != "*"]
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            names = [(node.name, node.lineno)]
        for name, line in names:
            if name in defined and name not in used:
                fl.add(line, "F811",
                       f"redefinition of unused {name!r} from line "
                       f"{defined[name]}")
            defined[name] = line

    # format specs ({x:<40}) are themselves JoinedStr nodes — never
    # F541 candidates
    specs = {id(n.format_spec) for n in ast.walk(tree)
             if isinstance(n, ast.FormattedValue) and n.format_spec}
    for node in ast.walk(tree):
        # ---- E722 ----------------------------------------------------
        if isinstance(node, ast.ExceptHandler) and node.type is None:
            fl.add(node.lineno, "E722", "bare except")
        # ---- E741 ----------------------------------------------------
        if isinstance(node, (ast.Assign, ast.For)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                for n in _iter_names(t):
                    if n.id in AMBIGUOUS:
                        fl.add(n.lineno, "E741",
                               f"ambiguous variable name {n.id!r}")
        if isinstance(node, ast.comprehension):
            for n in _iter_names(node.target):
                if n.id in AMBIGUOUS:
                    fl.add(n.lineno, "E741",
                           f"ambiguous variable name {n.id!r}")
        if isinstance(node, ast.ExceptHandler) and node.name in AMBIGUOUS:
            fl.add(node.lineno, "E741",
                   f"ambiguous variable name {node.name!r}")
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            args = node.args
            for a in (args.posonlyargs + args.args + args.kwonlyargs
                      + ([args.vararg] if args.vararg else [])
                      + ([args.kwarg] if args.kwarg else [])):
                if a.arg in AMBIGUOUS:
                    fl.add(a.lineno, "E741",
                           f"ambiguous argument name {a.arg!r}")
        # ---- F541 ----------------------------------------------------
        if isinstance(node, ast.JoinedStr) and id(node) not in specs \
                and not any(isinstance(v, ast.FormattedValue)
                            for v in node.values):
            fl.add(node.lineno, "F541",
                   "f-string without any placeholders")
        # ---- F632 ----------------------------------------------------
        if isinstance(node, ast.Compare) and any(
                isinstance(op, (ast.Is, ast.IsNot))
                for op in node.ops):
            operands = [node.left] + node.comparators
            if any(isinstance(o, ast.Constant) and
                   not isinstance(o.value, (bool, type(None)))
                   for o in operands):
                fl.add(node.lineno, "F632",
                       "use == to compare with str/int/tuple literals")


def lint_file(path: Path) -> list:
    text = path.read_text(encoding="utf-8")
    fl = FileLint(path, text)
    if text and not text.endswith("\n"):
        fl.add(len(fl.lines), "W292", "no newline at end of file")
    check_lines(fl)
    check_tokens(fl, text)
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        fl.add(e.lineno or 0, "E999", f"syntax error: {e.msg}")
        return fl.problems
    check_ast(fl, tree, is_init=path.name == "__init__.py")
    return fl.problems


def main(argv=None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    if args:
        files = [Path(a) for a in args]
    else:
        files = sorted(p for d in DEFAULT_DIRS
                       for p in (REPO / d).rglob("*.py"))
    total = 0
    for f in files:
        for line, code, msg in lint_file(f):
            print(f"{f.relative_to(REPO) if f.is_absolute() else f}"
                  f":{line}: {code} {msg}")
            total += 1
    print(f"checked {len(files)} file(s): "
          f"{'OK' if not total else f'{total} violation(s)'}")
    return 1 if total else 0


if __name__ == "__main__":
    raise SystemExit(main())
