#!/usr/bin/env python
"""Schema check for the serving driver's ``--metrics-out`` JSONL.

Used by the CI ``serve-smoke`` job; dependency-free on purpose (no
jax import) so it runs anywhere:

    python tools/check_serve_metrics.py serve_spmd.jsonl [more.jsonl...]

Per file it asserts:

  * every line is a JSON object with ``event`` and ``t`` fields;
  * exactly one ``serve_run`` summary exists, its accounting closes
    (``n_served + n_rejected == n_requests``) and its throughput /
    latency fields are finite non-negative numbers;
  * the scheduler log (``serve_sched``) is well-formed — known ``ev``
    kinds, integer ``round``/``rid`` — and every admitted request is
    eventually evicted (request lifecycle closes);
  * one final ``summary`` record (the registry flush) is present.

Exit code 0 = clean, 1 = problems (listed on stderr).
"""
from __future__ import annotations

import json
import math
import sys

SCHED_EVS = {"admit", "decode", "evict", "reject"}
RUN_NUM_FIELDS = ("wall_s", "compile_s", "tok_per_s",
                  "token_ms_p50", "token_ms_p99")


def check_file(path: str, problems: list) -> None:
    records = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError as e:
                problems.append(f"{path}:{i}: not JSON: {e}")
                continue
            if not isinstance(rec, dict) or "event" not in rec \
                    or "t" not in rec:
                problems.append(f"{path}:{i}: missing event/t fields")
                continue
            records.append(rec)

    runs = [r for r in records if r["event"] == "serve_run"]
    if len(runs) != 1:
        problems.append(f"{path}: expected exactly 1 serve_run record, "
                        f"found {len(runs)}")
    for run in runs:
        for k in RUN_NUM_FIELDS:
            v = run.get(k)
            if not isinstance(v, (int, float)) or not math.isfinite(v) \
                    or v < 0:
                problems.append(f"{path}: serve_run.{k} is not a "
                                f"finite non-negative number: {v!r}")
        ns, nr, nq = (run.get(k) for k in
                      ("n_served", "n_rejected", "n_requests"))
        if not all(isinstance(v, int) for v in (ns, nr, nq)) \
                or ns + nr != nq:
            problems.append(f"{path}: serve_run accounting does not "
                            f"close: served={ns} rejected={nr} "
                            f"requests={nq}")

    admitted, evicted = set(), set()
    for r in records:
        if r["event"] != "serve_sched":
            continue
        ev = r.get("ev")
        if ev not in SCHED_EVS:
            problems.append(f"{path}: unknown serve_sched ev {ev!r}")
            continue
        if not isinstance(r.get("round"), int) \
                or not isinstance(r.get("rid"), int):
            problems.append(f"{path}: serve_sched {ev} lacks integer "
                            f"round/rid: {r}")
            continue
        if ev == "admit":
            admitted.add(r["rid"])
        elif ev == "evict":
            evicted.add(r["rid"])
    leaked = admitted - evicted
    if leaked:
        problems.append(f"{path}: admitted but never evicted "
                        f"(slot/page leak): rids {sorted(leaked)}")
    if runs and not admitted and runs[0].get("n_served"):
        problems.append(f"{path}: serve_run reports served requests "
                        f"but no serve_sched admit events")

    if not any(r["event"] == "summary" for r in records):
        problems.append(f"{path}: missing final summary record "
                        f"(registry close() flush)")


def main(argv=None) -> int:
    paths = (argv if argv is not None else sys.argv[1:])
    if not paths:
        print("usage: check_serve_metrics.py FILE.jsonl [...]",
              file=sys.stderr)
        return 2
    problems: list = []
    for p in paths:
        try:
            check_file(p, problems)
        except OSError as e:
            problems.append(f"{p}: {e}")
    for p in problems:
        print(p, file=sys.stderr)
    print(f"checked {len(paths)} metrics file(s): "
          f"{'OK' if not problems else f'{len(problems)} problem(s)'}")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
