"""IR-interpreter cost vs round size: trace / compile / step time.

The scan backend exists to keep the round body's trace (and therefore
XLA compile time) constant as M·C grows; this benchmark measures that
directly against the unrolled reference oracle.

Rows:
  ir/<backend>/M<M>  — us_per_call is steady step wall time (CPU); the
                       derived column shows trace_ms (jax.make_jaxpr),
                       compile_ms (lower + compile) and the recursive
                       jaxpr equation count.

Expected shape: scan rows have ~flat trace_ms / compile_ms / eqns in M;
unrolled rows grow ~linearly in M (and dominate wall-clock long before
the paper-scale M·C ≫ 100 regime).
"""
from __future__ import annotations

import dataclasses
import time


def _count_eqns(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        n += 1
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for x in vs:
                if hasattr(x, "jaxpr"):
                    n += _count_eqns(x.jaxpr)
                elif hasattr(x, "eqns"):
                    n += _count_eqns(x)
    return n


def main(fast: bool = True):
    import jax

    from repro.configs import get_config, smoke_config
    from repro.core import pipeline_stream
    from repro.models import Model
    from repro.planner import plan, synthetic_profile

    cfg = smoke_config(get_config("granite-8b"))
    cfg = cfg.replace(
        n_layers=4,
        mesh_plan=dataclasses.replace(cfg.mesh_plan, pipe=2),
        param_dtype="float32", compute_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    sizes = [4, 16] if fast else [4, 16, 64]
    lines = []
    for backend in pipeline_stream.IR_BACKENDS:
        for M in sizes:
            p = plan(profile=synthetic_profile([1.0] * cfg.n_layers),
                     n_stages=2, schedule="1f1b", n_microbatches=M)
            k = jax.random.PRNGKey(1)
            batch = {
                "tokens": jax.random.randint(k, (M, 16), 0, cfg.vocab_size),
                "targets": jax.random.randint(k, (M, 16), 0,
                                              cfg.vocab_size),
            }
            state = pipeline_stream.make_ir_state(model, params, None,
                                                  plan=p)
            step = pipeline_stream.make_ir_train_step(
                model, plan=p, mode="spectrain", lr=0.05, backend=backend)

            t0 = time.perf_counter()
            jaxpr = jax.make_jaxpr(step)(state, batch)
            trace_ms = (time.perf_counter() - t0) * 1e3
            eqns = _count_eqns(jaxpr.jaxpr)

            t0 = time.perf_counter()
            compiled = jax.jit(step).lower(state, batch).compile()
            compile_ms = (time.perf_counter() - t0) * 1e3

            jax.block_until_ready(compiled(state, batch))   # warm-up
            reps = 3
            t0 = time.perf_counter()
            for _ in range(reps):
                out = compiled(state, batch)
            jax.block_until_ready(out)
            us = (time.perf_counter() - t0) / reps * 1e6

            lines.append(
                f"ir/{backend}/M{M},{us:.0f},"
                f"trace_ms={trace_ms:.0f};compile_ms={compile_ms:.0f};"
                f"eqns={eqns}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
