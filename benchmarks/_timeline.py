"""Discrete-event timeline model of the paper's execution modes on the
4-GPU PCIe box (Figs. 9/10).

Models, per GPU: compute busy time (fwd/bwd), P2P transfer time,
P2P-induced idle (link contention), and imbalance-induced idle — the four
components of the paper's Fig. 10 breakdown.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

# paper platform constants (§4.1): 4x Tesla P40 on PCIe 3.0 x16
P40_FLOPS = 11.76e12 * 0.35     # fp32 peak x achievable efficiency
PCIE_BW = 12.0e9                # bytes/s effective per link
N_GPUS_DEFAULT = 4


@dataclass
class ModelCost:
    name: str
    params: int                     # total weights
    flops_per_sample: float         # fwd flops per sample
    cut_activations: Tuple[int, ...]  # elements crossing each pipeline cut
    batch: int = 128


def dp_step_time(m: ModelCost, n_gpus: int) -> Dict[str, float]:
    """Synchronous data parallelism: compute on batch/n, then grad sync.

    The Falconwitch box supports simultaneous P2P transfers (§4.1), so the
    sync is ring-style: 2 x params x 4B x (n-1)/n per link, plus ~20%
    switch-contention idle.
    """
    compute = 3.0 * m.flops_per_sample * (m.batch / n_gpus) / P40_FLOPS
    bytes_per_link = 2.0 * m.params * 4.0 * (n_gpus - 1) / n_gpus
    p2p = bytes_per_link / PCIE_BW
    p2p_idle = 0.2 * p2p
    step = compute + p2p + p2p_idle
    return {"step": step, "compute": compute, "p2p": p2p,
            "p2p_idle": p2p_idle, "imbalance_idle": 0.0}


def pipeline_step_time(m: ModelCost, n_gpus: int, *,
                       imbalance: float = 0.08) -> Dict[str, float]:
    """Steady-state 1F1B pipeline (PipeDream-style, zero bubble after
    warm-up): per-minibatch time = the slowest stage's fwd+bwd time, with
    activation transfers overlapped (background thread, §3.1) except for
    their on-link serialization."""
    per_stage_flops = 3.0 * m.flops_per_sample * m.batch / n_gpus
    stage = per_stage_flops / P40_FLOPS
    slowest = stage * (1.0 + imbalance)
    # activation + gradient bytes on the busiest link
    if m.cut_activations:
        cut = max(m.cut_activations)
        act_bytes = 2.0 * cut * 4.0 * m.batch
    else:
        act_bytes = 0.0
    p2p = act_bytes / PCIE_BW
    step = max(slowest, p2p)        # overlapped; the max wins
    imbalance_idle = slowest - stage
    p2p_idle = max(0.0, p2p - slowest)
    return {"step": step, "compute": stage, "p2p": min(p2p, step),
            "p2p_idle": p2p_idle, "imbalance_idle": imbalance_idle}


def single_gpu_step(m: ModelCost) -> float:
    return 3.0 * m.flops_per_sample * m.batch / P40_FLOPS


def throughput(m: ModelCost, mode: str, n_gpus: int) -> float:
    """samples/sec, normalized externally."""
    if mode == "single":
        return m.batch / single_gpu_step(m)
    if mode == "dp":
        return m.batch / dp_step_time(m, n_gpus)["step"]
    return m.batch / pipeline_step_time(m, n_gpus)["step"]


# ---------------------------------------------------------------------------
# the paper's six benchmark models (§4.1), as cost models


def paper_models() -> List[ModelCost]:
    return [
        # CNNs (CIFAR-10, 32x32): flops ~ 2 * params_eff * spatial reuse
        ModelCost("vgg16", 138_357_544, 0.63e9,
                  (128 * 16 * 16, 256 * 8 * 8, 512 * 4 * 4)),
        ModelCost("resnet152", 60_192_808, 2.3e9,
                  (256 * 16 * 16, 512 * 8 * 8, 1024 * 4 * 4)),
        ModelCost("inception_v4", 42_679_816, 1.4e9,
                  (384 * 8 * 8, 1024 * 4 * 4, 1536 * 2 * 2)),
        # SNN: 32 FC layers x 2048 (CIFAR input)
        ModelCost("snn", 32 * 2048 * 2048 + 3072 * 2048, 2 * 32 * 2048 * 2048,
                  (2048, 2048, 2048)),
        # Transformer: 6+6 blocks, d=512, seq 20 (IMDb)
        ModelCost("transformer", 44_000_000 + 30000 * 512,
                  2 * 44_000_000 * 20, (20 * 512, 20 * 512, 20 * 512)),
        # Residual LSTM: 8 layers, 1024 mem units, seq 80
        ModelCost("residual_lstm", 8 * 4 * (512 * 1024 + 1024 * 1024),
                  2 * 8 * 4 * (512 + 1024) * 1024 * 80,
                  (80 * 512, 80 * 512, 80 * 512)),
    ]


def lm_models() -> List[ModelCost]:
    """Our ten assigned archs as cost models (seq 4096 training shape)."""
    from repro.configs import get_config, list_archs
    out = []
    for name in list_archs():
        cfg = get_config(name)
        seq = 4096
        out.append(ModelCost(
            name, cfg.param_count(),
            2.0 * cfg.active_param_count() * seq,
            tuple([cfg.d_model * seq] * 3), batch=16))
    return out
