"""Continuous-batching serving throughput and per-token latency.

Drives a seeded Poisson trace through the pipelined ``ServeEngine``
(ISSUE: >= 32 requests, mixed prompt/generation lengths in full mode)
and reports the serving numbers the paper's inference story needs:

Rows (primary column is us per emitted token = 1e6 / tok/s, so the
bench gate's "lower is better" convention holds):
  serve/scan_tok      — scan (SPMD) backend, us/token; derived carries
                        tok/s and the p50/p99 per-token latency from
                        the engine's round histogram;
  serve/mpmd_tok      — shard_map (MPMD) backend, same trace —
                        emitted tokens are checked bitwise against the
                        scan run before timing is reported;
  serve/simple_tok    — the whole-model SimpleEngine reference (one
                        request at a time, no batching): the derived
                        speedup column is the continuous-batching win;
  serve/compile       — engine warm-up (compile) time, us.

Wall time excludes compilation: engines warm up on throwaway caches
before the trace is driven.  The mpmd row is skipped (not failed) when
fewer than two devices are visible.
"""
from __future__ import annotations

import time


def _drive(engine, trace):
    t0 = time.perf_counter()
    results = engine.run(trace)
    wall_s = time.perf_counter() - t0
    n_tokens = sum(len(t) for t in results.values())
    return results, n_tokens, wall_s


def main(fast: bool = True):
    import jax

    from repro.models import Model
    from repro.obs import MetricsRegistry
    from repro.planner import serve_plan
    from repro.serve import ServeEngine, SimpleEngine, poisson_trace
    from benchmarks.conftest_shim import tiny_cfg

    cfg = tiny_cfg("granite-8b", n_layers=4, pipe=2)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n_req = 8 if fast else 32
    splan_kw = dict(n_slots=4, max_prefill=2, prompt_budget=12,
                    page_seq=32, n_layers=cfg.n_layers)
    trace = poisson_trace(n_req, rate=1.5, seed=0, prompt_lens=(2, 12),
                          gen_lens=(1, 8), vocab=cfg.vocab_size)

    rows = []

    def _bench(backend):
        reg = MetricsRegistry()
        eng = ServeEngine(model, params, serve_plan(None, n_stages=2,
                                                    **splan_kw),
                          backend=backend, registry=reg)
        results, n_tokens, wall_s = _drive(eng, trace)
        hist = reg.histogram("serve/token_ms")
        compile_s = reg.gauge("serve/compile_s").value or 0.0
        us_tok = wall_s / max(n_tokens, 1) * 1e6
        return results, us_tok, compile_s, dict(
            tok_per_s=n_tokens / max(wall_s, 1e-9),
            p50_ms=hist.percentile(50.0), p99_ms=hist.percentile(99.0),
            n_tokens=n_tokens)

    scan_res, scan_us, compile_s, d = _bench("scan")
    rows.append(f"serve/scan_tok,{scan_us:.0f},"
                f"tok_per_s={d['tok_per_s']:.1f};"
                f"p50_ms={d['p50_ms']:.2f};p99_ms={d['p99_ms']:.2f};"
                f"requests={n_req};tokens={d['n_tokens']}")
    rows.append(f"serve/compile,{compile_s * 1e6:.0f},backend=scan")

    if jax.device_count() >= 2:
        mpmd_res, mpmd_us, _, d = _bench("mpmd")
        assert mpmd_res == scan_res, \
            "mpmd serving diverged from scan (tokens not bitwise equal)"
        rows.append(f"serve/mpmd_tok,{mpmd_us:.0f},"
                    f"tok_per_s={d['tok_per_s']:.1f};"
                    f"p50_ms={d['p50_ms']:.2f};p99_ms={d['p99_ms']:.2f};"
                    f"bitwise=ok")

    reg = MetricsRegistry()
    simple = SimpleEngine(model, params,
                          serve_plan(None, n_stages=2, **splan_kw),
                          registry=reg)
    simple_res, n_tokens, wall_s = _drive(simple, trace)
    assert simple_res == scan_res, \
        "pipelined serving diverged from the whole-model reference"
    simple_us = wall_s / max(n_tokens, 1) * 1e6
    rows.append(f"serve/simple_tok,{simple_us:.0f},"
                f"batching_speedup={simple_us / max(scan_us, 1e-9):.2f}x")
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
