"""§Roofline: per (arch x shape x mesh) terms from the dry-run artifacts.

Reads results/dryrun/cells.jsonl (produced by
``python -m repro.launch.dryrun --all --out results/dryrun/cells.jsonl``).
Each row: the three roofline terms (s), dominant bottleneck, MODEL_FLOPS,
useful-flops ratio, and the roofline fraction.
"""
from __future__ import annotations

import json
import os

DEFAULT = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun", "cells.jsonl")


def load(path: str = DEFAULT):
    if not os.path.exists(path):
        return []
    rows = {}
    for line in open(path):
        r = json.loads(line)
        rows[(r["arch"], r["shape"], r.get("mesh", "?"))] = r
    return list(rows.values())


def main(fast: bool = True, path: str = DEFAULT):
    rows = load(path)
    lines = []
    if not rows:
        lines.append("roofline/missing,0,"
                     "run `python -m repro.launch.dryrun --all --out "
                     "results/dryrun/cells.jsonl` first")
        return lines
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                         r.get("mesh", ""))):
        tag = f"roofline/{r['arch']}/{r['shape']}/{r.get('mesh','')}"
        if r["status"] == "skip":
            lines.append(f"{tag},0,skip={r['skip_reason'][:60]}")
            continue
        if r["status"] != "ok":
            lines.append(f"{tag},0,FAIL={r.get('error','')[:80]}")
            continue
        t = r["terms"]
        step_us = max(t.values()) * 1e6
        lines.append(
            f"{tag},{step_us:.0f},"
            f"compute_s={t['compute_s']:.4f};memory_s={t['memory_s']:.4f};"
            f"collective_s={t['collective_s']:.4f};dom={r['dominant']};"
            f"frac={r['roofline_fraction']:.3f};"
            f"useful={r['useful_flops_ratio']:.2f}")
    oks = [r for r in rows if r["status"] == "ok"]
    if oks:
        fr = [r["roofline_fraction"] for r in oks]
        lines.append(f"roofline/summary,0,cells={len(rows)};ok={len(oks)};"
                     f"frac_min={min(fr):.3f};frac_max={max(fr):.3f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
