"""Reduced-config helper shared by benchmarks (mirror of tests/conftest)."""
from repro.configs import get_config, smoke_config
from repro.configs.base import MeshPlan


def tiny_cfg(name="granite-8b", *, n_layers=4, pipe=2, tensor=1, ticks=2,
             **kw):
    cfg = smoke_config(get_config(name))
    return cfg.replace(
        n_layers=n_layers,
        mesh_plan=MeshPlan(pipe=pipe, tensor=tensor, num_microbatches=ticks),
        param_dtype="float32", compute_dtype="float32", **kw)
