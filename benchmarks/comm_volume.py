"""Fig. 3 reproduction: inter-GPU data volume per minibatch, DP vs
pipelined MP, on the 4-GPU platform — for the paper's six models and the
ten assigned LM architectures.
"""
from __future__ import annotations

from typing import List

from benchmarks._timeline import ModelCost, lm_models, paper_models


def volumes(m: ModelCost, n_gpus: int = 4):
    dp = 2.0 * m.params * 4.0 * n_gpus          # grads up + weights down
    mp = 2.0 * 4.0 * m.batch * sum(m.cut_activations)  # act fwd + grad bwd
    return dp, mp


def rows(models: List[ModelCost]):
    out = []
    for m in models:
        dp, mp = volumes(m)
        out.append((m.name, dp, mp, dp / max(mp, 1.0)))
    return out


def main(fast: bool = True):
    lines = []
    rs = rows(paper_models()) + rows(lm_models())
    for name, dp, mp, ratio in rs:
        lines.append(f"comm_volume/{name},0,"
                     f"dp_MB={dp/2**20:.1f};mp_MB={mp/2**20:.1f};"
                     f"ratio={ratio:.1f}")
    ratios = [r[3] for r in rs]
    import numpy as np
    lines.append(f"comm_volume/geomean_ratio,0,"
                 f"{float(np.exp(np.mean(np.log(ratios)))):.1f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
