"""Per-kernel microbenchmark: interpret-mode validation error vs oracle +
derived FLOP counts (the wall-clock here is CPU interpret mode — the
numbers that matter for TPU are the derived FLOPs/bytes per call)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def tr(t):
    return jnp.swapaxes(t, 1, 2)


def _time(fn, *args, n=3):
    fn(*args)
    t0 = time.time()
    for _ in range(n):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) / n * 1e6


def main(fast: bool = True):
    lines = []
    key = jax.random.PRNGKey(0)

    # flash attention
    b, H, KV, s, d = 1, 4, 2, 256, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, H, d))
    k = jax.random.normal(ks[1], (b, s, KV, d))
    v = jax.random.normal(ks[2], (b, s, KV, d))
    us = _time(lambda *a: ops.flash_attention(*a, True, 128, 128, True),
               q, k, v)
    o = ops.flash_attention(q, k, v, True, 128, 128, True)
    o_ref = tr(ref.attention_ref(tr(q), tr(k), tr(v), causal=True))
    err = float(jnp.max(jnp.abs(o - o_ref)))
    flops = 4 * b * H * s * s * d // 2
    lines.append(f"kernel/flash_attention,{us:.0f},"
                 f"flops={flops};max_err={err:.1e}")

    # rwkv6
    b, h, s, hd = 1, 2, 128, 32
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (b, s, h, hd))
    kk = jax.random.normal(ks[1], (b, s, h, hd)) * 0.3
    vv = jax.random.normal(ks[2], (b, s, h, hd))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, hd)) * 0.5))
    u = jax.random.normal(ks[4], (h, hd)) * 0.3
    S0 = jnp.zeros((b, h, hd, hd))
    us = _time(lambda *a: ops.rwkv6_scan(*a, chunk=32, interpret=True)[0],
               r, kk, vv, w, u, S0)
    y, _ = ops.rwkv6_scan(r, kk, vv, w, u, S0, chunk=32, interpret=True)
    y_ref, _ = ref.rwkv6_ref(tr(r), tr(kk), tr(vv), tr(w), u, S0)
    err = float(jnp.max(jnp.abs(y - tr(y_ref))))
    lines.append(f"kernel/rwkv6_scan,{us:.0f},"
                 f"flops={4*b*h*s*hd*hd};max_err={err:.1e}")

    # mamba2
    b, h, s, p, n = 1, 2, 128, 16, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    decay = jnp.exp(-dt * 0.5)
    B = jax.random.normal(ks[3], (b, s, 1, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, 1, n)) * 0.5
    S0 = jnp.zeros((b, h, p, n))
    us = _time(lambda *a: ops.mamba2_scan(*a, chunk=32, interpret=True)[0],
               x, dt, decay, B, C, S0)
    y, _ = ops.mamba2_scan(x, dt, decay, B, C, S0, chunk=32,
                           interpret=True)
    Bh = jnp.repeat(B, h, axis=2)
    Ch = jnp.repeat(C, h, axis=2)
    y_ref, _ = ref.mamba2_ref(tr(x), jnp.moveaxis(dt, 1, 2),
                              jnp.moveaxis(decay, 1, 2), tr(Bh), tr(Ch), S0)
    err = float(jnp.max(jnp.abs(y - tr(y_ref))))
    lines.append(f"kernel/mamba2_scan,{us:.0f},"
                 f"flops={6*b*h*s*p*n};max_err={err:.1e}")

    # fused update
    ks = jax.random.split(key, 3)
    w0 = jax.random.normal(ks[0], (1 << 16,))
    v0 = jax.random.normal(ks[1], (1 << 16,))
    g0 = jax.random.normal(ks[2], (1 << 16,))
    us = _time(lambda *a: ops.fused_update(*a, lr=0.1, gamma=0.9, s=3.0,
                                           interpret=True)[0], w0, v0, g0)
    got = ops.fused_update(w0, v0, g0, lr=0.1, gamma=0.9, s=3.0,
                           interpret=True)
    exp = ref.fused_update_ref(w0, v0, g0, lr=0.1, gamma=0.9, s=3.0)
    err = max(float(jnp.max(jnp.abs(a - b_))) for a, b_ in zip(got, exp))
    # the win: 1 read of (w,v,g) + 1 write of (w',v',ŵ) vs 2 passes naive
    lines.append(f"kernel/fused_update,{us:.0f},"
                 f"bytes_saved_ratio=1.67;max_err={err:.1e}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
