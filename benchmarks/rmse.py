"""Fig. 8 reproduction: RMSE of SpecTrain-predicted vs stale weights at
version differences s ∈ {1,2,3}, measured on a real SNN training run."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.simulator import Simulator, make_mlp_staged


def main(fast: bool = True):
    steps = 150 if fast else 600
    fns, params = make_mlp_staged(jax.random.PRNGKey(0), in_dim=32,
                                  width=128, depth=8, n_classes=10,
                                  n_stages=4)
    sim = Simulator(fns, params, n_stages=4, scheme="spectrain", lr=0.05,
                    gamma=0.9, rmse_s=(1, 2, 3))

    key = jax.random.PRNGKey(7)
    wtrue = jax.random.normal(jax.random.PRNGKey(99), (32, 10))
    t0 = time.time()
    ms = []
    for i in range(steps):
        key, k1 = jax.random.split(key)
        x = jax.random.normal(k1, (64, 32))
        ms.append(sim.step({"x": x, "y": (x @ wtrue).argmax(-1)}))
    us = (time.time() - t0) / steps * 1e6

    lines = []
    for s in (1, 2, 3):
        pred = np.mean([m[f"rmse_pred_s{s}"] for m in ms[20:]])
        stale = np.mean([m[f"rmse_stale_s{s}"] for m in ms[20:]])
        lines.append(f"rmse/snn_s{s},{us:.0f},"
                     f"pred={pred:.2e};stale={stale:.2e};"
                     f"stale_over_pred={stale/pred:.2f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
