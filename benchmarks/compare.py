"""Benchmark-regression gate for CI.

Compares a fresh ``run.py --json-out`` dump against the committed
``benchmarks/baseline.json`` and exits non-zero when any shared
benchmark slowed down by more than ``--max-ratio``, or when a baseline
benchmark disappeared from the new run (a silently dropped bench would
otherwise un-gate itself).

Timings below ``--min-us`` on both sides are reported but never fail
the gate — at that scale the numbers are scheduler noise, not
regressions.  New benchmarks (present only in the new run) pass with a
note; commit an updated baseline to start gating them.

Usage:
    python benchmarks/run.py --only planner,kernels --json-out new.json
    python benchmarks/compare.py new.json benchmarks/baseline.json
"""
from __future__ import annotations

import argparse
import json


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="fresh run.py --json-out dump")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--max-ratio", type=float, default=1.5,
                    dest="max_ratio",
                    help="fail when new/base exceeds this (default 1.5)")
    ap.add_argument("--min-us", type=float, default=50.0, dest="min_us",
                    help="noise floor: rows under this on both sides "
                         "never fail the gate (default 50us)")
    args = ap.parse_args(argv)

    with open(args.new) as f:
        new = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    regressions = []
    print(f"{'benchmark':<40} {'base_us':>10} {'new_us':>10} {'ratio':>7}")
    for name in sorted(set(new) & set(base)):
        n, b = float(new[name]), float(base[name])
        ratio = n / b if b > 0 else float("inf")
        noise = max(n, b) < args.min_us
        bad = ratio > args.max_ratio and not noise
        tag = " REGRESSION" if bad else (" (noise floor)" if noise else "")
        print(f"{name:<40} {b:>10.0f} {n:>10.0f} {ratio:>7.2f}{tag}")
        if bad:
            regressions.append((name, ratio))
    for name in sorted(set(new) - set(base)):
        print(f"{name:<40} {'-':>10} {float(new[name]):>10.0f}   (new, "
              f"not gated)")
    missing = sorted(set(base) - set(new))
    for name in missing:
        print(f"{name:<40} {float(base[name]):>10.0f} {'-':>10}   MISSING")

    if regressions:
        print(f"\nFAIL: {len(regressions)} benchmark(s) regressed more "
              f"than {args.max_ratio}x: "
              + ", ".join(f"{n} ({r:.2f}x)" for n, r in regressions))
    if missing:
        print(f"\nFAIL: {len(missing)} baseline benchmark(s) missing from "
              f"the new run: " + ", ".join(missing))
    if not regressions and not missing:
        print(f"\nOK: no regression beyond {args.max_ratio}x "
              f"({len(set(new) & set(base))} benchmarks gated)")
    return 1 if (regressions or missing) else 0


if __name__ == "__main__":
    raise SystemExit(main())
