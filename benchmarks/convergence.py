"""Fig. 11 / Table 1 reproduction: learning curves + final loss of the
four schemes (Data-P reference = sync, Vanilla Model-P, PipeDream,
SpecTrain), on real training runs of the paper's FCN (SNN) and
Transformer families — both in the paper-exact simulator and in the
production streaming runtime.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import pipeline_stream
from repro.core.simulator import Simulator, make_mlp_staged
from repro.data import DataConfig, SyntheticLM
from repro.models import Model


def snn_simulator(fast: bool):
    steps = 250 if fast else 1200
    lr = 0.12
    fns, params = make_mlp_staged(jax.random.PRNGKey(0), in_dim=32,
                                  width=64, depth=8, n_classes=10,
                                  n_stages=4)
    wtrue = jax.random.normal(jax.random.PRNGKey(99), (32, 10))
    out = {}
    for scheme in Simulator.SCHEMES:
        sim = Simulator(fns, params, n_stages=4, scheme=scheme, lr=lr)
        key = jax.random.PRNGKey(1)
        losses = []
        t0 = time.time()
        for i in range(steps):
            key, k1 = jax.random.split(key)
            x = jax.random.normal(k1, (64, 32))
            losses.append(sim.step({"x": x,
                                    "y": (x @ wtrue).argmax(-1)})["loss"])
        out[scheme] = (np.mean(losses[-40:]),
                       (time.time() - t0) / steps * 1e6)
    return out


def transformer_stream(fast: bool):
    from benchmarks.conftest_shim import tiny_cfg
    steps = 150 if fast else 800
    cfg = tiny_cfg("granite-8b", n_layers=4, pipe=4)
    m = Model(cfg)
    data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 8, seed=5))
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       data.batch_at(0))
    out = {}
    for mode in pipeline_stream.MODES:
        state = pipeline_stream.init_state(m, jax.random.PRNGKey(0), sds,
                                           mode=mode)
        step = jax.jit(pipeline_stream.make_train_step(m, mode=mode,
                                                       lr=0.08))
        losses = []
        t0 = time.time()
        for s in range(steps):
            state, met = step(state, data.batch_at(s))
            if float(met["loss_valid"]):
                losses.append(float(met["loss"]))
        out[mode] = (np.mean(losses[-30:]),
                     (time.time() - t0) / steps * 1e6)
    return out, data.optimal_loss()


def main(fast: bool = True):
    lines = []
    sim = snn_simulator(fast)
    for scheme, (loss, us) in sim.items():
        lines.append(f"convergence/snn_sim/{scheme},{us:.0f},"
                     f"final_loss={loss:.4f}")
    lines.append(
        "convergence/snn_sim/spectrain_gap_vs_sync,0,"
        f"{sim['spectrain'][0] - sim['sync'][0]:+.4f}")
    tr, floor = transformer_stream(fast)
    for mode, (loss, us) in tr.items():
        lines.append(f"convergence/lm_stream/{mode},{us:.0f},"
                     f"final_loss={loss:.4f};floor={floor:.4f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
