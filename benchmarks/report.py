"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables
from results/dryrun/cells.jsonl.

``--metrics PATH.jsonl`` instead renders a telemetry JSONL stream (the
``--metrics-out`` output of ``launch/train.py`` / ``launch/serve.py``)
as markdown: one table of train-step records plus one row per other
structured event."""
from __future__ import annotations

import json
import os
import sys

DEFAULT = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun", "cells.jsonl")


def load(path=DEFAULT):
    rows = {}
    for line in open(path):
        r = json.loads(line)
        rows[(r["arch"], r["shape"], r.get("mesh", "?"))] = r
    return rows


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(rows) -> str:
    out = ["| arch | shape | mesh | status | compile s | args GB/dev |"
           " temp GB/dev | HLO GFLOP/dev | wire GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(rows.items()):
        if r["status"] == "skip":
            out.append(f"| {a} | {s} | {m} | skip ({r['skip_reason'][:40]}…) "
                       f"| | | | | |")
            continue
        if r["status"] != "ok":
            out.append(f"| {a} | {s} | {m} | **FAIL** {r.get('error','')[:60]}"
                       f" | | | | | |")
            continue
        mem = r["memory"]
        out.append(
            f"| {a} | {s} | {m} | ok | {r['compile_s']:.0f} "
            f"| {fmt_bytes(mem['argument_bytes'])} "
            f"| {fmt_bytes(mem['temp_bytes'])} "
            f"| {r['cost']['flops']/1e9:.0f} "
            f"| {fmt_bytes(r['wire_bytes_per_dev'])} |")
    return "\n".join(out)


def roofline_table(rows, mesh="16x16") -> str:
    out = ["| arch | shape | compute s | memory s | collective s |"
           " dominant | MODEL_FLOPS | useful | ideal s | **frac** |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(rows.items()):
        if m != mesh:
            continue
        if r["status"] == "skip":
            out.append(f"| {a} | {s} | — | — | — | skip | | | | |")
            continue
        if r["status"] != "ok":
            out.append(f"| {a} | {s} | — | — | — | FAIL | | | | |")
            continue
        t = r["terms"]
        out.append(
            f"| {a} | {s} | {t['compute_s']:.3f} | {t['memory_s']:.3f} "
            f"| {t['collective_s']:.3f} | {r['dominant'][:-2]} "
            f"| {r['model_flops']:.2e} | {r['useful_flops_ratio']:.2f} "
            f"| {r.get('ideal_s', 0):.3f} "
            f"| **{r['roofline_fraction']:.3f}** |")
    return "\n".join(out)


def summary(rows) -> str:
    oks = [r for r in rows.values() if r["status"] == "ok"]
    fails = [r for r in rows.values() if r["status"] == "fail"]
    skips = [r for r in rows.values() if r["status"] == "skip"]
    lines = [f"- cells: {len(rows)} total — {len(oks)} compiled, "
             f"{len(skips)} skipped (per brief), {len(fails)} failed"]
    if oks:
        worst = min(oks, key=lambda r: r["roofline_fraction"])
        best = max(oks, key=lambda r: r["roofline_fraction"])
        collb = [r for r in oks if r["dominant"] == "collective_s"]
        lines.append(f"- roofline fraction range: "
                     f"{worst['roofline_fraction']:.3f} "
                     f"({worst['arch']}/{worst['shape']}/{worst['mesh']}) "
                     f"to {best['roofline_fraction']:.3f} "
                     f"({best['arch']}/{best['shape']}/{best['mesh']})")
        lines.append("- collective-bound cells: "
                     + ", ".join(f"{r['arch']}/{r['shape']}/{r['mesh']}"
                                 for r in collb[:8]))
    return "\n".join(lines)


def metrics_tables(path) -> str:
    """Markdown rendering of an ``obs.MetricsRegistry`` JSONL stream."""
    events = [json.loads(line) for line in open(path) if line.strip()]
    steps = [e for e in events if e.get("event") == "train_step"]
    others = [e for e in events
              if e.get("event") not in ("train_step", "summary")]
    summaries = [e for e in events if e.get("event") == "summary"]
    out = [f"## Telemetry ({os.path.basename(path)})", ""]
    if steps:
        out += ["| step | loss | tok/s |", "|---|---|---|"]
        out += [f"| {e['step']} | {e['loss']} | {e['tok_per_s']} |"
                for e in steps]
        out.append("")
    if others:
        out += ["| t | event | fields |", "|---|---|---|"]
        for e in others:
            fields = ", ".join(
                f"{k}={v}" for k, v in e.items() if k not in ("event", "t"))
            out.append(f"| {e['t']:.3f} | {e['event']} | {fields} |")
        out.append("")
    if summaries:
        snap = summaries[-1]
        out += ["### Final summary", "",
                "| metric | value |", "|---|---|"]
        for k, v in sorted(snap.get("counters", {}).items()):
            out.append(f"| {k} | {v:g} |")
        for k, v in sorted(snap.get("gauges", {}).items()):
            out.append(f"| {k} | {'-' if v is None else v} |")
        for k, h in sorted(snap.get("histograms", {}).items()):
            if h.get("count"):
                out.append(f"| {k} | n={h['count']} mean={h['mean']:.1f} "
                           f"p99={h['p99']:.1f} |")
    return "\n".join(out)


if __name__ == "__main__":
    if "--metrics" in sys.argv:
        print(metrics_tables(sys.argv[sys.argv.index("--metrics") + 1]))
        raise SystemExit(0)
    rows = load(sys.argv[1] if len(sys.argv) > 1 else DEFAULT)
    print("## Dry-run\n")
    print(summary(rows))
    print()
    print(dryrun_table(rows))
    print("\n## Roofline (single pod, 16x16 = 256 chips)\n")
    print(roofline_table(rows, "16x16"))
    print("\n## Roofline (multi-pod, 2x16x16 = 512 chips)\n")
    print(roofline_table(rows, "2x16x16"))
