"""Planner benchmark: partition quality and planning time vs layer count.

Rows:
  planner/partition/L<L>xS<S>   — DP planning time; derived column shows
                                  the DP vs uniform bottleneck ratio on a
                                  skewed synthetic profile (lower = DP
                                  finds a strictly better split).
  planner/plan/<arch>           — end-to-end ``plan()`` time (profile +
                                  partition + IR emission + staleness
                                  derivation) on real configs.
  planner/event_table/<spec>    — lowering one schedule round to the
                                  dense int32 EventTable the scan
                                  interpreter executes; derived shows
                                  rows / switch branches / buffer slots.
"""
from __future__ import annotations

import time


def _skewed(L: int):
    # middle third of the stack 8x heavier (MoE-ish hotspot)
    return [8.0 if L // 3 <= j < 2 * L // 3 else 1.0 for j in range(L)]


def main(fast: bool = True):
    from repro.planner import dp_split, plan, synthetic_profile, uniform
    from repro.planner.partition import bottleneck, partition_profile, \
        profile_bottleneck

    lines = []
    sizes = [(8, 4), (16, 4), (32, 4), (64, 8)] if fast else \
            [(8, 4), (16, 4), (32, 4), (64, 8), (128, 8), (256, 16)]
    for L, S in sizes:
        comp = _skewed(L)
        cut = [0.05] * L
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            part = dp_split(comp, cut, S)
        us = (time.perf_counter() - t0) / reps * 1e6
        dp_cost = bottleneck(comp, cut, part)
        u_cost = bottleneck(comp, cut, uniform(L, S))
        lines.append(f"planner/partition/L{L}xS{S},{us:.0f},"
                     f"dp_over_uniform={dp_cost / u_cost:.3f};"
                     f"sizes={'-'.join(map(str, part.sizes()))}")

    archs = ["granite-8b"] if fast else ["granite-8b", "deepseek-moe-16b",
                                         "rwkv6-7b"]
    from repro.configs import get_config, smoke_config
    for name in archs:
        cfg = smoke_config(get_config(name)).replace(n_layers=8)
        t0 = time.perf_counter()
        p = plan(cfg, n_stages=4, schedule="stream",
                 profile_method="analytic")
        us = (time.perf_counter() - t0) * 1e6
        lines.append(f"planner/plan/{name},{us:.0f},"
                     f"s_fwd={'-'.join(map(str, p.s_fwd))};"
                     f"ring={p.ring_slots}")

    specs = [("1f1b", 4, 32)] if fast else \
            [("1f1b", 4, 32), ("2bw", 4, 32), ("interleaved", 4, 32)]
    for sched, S, M in specs:
        p = plan(profile=synthetic_profile([1.0] * (2 * S)), n_stages=S,
                 schedule=sched, n_microbatches=M,
                 virtual_stages=2 if sched == "interleaved" else 1)
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            t = p.event_table()
        us = (time.perf_counter() - t0) / reps * 1e6
        lines.append(f"planner/event_table/{sched}_S{S}xM{M},{us:.0f},"
                     f"rows={t.rows.shape[0]};branches={len(t.branches)};"
                     f"slots={t.n_val_slots}+{t.n_cot_slots}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
