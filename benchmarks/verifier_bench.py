"""Verifier benchmark: static verification time per compiled plan.

The verifier (``planner/verify.py``) runs by default at IR-runtime
construction, so its cost rides on every ``make_ir_train_step`` call —
this row keeps it honest under the PR 5 regression gate.

Rows:
  verifier/plan/<spec>     — full ``verify_plan`` time (event table +
                             device streams, artifacts re-compiled per
                             call, i.e. the construction-time cost);
                             derived shows events checked and
                             violations found (must be 0).
  verifier/largest_grid    — the largest plan in the CI verify grid
                             (interleaved S=4, v=2: 256 events across
                             both artifacts).
"""
from __future__ import annotations

import time


def _plan(schedule: str, S: int, v: int = 1):
    from repro.planner import plan, synthetic_profile
    C = S * v
    return plan(profile=synthetic_profile([1.0] * (2 * C)), n_stages=S,
                schedule=schedule, virtual_stages=v,
                partitioner="uniform")


def _time_verify(p, reps: int):
    from repro.planner import verify as pv
    reports = pv.verify_plan(p)     # warm (emitter caches, imports)
    t0 = time.perf_counter()
    for _ in range(reps):
        reports = pv.verify_plan(p)
    us = (time.perf_counter() - t0) / reps * 1e6
    n_ev = sum(r.n_events for r in reports)
    n_bad = sum(len(r.violations) for r in reports)
    return us, n_ev, n_bad


def main(fast: bool = True):
    lines = []
    reps = 3 if fast else 10
    specs = [("1f1b", 2, 1), ("2bw", 4, 1)] if fast else \
            [("1f1b", 2, 1), ("1f1b", 4, 1), ("2bw", 4, 1),
             ("gpipe", 4, 1), ("interleaved", 2, 2)]
    for schedule, S, v in specs:
        p = _plan(schedule, S, v)
        us, n_ev, n_bad = _time_verify(p, reps)
        tag = f"{schedule}_S{S}" + (f"v{v}" if v > 1 else "")
        lines.append(f"verifier/plan/{tag},{us:.0f},"
                     f"events={n_ev};violations={n_bad}")
    # the largest cell of the CI verify grid
    p = _plan("interleaved", 4, 2)
    us, n_ev, n_bad = _time_verify(p, reps)
    lines.append(f"verifier/largest_grid,{us:.0f},"
                 f"events={n_ev};violations={n_bad}")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
