"""Benchmark orchestrator: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="long training runs for convergence/rmse")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    from benchmarks import (breakdown, comm_time, comm_volume, convergence,
                            kernel_bench, planner_bench, rmse, roofline,
                            throughput)
    benches = {
        "comm_volume": comm_volume.main,      # Fig. 3
        "comm_time": comm_time.main,          # Fig. 4
        "throughput": throughput.main,        # Fig. 9
        "breakdown": breakdown.main,          # Fig. 10
        "rmse": rmse.main,                    # Fig. 8
        "convergence": convergence.main,      # Fig. 11 / Table 1
        "kernels": kernel_bench.main,         # Pallas kernels
        "roofline": roofline.main,            # EXPERIMENTS.md §Roofline
        "planner": planner_bench.main,        # EXPERIMENTS.md §Planner
    }
    picked = (args.only.split(",") if args.only else list(benches))
    print("name,us_per_call,derived")
    failures = 0
    for name in picked:
        try:
            for line in benches[name](fast=not args.full):
                print(line)
        except Exception:
            failures += 1
            print(f"{name}/ERROR,0,exception")
            traceback.print_exc(file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
