"""Benchmark orchestrator: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; ``--json-out PATH`` also
writes a machine-readable ``{name: us_per_call}`` dump — the format the
CI bench gate (``benchmarks/compare.py``) consumes and the committed
``benchmarks/baseline.json`` was recorded in.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="long training runs for convergence/rmse")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names")
    ap.add_argument("--json-out", default="", dest="json_out",
                    help="write {name: us_per_call} JSON to this path")
    args = ap.parse_args()

    from benchmarks import (breakdown, comm_time, comm_volume, convergence,
                            ir_compile, kernel_bench, planner_bench, rmse,
                            roofline, serve_bench, throughput,
                            trace_overhead, verifier_bench)
    benches = {
        "comm_volume": comm_volume.main,      # Fig. 3
        "comm_time": comm_time.main,          # Fig. 4
        "throughput": throughput.main,        # Fig. 9
        "breakdown": breakdown.main,          # Fig. 10
        "rmse": rmse.main,                    # Fig. 8
        "convergence": convergence.main,      # Fig. 11 / Table 1
        "kernels": kernel_bench.main,         # Pallas kernels
        "roofline": roofline.main,            # EXPERIMENTS.md §Roofline
        "planner": planner_bench.main,        # EXPERIMENTS.md §Planner
        "ir_compile": ir_compile.main,        # EXPERIMENTS.md §IR backends
        "trace_overhead": trace_overhead.main,  # docs/OBSERVABILITY.md
        "verifier": verifier_bench.main,      # planner/verify.py gate
        "serve": serve_bench.main,            # docs/SERVING.md
    }
    picked = (args.only.split(",") if args.only else list(benches))
    print("name,us_per_call,derived")
    results = {}
    failures = 0
    for name in picked:
        try:
            for line in benches[name](fast=not args.full):
                print(line)
                parts = line.split(",", 2)
                if len(parts) >= 2:
                    results[parts[0]] = float(parts[1])
        except Exception:
            failures += 1
            print(f"{name}/ERROR,0,exception")
            traceback.print_exc(file=sys.stderr)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
