"""Fig. 4 reproduction: % of training time spent on inter-GPU
communication under data parallelism (4 GPUs, PCIe)."""
from __future__ import annotations

from benchmarks._timeline import dp_step_time, lm_models, paper_models


def main(fast: bool = True):
    lines = []
    pcts = []
    for m in paper_models() + lm_models():
        t = dp_step_time(m, 4)
        pct = 100.0 * (t["p2p"] + t["p2p_idle"]) / t["step"]
        pcts.append(pct)
        lines.append(f"comm_time/{m.name},{t['step']*1e6:.0f},"
                     f"comm_pct={pct:.1f}")
    lines.append(f"comm_time/mean,0,comm_pct={sum(pcts)/len(pcts):.1f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
