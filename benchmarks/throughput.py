"""Fig. 9 reproduction: throughput of Data-P vs pipelined Model-P at 2 and
4 GPUs, normalized to single-GPU."""
from __future__ import annotations

from benchmarks._timeline import paper_models, throughput


def main(fast: bool = True):
    lines = []
    fcn_speedups = []
    all_speedups = []
    for m in paper_models():
        base = throughput(m, "single", 1)
        for n in (2, 4):
            dp = throughput(m, "dp", n) / base
            mp = throughput(m, "pipe", n) / base
            lines.append(f"throughput/{m.name}/gpus{n},0,"
                         f"dp_x={dp:.2f};mp_x={mp:.2f}")
            if n == 4:
                all_speedups.append(mp / dp)
                if m.name in ("snn", "transformer", "residual_lstm"):
                    fcn_speedups.append(mp / dp)
    import numpy as np
    lines.append(f"throughput/mp_over_dp_4gpu_max,0,"
                 f"{max(all_speedups):.2f}")
    lines.append(f"throughput/mp_over_dp_4gpu_fcn_rnn_mean,0,"
                 f"{float(np.mean(fcn_speedups)):.2f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
