"""Tracer overhead: traced vs untraced IR step wall time.

The ``--trace`` instrumentation (``repro.obs.PipelineTracer``) adds one
ordered host callback per compute event inside the jitted round body.
This benchmark bounds its cost on the step path:

Rows:
  trace/step_off — steady step wall time, tracer off (the PR-guarantee
                   path: byte-identical program to an untraced build);
  trace/step_on  — same plan/model with the tracer attached; derived
                   column reports the relative overhead.

Expected shape: ``step_off`` matches the plain ``ir/scan`` step cost;
``step_on`` pays one io_callback round-trip per event (~10s of us each
on CPU), small relative to real layer compute and zero when ``--trace``
is not passed.
"""
from __future__ import annotations

import dataclasses
import time


def _steady_us(fn, state, batch, reps: int = 5) -> float:
    import jax

    jax.block_until_ready(fn(state, batch))      # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(state, batch)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def main(fast: bool = True):
    import jax

    from repro.configs import get_config, smoke_config
    from repro.core import pipeline_stream
    from repro.models import Model
    from repro.obs import PipelineTracer
    from repro.planner import plan, synthetic_profile

    cfg = smoke_config(get_config("granite-8b"))
    cfg = cfg.replace(
        n_layers=4,
        mesh_plan=dataclasses.replace(cfg.mesh_plan, pipe=2),
        param_dtype="float32", compute_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    M = 4 if fast else 16
    p = plan(profile=synthetic_profile([1.0] * cfg.n_layers),
             n_stages=2, schedule="1f1b", n_microbatches=M)
    k = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(k, (M, 16), 0, cfg.vocab_size),
        "targets": jax.random.randint(k, (M, 16), 0, cfg.vocab_size),
    }

    # no donation here (unlike the train driver): state is reused across
    # reps so the loop times the step alone, not state reconstruction
    def fresh_state():
        copies = jax.tree.map(lambda x: x.copy(), params)
        return pipeline_stream.make_ir_state(model, copies, None, plan=p)

    step_off = jax.jit(pipeline_stream.make_ir_train_step(
        model, plan=p, mode="spectrain", lr=0.05, backend="scan"))
    us_off = _steady_us(step_off, fresh_state(), batch)

    tracer = PipelineTracer(p)
    step_on = tracer.wrap_step(jax.jit(pipeline_stream.make_ir_train_step(
        model, plan=p, mode="spectrain", lr=0.05, backend="scan",
        tracer=tracer)))
    us_on = _steady_us(step_on, fresh_state(), batch)

    pct = (us_on / us_off - 1.0) * 100.0
    return [
        f"trace/step_off,{us_off:.0f},M={M}",
        f"trace/step_on,{us_on:.0f},overhead_pct={pct:.1f};M={M};"
        f"rounds={len(tracer.rounds)}",
    ]


if __name__ == "__main__":
    print("\n".join(main()))
