"""Fig. 10 reproduction: execution-time breakdown (compute / P2P /
P2P-idle / imbalance-idle) of Data-P vs Model-P, normalized to Data-P."""
from __future__ import annotations

from benchmarks._timeline import (dp_step_time, paper_models,
                                  pipeline_step_time)


def main(fast: bool = True):
    lines = []
    for m in paper_models():
        dp = dp_step_time(m, 4)
        mp = pipeline_step_time(m, 4)
        norm = dp["step"]
        for mode, t in (("dp", dp), ("mp", mp)):
            parts = ";".join(
                f"{k}={t[k]/norm:.3f}"
                for k in ("compute", "p2p", "p2p_idle", "imbalance_idle"))
            lines.append(f"breakdown/{m.name}/{mode},"
                         f"{t['step']*1e6:.0f},{parts}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
