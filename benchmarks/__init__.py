import os
import sys

# allow `python -m benchmarks.run` from the repo root without install
_src = os.path.join(os.path.dirname(__file__), "..", "src")
if _src not in sys.path:
    sys.path.insert(0, _src)
