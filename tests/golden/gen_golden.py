"""Generate the golden streaming-runtime trajectories for
``tests/test_schedule_equivalence.py``.

The fixture was produced by the PRE-refactor stacked ``[S, Lps, ...]``
runtime (commit 890b850) and pins its exact uniform-plan trajectories:
per-tick losses plus SHA-256 digests of every final parameter leaf, with
stage layers flattened to ``[L, ...]`` (a layout both the stacked and
the ragged runtime reduce to).  Digest equality == bitwise equality, so
the ragged (per-stage param tree) runtime must reproduce these
bit-for-bit under a uniform partition — rerunning this script on a
post-refactor tree only confirms self-consistency, it does not re-derive
the pre-refactor reference.

Usage:  PYTHONPATH=src python tests/golden/gen_golden.py
"""
import hashlib
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from conftest import lm_batch, tiny_cfg  # noqa: E402
from repro.core import pipeline_stream  # noqa: E402
from repro.models import Model  # noqa: E402

CASES = [
    # (mode, pipe, n_layers, lr, ticks)
    ("spectrain", 2, 4, 0.05, 8),
    ("vanilla", 2, 4, 0.05, 8),
    ("pipedream", 2, 4, 0.05, 8),
    ("spectrain", 3, 6, 0.05, 10),
    ("spectrain", 4, 4, 0.05, 12),
]


def final_digests(params):
    """{leaf path: sha256 hexdigest} of final params, stage layers
    flattened to [L, ...] — a layout both the stacked and the ragged
    runtime can be reduced to."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            params["outer"])[0]:
        key = "outer/" + "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                                  for p in path)
        out[key] = hashlib.sha256(np.asarray(leaf).tobytes()).hexdigest()
    stages = params["stages"]
    if isinstance(stages, (tuple, list)):   # ragged: concat per-stage trees
        flat = jax.tree.map(lambda *xs: np.concatenate(
            [np.asarray(x) for x in xs], 0), *stages)
    else:                                    # stacked: merge [S, Lps] -> [L]
        flat = jax.tree.map(
            lambda a: np.asarray(a).reshape((-1,) + a.shape[2:]), stages)
    for path, leaf in jax.tree_util.tree_flatten_with_path(flat)[0]:
        key = "stages/" + "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = hashlib.sha256(np.asarray(leaf).tobytes()).hexdigest()
    return out


def run_case(mode, pipe, n_layers, lr, ticks):
    cfg = tiny_cfg("granite-8b", n_layers=n_layers, pipe=pipe)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=4, seq=16)
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       batch)
    state = pipeline_stream.make_state(m, params, sds, mode=mode)
    step = jax.jit(pipeline_stream.make_train_step(m, mode=mode, lr=lr))
    losses, valids = [], []
    for _ in range(ticks):
        state, met = step(state, batch)
        losses.append(float(met["loss"]))
        valids.append(float(met["loss_valid"]))
    rec = {"losses": np.asarray(losses, np.float64),
           "valids": np.asarray(valids, np.float64)}
    for k, v in final_digests(state["params"]).items():
        rec["final/" + k] = np.asarray(v)
    return rec


def main():
    out = {}
    for mode, pipe, n_layers, lr, ticks in CASES:
        name = f"{mode}_p{pipe}_L{n_layers}"
        rec = run_case(mode, pipe, n_layers, lr, ticks)
        for k, v in rec.items():
            out[f"{name}/{k}"] = v
        print(f"{name}: losses={rec['losses'][-3:]}")
    path = os.path.join(os.path.dirname(__file__),
                        "stream_uniform_golden.npz")
    np.savez_compressed(path, **out)
    print(f"wrote {path} ({os.path.getsize(path)} bytes, {len(out)} keys)")


if __name__ == "__main__":
    main()
