"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype
sweeps per the deliverable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def tr(t):
    return jnp.swapaxes(t, 1, 2)


# ---------------------------------------------------------------------------
# flash attention


FLASH_CASES = [
    # b, H, KV, sq, sk, d, causal, dtype
    (2, 4, 4, 256, 256, 64, True, jnp.float32),
    (1, 8, 2, 256, 256, 128, True, jnp.float32),
    (2, 4, 1, 128, 256, 64, False, jnp.float32),
    (1, 4, 4, 128, 128, 64, True, jnp.bfloat16),
    (1, 2, 2, 512, 512, 32, True, jnp.float32),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_fwd(case):
    b, H, KV, sq, sk, d, causal, dt = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, H, d)).astype(dt)
    k = jax.random.normal(ks[1], (b, sk, KV, d)).astype(dt)
    v = jax.random.normal(ks[2], (b, sk, KV, d)).astype(dt)
    o = ops.flash_attention(q, k, v, causal, 128, 128, True)
    o_ref = tr(ref.attention_ref(tr(q), tr(k), tr(v), causal=causal))
    tol = 2e-5 if dt == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol,
                               rtol=tol)


def test_flash_bwd():
    b, H, KV, s, d = 1, 4, 2, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, H, d))
    k = jax.random.normal(ks[1], (b, s, KV, d))
    v = jax.random.normal(ks[2], (b, s, KV, d))
    f1 = lambda *a: jnp.sum(jnp.sin(ops.flash_attention(
        *a, True, 64, 64, True)))
    f2 = lambda q, k, v: jnp.sum(jnp.sin(tr(ref.attention_ref(
        tr(q), tr(k), tr(v), causal=True))))
    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b_, nm in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=2e-5, rtol=1e-3, err_msg=nm)


def test_flash_block_shape_invariance():
    b, H, s, d = 1, 2, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, s, H, d))
    k = jax.random.normal(ks[1], (b, s, H, d))
    v = jax.random.normal(ks[2], (b, s, H, d))
    o1 = ops.flash_attention(q, k, v, True, 64, 64, True)
    o2 = ops.flash_attention(q, k, v, True, 128, 32, True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# rwkv6


RWKV_CASES = [
    (2, 3, 128, 32, 16),
    (1, 2, 64, 64, 32),
    (1, 1, 96, 16, 32),
]


@pytest.mark.parametrize("case", RWKV_CASES)
def test_rwkv6_scan(case):
    b, h, s, hd, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    r = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd)) * 0.3
    v = jax.random.normal(ks[2], (b, s, h, hd))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, hd)) * 0.5
                         - 0.5))
    u = jax.random.normal(ks[4], (h, hd)) * 0.3
    S0 = jax.random.normal(ks[5], (b, h, hd, hd)) * 0.1
    y, sT = ops.rwkv6_scan(r, k, v, w, u, S0, chunk=chunk, interpret=True)
    y_ref, sT_ref = ref.rwkv6_ref(tr(r), tr(k), tr(v), tr(w), u, S0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(tr(y_ref)),
                               atol=5e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref),
                               atol=5e-3, rtol=1e-3)


def test_rwkv6_state_continuity():
    """Running two half-sequences with carried state == one full run."""
    b, h, s, hd = 1, 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    r = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd)) * 0.3
    v = jax.random.normal(ks[2], (b, s, h, hd))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, hd)) * 0.3))
    u = jax.random.normal(ks[4], (h, hd)) * 0.3
    S0 = jnp.zeros((b, h, hd, hd))
    y_full, sT_full = ops.rwkv6_scan(r, k, v, w, u, S0, chunk=16,
                                     interpret=True)
    half = s // 2
    y1, s1 = ops.rwkv6_scan(r[:, :half], k[:, :half], v[:, :half],
                            w[:, :half], u, S0, chunk=16, interpret=True)
    y2, s2 = ops.rwkv6_scan(r[:, half:], k[:, half:], v[:, half:],
                            w[:, half:], u, s1, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=5e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sT_full),
                               atol=5e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# mamba2


MAMBA_CASES = [
    (2, 4, 128, 16, 8, 2, 16),
    (1, 2, 64, 32, 16, 1, 32),
]


@pytest.mark.parametrize("case", MAMBA_CASES)
def test_mamba2_scan(case):
    b, h, s, p, n, g, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    decay = jnp.exp(-dt * jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3))
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    S0 = jnp.zeros((b, h, p, n))
    y, sT = ops.mamba2_scan(x, dt, decay, B, C, S0, chunk=chunk,
                            interpret=True)
    rep = h // g
    Bh, Ch = (jnp.repeat(t, rep, axis=2) for t in (B, C))
    y_ref, sT_ref = ref.mamba2_ref(tr(x), jnp.moveaxis(dt, 1, 2),
                                   jnp.moveaxis(decay, 1, 2),
                                   tr(Bh), tr(Ch), S0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(tr(y_ref)),
                               atol=5e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref),
                               atol=5e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# fused SpecTrain update


@pytest.mark.parametrize("shape,dt", [((1000, 37), jnp.float32),
                                      ((8192,), jnp.float32),
                                      ((63,), jnp.float32),
                                      ((512, 16), jnp.bfloat16)])
def test_fused_update(shape, dt):
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    w = jax.random.normal(ks[0], shape).astype(dt)
    v = jax.random.normal(ks[1], shape)
    g = jax.random.normal(ks[2], shape).astype(dt)
    got = ops.fused_update(w, v, g, lr=0.1, gamma=0.9, s=3.0, block=4096,
                           interpret=True)
    exp = ref.fused_update_ref(w, v, g, lr=0.1, gamma=0.9, s=3.0)
    for a, b, nm in zip(got, exp, ("w", "v", "what")):
        tol = 1e-6 if dt == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=tol,
                                   rtol=tol, err_msg=nm)


def test_fused_update_matches_optimizer():
    """The kernel must agree with optim.sgd + spectrain.predict_weights."""
    from repro.core import spectrain as st
    from repro.optim import sgd
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    w = jax.random.normal(ks[0], (256,))
    v = jax.random.normal(ks[1], (256,))
    g = jax.random.normal(ks[2], (256,))
    w2, v2, wh = ops.fused_update(w, v, g, lr=0.05, gamma=0.9, s=4.0,
                                  interpret=True)
    p2, m2 = sgd.update(w, sgd.MomentumState(v), g, lr=0.05, gamma=0.9)
    pred = st.predict_weights(p2, m2.v, 0.05, 4.0)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(p2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(m2.v), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(wh), np.asarray(pred), rtol=1e-5,
                               atol=1e-6)
