"""Checkpointing: roundtrip, atomicity, GC, exact resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import lm_batch, tiny_cfg
from repro.core import pipeline_stream
from repro.models import Model
from repro.runtime import checkpoint as ckpt


@pytest.fixture()
def setup(tmp_path):
    cfg = tiny_cfg("granite-8b", n_layers=2, pipe=2)
    m = Model(cfg)
    batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=4, seq=8)
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       batch)
    state = pipeline_stream.init_state(m, jax.random.PRNGKey(0), sds)
    step = jax.jit(pipeline_stream.make_train_step(m, mode="spectrain",
                                                   lr=0.02))
    return str(tmp_path), m, state, step, batch


class TestRoundtrip:
    def test_exact_roundtrip(self, setup):
        d, m, state, step, batch = setup
        ckpt.save(d, state, 7)
        got, s = ckpt.restore(d, state)
        assert s == 7
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_and_gc(self, setup):
        d, m, state, step, batch = setup
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, state, s, keep=2)
        assert ckpt.all_steps(d) == [4, 5]
        assert ckpt.latest_step(d) == 5

    def test_atomic_ignores_partial(self, setup, tmp_path):
        d, m, state, step, batch = setup
        ckpt.save(d, state, 1)
        # simulate a crashed write
        os.makedirs(os.path.join(d, "step_00000002.tmp"), exist_ok=True)
        # no manifest
        os.makedirs(os.path.join(d, "step_00000003"), exist_ok=True)
        assert ckpt.latest_step(d) == 1

    def test_background_save(self, setup):
        d, m, state, step, batch = setup
        t = ckpt.save(d, state, 9, background=True)
        t.join(timeout=30)
        assert ckpt.latest_step(d) == 9


class TestStackedMigration:
    """Pre-ragged stacked ``[S, Lps, ...]`` checkpoints restore
    bit-exactly onto the ragged canonical template via the shim."""

    def test_stacked_checkpoint_loads_bit_exact(self, setup, tmp_path):
        d, m, state, step, batch = setup
        # take a few real steps so momentum / w_stash are non-trivial
        state2 = pipeline_stream.make_state(
            m, jax.tree.map(jnp.asarray, state["params"]),
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         batch), mode="pipedream")
        pd_step = jax.jit(pipeline_stream.make_train_step(
            m, mode="pipedream", lr=0.02))
        for _ in range(4):
            state2, _ = pd_step(state2, batch)

        # re-spell the state the way the pre-refactor runtime stored it:
        # stacked stage trees and a [S, R, ...] weight ring
        old = dict(state2)
        old["params"] = {
            "outer": state2["params"]["outer"],
            "stages": m.stack_stage_params(state2["params"]["stages"])}
        old["momentum"] = {
            "outer": state2["momentum"]["outer"],
            "stages": m.stack_stage_params(state2["momentum"]["stages"])}
        old["w_stash"] = jax.tree.map(lambda *xs: jnp.stack(xs, 0),
                                      *state2["w_stash"])
        ckpt.save(str(tmp_path), old, 5)

        got, s = ckpt.restore(str(tmp_path), state2)
        assert s == 5
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_migrated_state_resumes_identically(self, setup, tmp_path):
        """Training from a migrated stacked checkpoint == training from
        the ragged original, bitwise."""
        d, m, state, step, batch = setup
        for _ in range(3):
            state, _ = step(state, batch)
        old = dict(state)
        old["params"] = {
            "outer": state["params"]["outer"],
            "stages": m.stack_stage_params(state["params"]["stages"])}
        old["momentum"] = {
            "outer": state["momentum"]["outer"],
            "stages": m.stack_stage_params(state["momentum"]["stages"])}
        ckpt.save(str(tmp_path), old, 2)
        restored, _ = ckpt.restore(str(tmp_path), state)
        s_a, s_b = state, restored
        for _ in range(3):
            s_a, _ = step(s_a, batch)
            s_b, _ = step(s_b, batch)
        for a, b in zip(jax.tree.leaves(s_a["params"]),
                        jax.tree.leaves(s_b["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_missing_leaf_raises_key_error(self, setup, tmp_path):
        d, m, state, step, batch = setup
        ckpt.save(str(tmp_path), {"params": state["params"]}, 1)
        with pytest.raises(KeyError, match="momentum"):
            ckpt.restore(str(tmp_path), {"params": state["params"],
                                         "momentum": state["momentum"]})


class TestExactResume:
    def test_resume_reproduces_trajectory(self, setup):
        """train 6 == train 3 + save + restore + train 3, bitwise."""
        d, m, state, step, batch = setup
        s_a = state
        for i in range(6):
            s_a, _ = step(s_a, batch)

        s_b = state
        for i in range(3):
            s_b, _ = step(s_b, batch)
        ckpt.save(d, s_b, 2)
        s_c, _ = ckpt.restore(d, s_b)
        for i in range(3):
            s_c, _ = step(s_c, batch)

        for a, b in zip(jax.tree.leaves(s_a["params"]),
                        jax.tree.leaves(s_c["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
