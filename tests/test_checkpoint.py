"""Checkpointing: roundtrip, atomicity, GC, exact resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import lm_batch, tiny_cfg
from repro.core import pipeline_stream
from repro.models import Model
from repro.runtime import checkpoint as ckpt


@pytest.fixture()
def setup(tmp_path):
    cfg = tiny_cfg("granite-8b", n_layers=2, pipe=2)
    m = Model(cfg)
    batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=4, seq=8)
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       batch)
    state = pipeline_stream.init_state(m, jax.random.PRNGKey(0), sds)
    step = jax.jit(pipeline_stream.make_train_step(m, mode="spectrain",
                                                   lr=0.02))
    return str(tmp_path), m, state, step, batch


class TestRoundtrip:
    def test_exact_roundtrip(self, setup):
        d, m, state, step, batch = setup
        ckpt.save(d, state, 7)
        got, s = ckpt.restore(d, state)
        assert s == 7
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_and_gc(self, setup):
        d, m, state, step, batch = setup
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, state, s, keep=2)
        assert ckpt.all_steps(d) == [4, 5]
        assert ckpt.latest_step(d) == 5

    def test_atomic_ignores_partial(self, setup, tmp_path):
        d, m, state, step, batch = setup
        ckpt.save(d, state, 1)
        # simulate a crashed write
        os.makedirs(os.path.join(d, "step_00000002.tmp"), exist_ok=True)
        os.makedirs(os.path.join(d, "step_00000003"), exist_ok=True)  # no manifest
        assert ckpt.latest_step(d) == 1

    def test_background_save(self, setup):
        d, m, state, step, batch = setup
        t = ckpt.save(d, state, 9, background=True)
        t.join(timeout=30)
        assert ckpt.latest_step(d) == 9


class TestExactResume:
    def test_resume_reproduces_trajectory(self, setup):
        """train 6 == train 3 + save + restore + train 3, bitwise."""
        d, m, state, step, batch = setup
        s_a = state
        for i in range(6):
            s_a, _ = step(s_a, batch)

        s_b = state
        for i in range(3):
            s_b, _ = step(s_b, batch)
        ckpt.save(d, s_b, 2)
        s_c, _ = ckpt.restore(d, s_b)
        for i in range(3):
            s_c, _ = step(s_c, batch)

        for a, b in zip(jax.tree.leaves(s_a["params"]),
                        jax.tree.leaves(s_c["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
