"""Serving: schedule-IR artifacts, the continuous-batching scheduler,
and end-to-end engine determinism.

The load-bearing claims:

  * the serve table/streams verify clean and the serve mutation
    harness catches every seeded corruption (the verifier is armed);
  * the scheduler's event log satisfies the request-trace invariants
    (page lifetime == request lifetime, one decode per live request
    per round, no slot sharing) on real engine runs;
  * same seed + arrival trace => bitwise-identical tokens across the
    scan and mpmd backends, across the whole-model SimpleEngine, and
    across a mid-run elastic restate.
"""
import jax
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.models import Model
from repro.planner import serve_plan
from repro.planner import verify as pv
from repro.serve import (ContinuousBatcher, Request, ServeEngine,
                         SimpleEngine, admissible, poisson_trace)

PLAN_KW = dict(n_slots=4, max_prefill=2, prompt_budget=8, page_seq=32,
               n_layers=4)


def _splan(n_stages=2, **kw):
    merged = dict(PLAN_KW)
    merged.update(kw)
    return serve_plan(None, n_stages=n_stages, **merged)


@pytest.fixture(scope="module")
def gmodel():
    cfg = tiny_cfg("granite-8b", n_layers=4, pipe=2)
    m = Model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def trace8(gmodel):
    cfg = gmodel[0].cfg
    return poisson_trace(8, rate=0.7, seed=3, prompt_lens=(1, 8),
                         gen_lens=(1, 6), vocab=cfg.vocab_size)


# ===========================================================================
# IR artifacts
# ===========================================================================


class TestServeIR:
    @pytest.mark.parametrize("S,F", [(2, 1), (2, 2), (4, 3), (3, 0)])
    def test_artifacts_verify_clean(self, S, F):
        p = _splan(n_stages=S, max_prefill=F, n_layers=2 * S)
        p.verify(device_streams=True)

    @pytest.mark.parametrize("S,F", [(2, 2), (4, 3)])
    def test_mutation_harness_all_caught(self, S, F):
        p = _splan(n_stages=S, max_prefill=F, n_layers=2 * S)
        n, failures = pv.serve_self_test(p)
        assert n >= 8 and not failures, failures

    def test_streams_need_stage_fold(self):
        # the device lowering folds one chunk per device
        p = _splan(n_stages=2)
        assert p.serve_streams().n_devices == 2


# ===========================================================================
# trace + scheduler
# ===========================================================================


class TestTrace:
    def test_deterministic_and_bounded(self):
        a = poisson_trace(32, rate=1.5, seed=7)
        b = poisson_trace(32, rate=1.5, seed=7)
        assert a == b
        assert a != poisson_trace(32, rate=1.5, seed=8)
        assert all(2 <= len(q.prompt) <= 12 and 1 <= q.gen_len <= 8
                   for q in a)
        arr = [q.arrival for q in a]
        assert arr == sorted(arr)

    def test_rejects_bad_params(self):
        with pytest.raises(ValueError):
            poisson_trace(0)
        with pytest.raises(ValueError):
            poisson_trace(4, rate=0.0)


class TestScheduler:
    def test_admissible(self):
        p = _splan()
        ok = Request(0, 0, (1, 2, 3), 2)
        assert admissible(ok, p)
        assert not admissible(Request(1, 0, (), 2), p)           # empty
        assert not admissible(Request(2, 0, (1,) * 9, 2), p)     # > budget
        assert not admissible(Request(3, 0, (1, 2), 0), p)       # no gen
        assert not admissible(Request(4, 0, (1,) * 8, 32), p)    # > page

    def test_lifecycle_and_trace_invariants(self):
        p = _splan(n_slots=2, max_prefill=1)
        reqs = [Request(i, i // 2, (1, 2), 2) for i in range(5)]
        reqs.append(Request(5, 0, (1,) * 9, 2))    # inadmissible
        sched = ContinuousBatcher(p, reqs)
        r = 0
        while sched.active:
            batch = sched.poll(r)
            n = sched.n_round_tokens()
            if not n:
                r = max(r + 1, sched.next_arrival() or r + 1)
                continue
            sched.commit(r, np.arange(p.n_slots, dtype=np.int32),
                         np.zeros((max(p.max_prefill, 1),), np.int32))
            r += 1
        assert sched.results[5] == ()               # rejected
        assert all(len(sched.results[i]) == 2 for i in range(5))
        rep = pv.verify_request_trace(sched.events, n_slots=p.n_slots,
                                      n_pages=p.n_pages,
                                      n_stages=p.n_stages)
        assert rep.ok, rep.violations

    def test_head_of_line_blocking(self):
        p = _splan(n_slots=1, max_prefill=1)
        reqs = [Request(0, 0, (1, 2), 3), Request(1, 0, (3,), 1)]
        sched = ContinuousBatcher(p, reqs)
        sched.poll(0)
        # slot is full: request 1 must wait even though it would fit
        assert sched.live and sched.queue
        sched.commit(0, np.zeros((1,), np.int32),
                     np.zeros((1,), np.int32))
        assert 1 not in sched.results or sched.results[1] != ()


# ===========================================================================
# engines: cross-backend and cross-engine determinism
# ===========================================================================


class TestServeEngines:
    def test_scan_matches_simple_and_trace_verifies(self, gmodel,
                                                    trace8):
        m, params = gmodel
        p = _splan()
        eng = ServeEngine(m, params, p, backend="scan")
        got = eng.run(trace8)
        ref = SimpleEngine(m, params, p).run(trace8)
        assert got == ref
        rep = pv.verify_request_trace(eng.last_events,
                                      n_slots=p.n_slots,
                                      n_pages=p.n_pages,
                                      n_stages=p.n_stages)
        assert rep.ok, rep.violations

    def test_same_seed_same_tokens(self, gmodel, trace8):
        m, params = gmodel
        a = ServeEngine(m, params, _splan(), backend="scan").run(trace8)
        b = ServeEngine(m, params, _splan(), backend="scan").run(trace8)
        assert a == b

    def test_stage_split_does_not_change_tokens(self, gmodel, trace8):
        m, params = gmodel
        a = ServeEngine(m, params, _splan(2), backend="scan").run(trace8)
        b = ServeEngine(m, params, _splan(4), backend="scan").run(trace8)
        assert a == b

    def test_rwkv6_scan_matches_simple(self):
        cfg = tiny_cfg("rwkv6-7b", n_layers=4, pipe=2)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(1))
        reqs = poisson_trace(6, rate=0.8, seed=5, prompt_lens=(1, 6),
                             gen_lens=(1, 4), vocab=cfg.vocab_size)
        p = _splan()
        a = ServeEngine(m, params, p, backend="scan").run(reqs)
        b = SimpleEngine(m, params, p).run(reqs)
        assert a == b

    def test_restate_mid_run_is_bitwise(self, gmodel, trace8):
        m, params = gmodel
        base = ServeEngine(m, params, _splan(), backend="scan"
                           ).run(trace8)
        eng = ServeEngine(m, params, _splan(), backend="scan")
        early = [q for q in trace8 if q.arrival <= 2]
        late = [q for q in trace8 if q.arrival > 2]
        r1 = eng.run(early)
        eng.restate(_splan(4))
        r2 = eng.run(late)
        assert {**r1, **r2} == base

    def test_restate_refuses_geometry_change(self, gmodel):
        m, params = gmodel
        eng = ServeEngine(m, params, _splan(), backend="scan")
        with pytest.raises(ValueError, match="page_seq"):
            eng.restate(_splan(page_seq=64))

    def test_hybrid_is_gated_with_pointer(self):
        cfg = tiny_cfg("zamba2-1.2b", n_layers=4, pipe=2)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        with pytest.raises(NotImplementedError, match="SimpleEngine"):
            ServeEngine(m, params, _splan(), backend="scan")


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="mpmd serving needs >= 2 devices "
                           "(XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=2)")
class TestServeMpmd:
    def test_mpmd_bitwise_matches_scan(self, gmodel, trace8):
        m, params = gmodel
        a = ServeEngine(m, params, _splan(), backend="scan").run(trace8)
        b = ServeEngine(m, params, _splan(), backend="mpmd").run(trace8)
        assert a == b

    def test_mpmd_restate_mid_run_is_bitwise(self, gmodel, trace8):
        if jax.device_count() < 4:
            pytest.skip("restate to 4 stages needs 4 devices")
        m, params = gmodel
        base = ServeEngine(m, params, _splan(), backend="scan"
                           ).run(trace8)
        eng = ServeEngine(m, params, _splan(), backend="mpmd")
        early = [q for q in trace8 if q.arrival <= 2]
        late = [q for q in trace8 if q.arrival > 2]
        r1 = eng.run(early)
        eng.restate(_splan(4))
        r2 = eng.run(late)
        assert {**r1, **r2} == base
