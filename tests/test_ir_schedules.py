"""1F1B-family schedules in the IR and the IR-interpreter runtime.

Four layers of evidence, mirroring the PR-2 harness:

  * **Closed forms** — IR-derived staleness equals the
    ``core/spectrain.py`` closed forms: 0 everywhere for the flush
    schedules (1f1b / interleaved), a uniform 1 for PipeDream-2BW, for
    S ∈ {2, 3, 4, 8}.
  * **Timeline metrics** — bubble fraction, activation-stash depth and
    weight-stash depth derived from the IR match their textbook
    formulas ((S−1)/(M+S−1), S−k, double buffer = 2, ...).
  * **Execution** — 1f1b / interleaved / 2bw plans with DP-partitioned
    ragged (chunk-)stages run end-to-end through the IR interpreter in
    ``core/pipeline_stream.py`` and track the simulator's loss
    trajectory; flush schedules are mode-invariant (their staleness is
    0, so vanilla == pipedream == spectrain bit-for-bit).
  * **CLI** — ``--schedule 1f1b`` and ``--schedule interleaved
    --virtual-stages 2`` train through ``launch/train.py``.
"""
import numpy as np
import pytest

import jax

from conftest import lm_batch, tiny_cfg
from repro.core import pipeline_stream
from repro.core import spectrain as st
from repro.core.simulator import Simulator, staged_from_model
from repro.models import Model
from repro.planner import plan, synthetic_profile, uniform
from repro.planner import schedule_ir as ir
from repro.planner.api import check_against_closed_forms

NS = (2, 3, 4, 8)


# ===========================================================================
# closed forms
# ===========================================================================


class TestClosedForms:
    @pytest.mark.parametrize("n", NS)
    def test_1f1b_is_staleness_free(self, n):
        sched = ir.one_f_one_b(n)
        sched.validate()
        for k in range(n):
            for phase in ("forward", "backward"):
                assert sched.staleness(k, phase) == \
                    st.version_difference_1f1b(k, n, phase) == 0

    @pytest.mark.parametrize("n", NS)
    def test_2bw_staleness_is_uniform_one(self, n):
        sched = ir.pipedream_2bw(n)
        sched.validate()
        for k in range(n):
            for phase in ("forward", "backward"):
                assert sched.staleness(k, phase) == \
                    st.version_difference_2bw(k, n, phase) == 1

    @pytest.mark.parametrize("n", (2, 3, 4))
    @pytest.mark.parametrize("v", (2, 3))
    def test_interleaved_is_staleness_free(self, n, v):
        sched = ir.interleaved_1f1b(n, v=v)
        sched.validate()
        assert sched.n_stages == n * v and sched.n_devices == n
        for q in range(n * v):
            for phase in ("forward", "backward"):
                assert sched.staleness(q, phase) == 0

    @pytest.mark.parametrize("schedule,v", [("1f1b", 1), ("2bw", 1),
                                            ("interleaved", 2)])
    @pytest.mark.parametrize("n", NS)
    def test_plan_matches_closed_forms(self, schedule, v, n):
        p = plan(n_layers=2 * n * v, n_stages=n, schedule=schedule,
                 virtual_stages=v)
        check_against_closed_forms(p)
        assert p.n_chunks == n * v
        assert len(p.s_fwd) == len(p.bwd_lag) == p.n_chunks

    def test_2bw_warmup_group_reads_initial_weights(self):
        """Group 0 has no earlier version to pin — its derived staleness
        is 0 (the warm-up truncation), steady groups are 1."""
        sched = ir.pipedream_2bw(2, n_microbatches=2)
        assert sched.staleness(0, "forward", mb=0) == 0
        assert sched.staleness(0, "forward", mb=3) == 1


# ===========================================================================
# timeline metrics
# ===========================================================================


class TestTimelineMetrics:
    @pytest.mark.parametrize("n", NS)
    def test_1f1b_bubble_and_stash(self, n):
        sched = ir.one_f_one_b(n)
        M = sched.round_microbatches
        assert sched.bubble_fraction() == pytest.approx(
            (n - 1) / (M + n - 1))
        # 1F1B's reason to exist: stage k stashes S−k activations, not M
        assert [sched.peak_activation_stash(k) for k in range(n)] == \
            [n - k for k in range(n)]
        g = ir.gpipe(n, n_microbatches=M, n_rounds=2)
        assert [g.peak_activation_stash(k) for k in range(n)] == [M] * n

    @pytest.mark.parametrize("n,v", [(2, 2), (3, 2), (4, 2), (2, 3)])
    def test_interleaved_shrinks_bubble(self, n, v):
        M = 2 * n
        intl = ir.interleaved_1f1b(n, M, v=v)
        flat = ir.one_f_one_b(n, M)
        assert intl.bubble_fraction() == pytest.approx(
            (n - 1) / (M * v + n - 1))
        assert intl.bubble_fraction() < flat.bubble_fraction()

    @pytest.mark.parametrize("n", (2, 3, 4))
    def test_weight_stash_depth_derived(self, n):
        """The 2BW double buffer is a derived quantity, not an input."""
        assert all(ir.pipedream_2bw(n).weight_stash_depth(k) == 2
                   for k in range(n))
        assert all(ir.one_f_one_b(n).weight_stash_depth(k) == 1
                   for k in range(n))
        assert all(ir.interleaved_1f1b(n, v=2).weight_stash_depth(q) == 1
                   for q in range(2 * n))

    def test_2bw_rejects_group_smaller_than_depth(self):
        """m < S would need more than 2 weight buffers (the paper's
        m ≥ d constraint)."""
        with pytest.raises(ValueError, match="2 weight buffers"):
            ir.pipedream_2bw(4, n_microbatches=2)

    def test_interleaved_rejects_ragged_microbatch_groups(self):
        with pytest.raises(ValueError, match="n_microbatches"):
            ir.interleaved_1f1b(3, 4, v=2)

    def test_pinned_version_must_exist(self):
        bad = ir.Schedule("bad", 1, [
            ir.Event(ir.FWD, 0, stage=0, mb=0, wv=1),
            ir.Event(ir.BWD, 1, stage=0, mb=0),
            ir.Event(ir.UPDATE, 2, stages=(0,), mbs=(0,))])
        with pytest.raises(ValueError, match="pins"):
            bad.validate()

    def test_device_double_booking_detected(self):
        bad = ir.Schedule("bad", 2, [
            ir.Event(ir.FWD, 0, stage=0, mb=0),
            ir.Event(ir.FWD, 0, stage=1, mb=1)], n_devices=1)
        with pytest.raises(ValueError, match="double-booked"):
            bad.validate()

    def test_update_with_missing_bwd_detected(self):
        """An applied gradient with no backward is malformed, not
        merely incomplete — validate() must raise, not skip the
        minibatch."""
        bad = ir.Schedule("bad", 1, [
            ir.Event(ir.FWD, 0, stage=0, mb=0),
            ir.Event(ir.UPDATE, 1, stages=(0,), mbs=(0,))])
        with pytest.raises(ValueError, match=r"no bwd\(0,0\)"):
            bad.validate()

    def test_update_with_missing_fwd_detected(self):
        bad = ir.Schedule("bad", 1, [
            ir.Event(ir.BWD, 0, stage=0, mb=0),
            ir.Event(ir.UPDATE, 1, stages=(0,), mbs=(0,))])
        with pytest.raises(ValueError, match=r"no fwd\(0,0\)"):
            bad.validate()

    def test_out_of_order_fwd_chain_detected(self):
        bad = ir.Schedule("bad", 2, [
            ir.Event(ir.FWD, 0, stage=1, mb=0),
            ir.Event(ir.FWD, 1, stage=0, mb=0),
            ir.Event(ir.BWD, 2, stage=1, mb=0),
            ir.Event(ir.BWD, 3, stage=0, mb=0),
            ir.Event(ir.UPDATE, 4, stages=(0, 1), mbs=(0,))])
        with pytest.raises(ValueError,
                           match=r"fwd\(0,1\) before fwd\(0,0\)"):
            bad.validate()

    def test_out_of_order_bwd_chain_detected(self):
        bad = ir.Schedule("bad", 2, [
            ir.Event(ir.FWD, 0, stage=0, mb=0),
            ir.Event(ir.FWD, 1, stage=1, mb=0),
            ir.Event(ir.BWD, 2, stage=0, mb=0),
            ir.Event(ir.BWD, 3, stage=1, mb=0),
            ir.Event(ir.UPDATE, 4, stages=(0, 1), mbs=(0,))])
        with pytest.raises(ValueError,
                           match=r"bwd\(0,0\) before bwd\(0,1\)"):
            bad.validate()

    def test_bwd_before_fwd_detected(self):
        bad = ir.Schedule("bad", 1, [
            ir.Event(ir.BWD, 0, stage=0, mb=0),
            ir.Event(ir.FWD, 1, stage=0, mb=0),
            ir.Event(ir.UPDATE, 2, stages=(0,), mbs=(0,))])
        with pytest.raises(ValueError, match="before fwd"):
            bad.validate()

    def test_update_before_bwd_detected(self):
        bad = ir.Schedule("bad", 1, [
            ir.Event(ir.FWD, 0, stage=0, mb=0),
            ir.Event(ir.UPDATE, 1, stages=(0,), mbs=(0,)),
            ir.Event(ir.BWD, 2, stage=0, mb=0)])
        with pytest.raises(ValueError, match="update of 0 before"):
            bad.validate()


# ===========================================================================
# virtual-stage parameter chunking
# ===========================================================================


class TestVirtualStageParams:
    def _model(self, n_layers=4, pipe=2):
        cfg = tiny_cfg("granite-8b", n_layers=n_layers, pipe=pipe)
        m = Model(cfg)
        return m, m.init(jax.random.PRNGKey(0))

    def test_chunk_trees_and_device_grouping(self):
        m, params = self._model(n_layers=4, pipe=2)
        chunks = m.partition_stage_params(params["stages"], (1, 1, 1, 1),
                                          n_chunks=4)
        assert len(chunks) == 4
        assert all(jax.tree.leaves(t["layers"])[0].shape[0] == 1
                   for t in chunks)
        per_dev = m.device_chunk_params(chunks)
        # device d hosts chunks d, d+S (Megatron round-robin)
        assert len(per_dev) == 2 and len(per_dev[0]) == 2
        for a, b in zip(jax.tree.leaves(per_dev[0][1]),
                        jax.tree.leaves(chunks[2])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # chunk order == flat layer order
        flat = m.flat_layers(params["stages"])
        cat = jax.tree.map(lambda *xs: np.concatenate(
            [np.asarray(x) for x in xs], 0), *chunks)
        for a, b in zip(jax.tree.leaves(cat), jax.tree.leaves(flat)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_chunk_count_validation(self):
        m, params = self._model(n_layers=4, pipe=2)
        with pytest.raises(ValueError, match="chunk"):
            m.partition_stage_params(params["stages"], (1, 1, 1, 1),
                                     n_chunks=3)
        with pytest.raises(ValueError, match="fold"):
            m.device_chunk_params((None,) * 3, 2)

    def test_hybrid_models_refuse_virtual_stages(self):
        """A hybrid model ties one shared block per device; chunking
        would hand sibling chunks copies that independent per-chunk
        updates silently fork — refused at partition time."""
        cfg = tiny_cfg("zamba2-1.2b", n_layers=4, pipe=2)
        m = Model(cfg)
        assert m.hybrid
        params = m.init(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="shared"):
            m.partition_stage_params(params["stages"], (1, 1, 1, 1),
                                     n_chunks=4)
        # device-count chunking (plain ragged) still works
        trees = m.partition_stage_params(params["stages"], (1, 3))
        assert len(trees) == 2 and "shared" in trees[0]


# ===========================================================================
# IR-interpreter runtime
# ===========================================================================

# skewed per-layer costs whose DP split is provably non-uniform
_SKEW = [9.0, 1.0, 1.0, 1.0]


def _dp_ir_plan(schedule, S=2, v=1, M=4):
    p = plan(profile=synthetic_profile(_SKEW), n_stages=S,
             schedule=schedule, virtual_stages=v, n_microbatches=M)
    if v == 1:
        assert p.partition.sizes() != uniform(len(_SKEW), S).sizes(), \
            "test profile must force a non-uniform split"
    return p


class TestIRRuntime:
    def _setup(self, p, mode="spectrain", lr=0.05):
        cfg = tiny_cfg("granite-8b", n_layers=len(_SKEW), pipe=p.n_stages)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=8, seq=16)
        state = pipeline_stream.make_ir_state(m, params, None, plan=p,
                                              mode=mode)
        step = jax.jit(pipeline_stream.make_ir_train_step(
            m, plan=p, mode=mode, lr=lr))
        return m, params, batch, state, step

    @pytest.mark.parametrize("schedule,v", [("1f1b", 1),
                                            ("interleaved", 2)])
    def test_flush_runs_track_simulator(self, schedule, v):
        """Acceptance criterion: a DP-partitioned 1f1b / interleaved plan
        executes end-to-end and lands where the staleness-free simulator
        (same ragged chunk trees, same data) does — flush schedules ARE
        synchronous training."""
        p = _dp_ir_plan(schedule, v=v)
        m, params, batch, state, step = self._setup(p)
        got_sizes = tuple(jax.tree.leaves(t["layers"])[0].shape[0]
                          for t in state["params"]["stages"])
        assert got_sizes == p.partition.sizes()
        losses = []
        for _ in range(25):
            state, met = step(state, batch)
            losses.append(float(met["loss"]))

        fns, repack = staged_from_model(m, p.partition)
        sim = Simulator(fns, repack(params), plan=p, scheme="sync", lr=0.05)
        sim_losses = [sim.step(batch)["loss"] for _ in range(25)]

        assert np.isfinite(losses).all() and np.isfinite(sim_losses).all()
        # one flush round == one full-batch momentum-SGD step: the very
        # first loss must agree to numerics, converged levels closely
        assert abs(losses[0] - sim_losses[0]) < 1e-3
        assert losses[-1] < losses[0]
        assert abs(np.mean(losses[-5:]) - np.mean(sim_losses[-5:])) < 0.75

    def test_2bw_runs_and_tracks_simulator(self):
        p = _dp_ir_plan("2bw")
        m, params, batch, state, step = self._setup(p)
        losses = []
        for _ in range(30):
            state, met = step(state, batch)
            losses.append(float(met["loss"]))
        fns, repack = staged_from_model(m, p.partition)
        sim = Simulator(fns, repack(params), plan=p, scheme="spectrain",
                        lr=0.05)
        sim_losses = [sim.step(batch)["loss"] for _ in range(30)]
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0]
        assert abs(np.mean(losses[-5:]) - np.mean(sim_losses[-5:])) < 0.75

    def test_flush_schedules_are_mode_invariant(self):
        """Staleness 0 ⇒ nothing to stash or predict: vanilla, pipedream
        and spectrain must produce identical trajectories."""
        p = _dp_ir_plan("1f1b")
        ref = None
        for mode in pipeline_stream.MODES:
            _, _, batch, state, step = self._setup(p, mode=mode)
            losses = []
            for _ in range(6):
                state, met = step(state, batch)
                losses.append(float(met["loss"]))
            if ref is None:
                ref = losses
            else:
                np.testing.assert_array_equal(ref, losses)

    def test_2bw_spectrain_differs_from_pinned_and_beats_it(self):
        """2BW + weight prediction: the predicted read Ŵ = W_prev − η·v
        differs from the raw double-buffer read, and both converge."""
        p = _dp_ir_plan("2bw")
        out = {}
        for mode in ("pipedream", "spectrain"):
            _, _, batch, state, step = self._setup(p, mode=mode)
            losses = []
            for _ in range(20):
                state, met = step(state, batch)
                losses.append(float(met["loss"]))
            out[mode] = losses
        assert out["pipedream"] != out["spectrain"]
        assert out["spectrain"][-1] < out["spectrain"][0]
        assert out["pipedream"][-1] < out["pipedream"][0]

    def test_2bw_state_carries_double_buffer(self):
        p = _dp_ir_plan("2bw")
        _, params, batch, state, step = self._setup(p)
        assert "stash" in state and max(p.w_stash_depth) == 2
        s1, _ = step(state, batch)
        # after one group the stash holds the pre-update version
        for a, b in zip(jax.tree.leaves(s1["stash"]["params"]),
                        jax.tree.leaves(state["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_flush_state_has_no_stash(self):
        p = _dp_ir_plan("1f1b")
        _, _, _, state, _ = self._setup(p)
        assert "stash" not in state and max(p.w_stash_depth) == 1


class TestIRPlanValidation:
    def _mk(self, n_layers=4, pipe=2):
        cfg = tiny_cfg("granite-8b", n_layers=n_layers, pipe=pipe)
        m = Model(cfg)
        return m, m.init(jax.random.PRNGKey(0))

    def test_stream_plan_rejected_by_interpreter(self):
        m, params = self._mk()
        p = plan(profile=synthetic_profile([1.0] * 4), n_stages=2,
                 schedule="stream")
        with pytest.raises(ValueError, match="IR interpreter"):
            pipeline_stream.make_ir_state(m, params, None, plan=p)

    def test_ir_plan_rejected_by_stream_runtime(self):
        m, params = self._mk()
        p = plan(profile=synthetic_profile([1.0] * 4), n_stages=2,
                 schedule="1f1b")
        with pytest.raises(ValueError, match="stream"):
            pipeline_stream.make_train_step(m, mode="spectrain", lr=0.05,
                                            plan=p)

    def test_wrong_layer_count_rejected(self):
        m, params = self._mk(n_layers=4)
        p = plan(profile=synthetic_profile([1.0] * 6), n_stages=2,
                 schedule="1f1b")
        with pytest.raises(ValueError, match="layers"):
            pipeline_stream.make_ir_state(m, params, None, plan=p)

    def test_wrong_device_count_rejected(self):
        m, params = self._mk(n_layers=4, pipe=2)
        p = plan(profile=synthetic_profile([1.0] * 4), n_stages=4,
                 schedule="1f1b")
        with pytest.raises(ValueError, match="device"):
            pipeline_stream.make_ir_state(m, params, None, plan=p)

    def test_simulator_accepts_interleaved_chunk_plans(self):
        m, params = self._mk(n_layers=4, pipe=2)
        p = plan(profile=synthetic_profile([1.0] * 4), n_stages=2,
                 schedule="interleaved", virtual_stages=2)
        fns, repack = staged_from_model(m, p.partition)
        sim = Simulator(fns, repack(params), plan=p, scheme="sync", lr=0.05)
        assert sim.N == 4


# ===========================================================================
# CLI acceptance
# ===========================================================================


class TestTrainCLI:
    @pytest.mark.parametrize("argv", [
        ["--schedule", "1f1b"],
        ["--schedule", "1f1b", "--no-verify"],
        ["--schedule", "interleaved", "--virtual-stages", "2"],
        ["--schedule", "2bw"],
    ])
    def test_schedules_train_end_to_end(self, argv):
        from repro.launch import train
        rc = train.main([
            "--arch", "granite-8b", "--smoke", "--pipe", "2",
            "--layers", "4", "--steps", "3", "--batch", "8",
            "--seq", "16", "--log-every", "2"] + argv)
        assert rc == 0
