"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import spectrain  # noqa: E402
from repro.models.layers import (apply_rope, rope_freqs,  # noqa: E402
                                 softmax_xent)
from repro.optim import sgd  # noqa: E402


class FakeCfg:
    rope_theta = 10000.0
    hd = 16


@settings(max_examples=20, deadline=None)
@given(s1=st.integers(0, 8), s2=st.integers(0, 8), seed=st.integers(0, 99))
def test_prediction_additive_in_s(s1, s2, seed):
    """Ŵ(s1+s2) = predict(predict(W, s1), s2) with frozen momentum."""
    k = jax.random.PRNGKey(seed)
    w = jax.random.normal(k, (16,))
    v = jax.random.normal(jax.random.PRNGKey(seed + 1), (16,))
    a = spectrain.predict_weights(w, v, 0.1, s1 + s2)
    b = spectrain.predict_weights(
        spectrain.predict_weights(w, v, 0.1, s1), v, 0.1, s2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 99), pos=st.integers(0, 1000))
def test_rope_preserves_norm(seed, pos):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 4, 2, 16))
    inv = rope_freqs(FakeCfg())
    y = apply_rope(x, jnp.full((1, 4), pos), inv)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 99))
def test_rope_relative_position_invariance(seed):
    """q·k after rope depends only on relative offset."""
    k0 = jax.random.PRNGKey(seed)
    q = jax.random.normal(k0, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 1, 1, 16))
    inv = rope_freqs(FakeCfg())

    def score(pq, pk):
        qr = apply_rope(q, jnp.asarray([[pq]]), inv)
        kr = apply_rope(k, jnp.asarray([[pk]]), inv)
        return float(jnp.sum(qr * kr))

    assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-3,
                                        abs=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 99), gamma=st.sampled_from([0.0, 0.5, 0.9]))
def test_momentum_zero_gradient_decays(seed, gamma):
    """With g=0 the momentum shrinks geometrically; weights drift bounded."""
    v0 = jax.random.normal(jax.random.PRNGKey(seed), (8,))
    w = jnp.zeros((8,))
    v = v0
    for i in range(5):
        w, ms = sgd.update(w, sgd.MomentumState(v), jnp.zeros((8,)),
                           lr=0.1, gamma=gamma)
        v = ms.v
    np.testing.assert_allclose(np.asarray(v), np.asarray(v0 * gamma ** 5),
                               atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 99), vocab=st.sampled_from([8, 17, 64]))
def test_xent_uniform_logits_is_log_vocab(seed, vocab):
    logits = jnp.zeros((2, 4, vocab))
    tgt = jax.random.randint(jax.random.PRNGKey(seed), (2, 4), 0, vocab)
    loss = softmax_xent(logits, tgt, vocab)
    assert float(loss) == pytest.approx(np.log(vocab), rel=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 99))
def test_xent_perfect_prediction_near_zero(seed):
    tgt = jax.random.randint(jax.random.PRNGKey(seed), (2, 4), 0, 16)
    logits = 100.0 * jax.nn.one_hot(tgt, 16)
    assert float(softmax_xent(logits, tgt, 16)) < 1e-3


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 20), n=st.sampled_from([2, 4]))
def test_stream_version_difference_consistency(seed, n):
    """In the stream schedule the prediction distance equals the actual
    number of updates a microbatch waits for (2(N-1-k))."""
    for k in range(n):
        s = spectrain.version_difference_stream(k, n, "forward")
        fwd_tick = k
        bwd_tick = 2 * (n - 1) - k
        assert s == bwd_tick - fwd_tick
