"""Chunk-parallel WKV6/SSD (the hillclimb fix) vs the sequential scans."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm


def _rwkv_inputs(b=2, s=128, h=3, hd=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd)) * 0.3
    v = jax.random.normal(ks[2], (b, s, h, hd))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, hd)) * 0.5
                         - 0.5))
    u = jax.random.normal(ks[4], (h, hd)) * 0.3
    S0 = jax.random.normal(ks[5], (b, h, hd, hd)) * 0.1
    return r, k, v, w, u, S0


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_rwkv6_chunked_matches_scan(chunk):
    r, k, v, w, u, S0 = _rwkv_inputs()
    y1, s1 = ssm.rwkv6_wkv_ref(r, k, v, w, u, S0)
    y2, s2 = ssm.rwkv6_wkv_chunked(r, k, v, w, u, S0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4,
                               rtol=1e-4)


def test_rwkv6_chunked_grads_match():
    r, k, v, w, u, S0 = _rwkv_inputs(s=64)
    for i, arg in enumerate("rkvw"):
        def f(fn):
            def g(x):
                args = [r, k, v, w]
                args[i] = x
                return jnp.sum(jnp.sin(fn(*args, u, S0)[0]))
            return g
        g1 = jax.grad(f(ssm.rwkv6_wkv_ref))([r, k, v, w][i])
        g2 = jax.grad(f(lambda *a: ssm.rwkv6_wkv_chunked(*a, chunk=16)))(
            [r, k, v, w][i])
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   atol=5e-4, rtol=1e-3, err_msg=arg)


@pytest.mark.parametrize("chunk", [16, 32])
def test_mamba2_chunked_matches_scan(chunk):
    b, s, nh, p, n, g = 2, 128, 4, 16, 8, 2
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, s, nh, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    decay = jnp.exp(-dt * jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3))
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    S0 = jnp.zeros((b, nh, p, n))
    y1, s1 = ssm.mamba2_ssd_ref(x, dt, decay, B, C, S0)
    y2, s2 = ssm.mamba2_ssd_chunked(x, dt, decay, B, C, S0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4,
                               rtol=1e-4)


def test_chunked_path_engages_on_long_seq():
    """With USE_CHUNKED on, long sequences route through the chunked form
    and produce finite outputs; with random-init decay parameters the two
    paths agree in distribution (exact equality holds in the trained-decay
    envelope tested above — the module docstring documents the underflow
    limit that the Pallas kernel's log-space renorm removes)."""
    from conftest import lm_batch, tiny_cfg
    from repro.models import Model
    cfg = tiny_cfg("rwkv6-7b", n_layers=2, pipe=1)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=1,
                     seq=ssm.CHUNKED_MIN_SEQ)
    old = ssm.USE_CHUNKED
    try:
        ssm.USE_CHUNKED = True
        l1, _ = m.forward(params, batch)
        loss1 = m.loss(params, batch)
    finally:
        ssm.USE_CHUNKED = old
    assert np.isfinite(np.asarray(l1, np.float32)).all()
    assert np.isfinite(float(loss1))
