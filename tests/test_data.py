"""Data pipeline: determinism, sharding, resumability, learnability floor."""
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticLM, make_iterator


@pytest.fixture(scope="module")
def data():
    return SyntheticLM(DataConfig(vocab_size=64, seq_len=32,
                                  global_batch=8, seed=7))


class TestDeterminism:
    def test_same_step_same_batch(self, data):
        a = data.batch_at(5)
        b = data.batch_at(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_different_steps_differ(self, data):
        a = data.batch_at(5)
        b = data.batch_at(6)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_targets_are_shifted_tokens(self, data):
        b = data.batch_at(0)
        # targets[t] is the next token after tokens[t]
        assert b["tokens"].shape == b["targets"].shape
        np.testing.assert_array_equal(b["tokens"][:, 1:],
                                      b["targets"][:, :-1])


class TestSharding:
    def test_shards_are_disjoint_and_deterministic(self, data):
        s0 = data.batch_at(3, shard=0, num_shards=2)
        s1 = data.batch_at(3, shard=1, num_shards=2)
        assert s0["tokens"].shape[0] == 4
        assert not np.array_equal(s0["tokens"], s1["tokens"])
        # re-materializing a shard is deterministic (resume on any host)
        np.testing.assert_array_equal(
            s0["tokens"], data.batch_at(3, shard=0, num_shards=2)["tokens"])


class TestResume:
    def test_iterator_resumes_exactly(self, data):
        it = make_iterator(data, 0)
        seq = [next(it) for _ in range(6)]
        it2 = make_iterator(data, 3)
        for want_step in (3, 4, 5):
            step, batch = next(it2)
            assert step == want_step
            np.testing.assert_array_equal(batch["tokens"],
                                          seq[want_step][1]["tokens"])


class TestLearnability:
    def test_bigram_floor_below_uniform(self, data):
        floor = data.optimal_loss()
        assert 0 < floor < np.log(64)

    def test_uniform_floor_is_log_vocab(self):
        d = SyntheticLM(DataConfig(vocab_size=64, seq_len=8, global_batch=2,
                                   kind="uniform"))
        assert d.optimal_loss() == pytest.approx(np.log(64))

    def test_bigram_statistics_match_table(self, data):
        """Empirical next-token distribution tracks the bigram table."""
        big = np.zeros((64, 64))
        for s in range(20):
            b = data.batch_at(s)
            for row_t, row_y in zip(b["tokens"], b["targets"]):
                np.add.at(big, (row_t, row_y), 1.0)
        # correlation between empirical transitions and the true table
        emp = big / np.maximum(big.sum(-1, keepdims=True), 1)
        mask = big.sum(-1) > 50
        true = data._P[mask]
        got = emp[mask]
        corr = np.corrcoef(true.ravel(), got.ravel())[0, 1]
        assert corr > 0.7, corr
