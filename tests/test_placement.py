"""Per-stage device placement for ragged stage weights.

Runs on a forced multi-device host mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — the dedicated
CI placement job sets this); skipped on single-device runs, where the
pipe axis cannot be materialized.

The property under test is the paper's §3 placement model: stage ``k``'s
params / momentum / fused-predict mirror / pipedream ``w_stash`` live
*only* on pipe device ``k`` — no ``pipe``-axis replication — for both
uniform and DP (non-uniform) plans, while activation rings stay on the
full mesh.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from conftest import lm_batch, tiny_cfg
from repro.core import pipeline_stream
from repro.models import Model
from repro.planner import plan, synthetic_profile
from repro.runtime import sharding as sh

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _mesh():
    return Mesh(np.array(jax.devices()[:4]).reshape(1, 4), ("data", "pipe"))


def _state(cfg, mode="pipedream", pplan=None, fused_predict=False):
    m = Model(cfg)
    b = lm_batch(jax.random.PRNGKey(1), cfg, batch=4, seq=8)
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), b)
    state = pipeline_stream.make_state(
        m, m.init(jax.random.PRNGKey(0)), sds, mode=mode, plan=pplan,
        fused_predict=fused_predict)
    return m, state


def _stage_devices(tree):
    return {d for leaf in jax.tree.leaves(tree)
            for d in leaf.sharding.device_set}


def _assert_stage_pinned(placed, mesh, n_stages):
    """Every per-stage tree of every weight-like state entry sits on
    exactly its own pipe device."""
    pipe_devs = [mesh.devices[0, k] for k in range(n_stages)]
    checked = 0
    for name in ("params", "momentum", "pred"):
        if name not in placed:
            continue
        for k, t in enumerate(placed[name]["stages"]):
            devs = _stage_devices(t)
            assert devs == {pipe_devs[k % n_stages]}, (name, k, devs)
            checked += 1
    if "w_stash" in placed:
        for k, t in enumerate(placed["w_stash"]):
            assert _stage_devices(t) == {pipe_devs[k % n_stages]}, ("w", k)
            checked += 1
    assert checked >= 2 * n_stages


class TestStagePlacement:
    def test_uniform_plan_pins_each_stage(self):
        mesh = _mesh()
        cfg = tiny_cfg("granite-8b", n_layers=8, pipe=4)
        m, state = _state(cfg, mode="pipedream")
        rules = sh.logical_rules(cfg, mesh)
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        shards = sh.stage_placement_shardings(m, sds, mesh, rules)
        placed = jax.device_put(state, shards)
        _assert_stage_pinned(placed, mesh, 4)
        # activation rings stay on the full mesh, not one device
        assert len(_stage_devices(placed["fwd_buf"])) == 4

    def test_dp_plan_pins_ragged_stages(self):
        """Non-uniform (DP) partition: differently-shaped stage trees
        still pin to their own pipe device."""
        mesh = _mesh()
        p = plan(profile=synthetic_profile([9.0, 9.0, 9.0, 1.0, 1.0, 1.0,
                                            1.0]),
                 n_stages=4, schedule="stream", partitioner="dp")
        sizes = p.partition.sizes()
        assert len(set(sizes)) > 1, sizes    # genuinely ragged
        cfg = tiny_cfg("granite-8b", n_layers=7, pipe=4)
        m, state = _state(cfg, mode="spectrain", pplan=p,
                          fused_predict=True)
        rules = sh.logical_rules(cfg, mesh)
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        shards = sh.stage_placement_shardings(m, sds, mesh, rules)
        placed = jax.device_put(state, shards)
        _assert_stage_pinned(placed, mesh, 4)

    def test_spmd_shardings_still_full_mesh(self):
        """stream_state_shardings (the jit path) keeps every leaf on the
        full mesh — placement maps and SPMD specs are distinct tools."""
        mesh = _mesh()
        cfg = tiny_cfg("granite-8b", n_layers=8, pipe=4)
        m, state = _state(cfg)
        rules = sh.logical_rules(cfg, mesh)
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        shards = sh.stream_state_shardings(m, sds, mesh, rules)
        for s in jax.tree.leaves(
                shards, is_leaf=lambda x: hasattr(x, "device_set")):
            assert s.mesh.devices.size == 4

    def test_no_pipe_axis_raises(self):
        mesh = Mesh(np.array(jax.devices()[:4]).reshape(4), ("data",))
        cfg = tiny_cfg("granite-8b", n_layers=8, pipe=4)
        m, state = _state(cfg)
        rules = sh.logical_rules(cfg, mesh)
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        with pytest.raises(ValueError, match="pipe"):
            sh.stage_placement_shardings(m, sds, mesh, rules)
