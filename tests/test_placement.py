"""Per-stage device placement for ragged stage weights.

Runs on a forced multi-device host mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — the dedicated
CI placement job sets this); skipped on single-device runs, where the
pipe axis cannot be materialized.

The property under test is the paper's §3 placement model: stage ``k``'s
params / momentum / fused-predict mirror / pipedream ``w_stash`` live
*only* on pipe device ``k`` — no ``pipe``-axis replication — for both
uniform and DP (non-uniform) plans, while activation rings stay on the
full mesh.
"""
import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from conftest import lm_batch, tiny_cfg
from repro.core import pipeline_stream
from repro.models import Model
from repro.planner import plan, synthetic_profile
from repro.runtime import sharding as sh

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _mesh():
    return Mesh(np.array(jax.devices()[:4]).reshape(1, 4), ("data", "pipe"))


def _state(cfg, mode="pipedream", pplan=None, fused_predict=False):
    m = Model(cfg)
    b = lm_batch(jax.random.PRNGKey(1), cfg, batch=4, seq=8)
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), b)
    state = pipeline_stream.make_state(
        m, m.init(jax.random.PRNGKey(0)), sds, mode=mode, plan=pplan,
        fused_predict=fused_predict)
    return m, state


def _stage_devices(tree):
    return {d for leaf in jax.tree.leaves(tree)
            for d in leaf.sharding.device_set}


def _assert_stage_pinned(placed, mesh, n_stages):
    """Every per-stage tree of every weight-like state entry sits on
    exactly its own pipe device."""
    pipe_devs = [mesh.devices[0, k] for k in range(n_stages)]
    checked = 0
    for name in ("params", "momentum", "pred"):
        if name not in placed:
            continue
        for k, t in enumerate(placed[name]["stages"]):
            devs = _stage_devices(t)
            assert devs == {pipe_devs[k % n_stages]}, (name, k, devs)
            checked += 1
    if "w_stash" in placed:
        for k, t in enumerate(placed["w_stash"]):
            assert _stage_devices(t) == {pipe_devs[k % n_stages]}, ("w", k)
            checked += 1
    assert checked >= 2 * n_stages


class TestStagePlacement:
    def test_uniform_plan_pins_each_stage(self):
        mesh = _mesh()
        cfg = tiny_cfg("granite-8b", n_layers=8, pipe=4)
        m, state = _state(cfg, mode="pipedream")
        rules = sh.logical_rules(cfg, mesh)
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        shards = sh.stage_placement_shardings(m, sds, mesh, rules)
        placed = jax.device_put(state, shards)
        _assert_stage_pinned(placed, mesh, 4)
        # activation rings stay on the full mesh, not one device
        assert len(_stage_devices(placed["fwd_buf"])) == 4

    def test_dp_plan_pins_ragged_stages(self):
        """Non-uniform (DP) partition: differently-shaped stage trees
        still pin to their own pipe device."""
        mesh = _mesh()
        p = plan(profile=synthetic_profile([9.0, 9.0, 9.0, 1.0, 1.0, 1.0,
                                            1.0]),
                 n_stages=4, schedule="stream", partitioner="dp")
        sizes = p.partition.sizes()
        assert len(set(sizes)) > 1, sizes    # genuinely ragged
        cfg = tiny_cfg("granite-8b", n_layers=7, pipe=4)
        m, state = _state(cfg, mode="spectrain", pplan=p,
                          fused_predict=True)
        rules = sh.logical_rules(cfg, mesh)
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        shards = sh.stage_placement_shardings(m, sds, mesh, rules)
        placed = jax.device_put(state, shards)
        _assert_stage_pinned(placed, mesh, 4)

    def test_spmd_shardings_still_full_mesh(self):
        """stream_state_shardings (the jit path) keeps every leaf on the
        full mesh — placement maps and SPMD specs are distinct tools."""
        mesh = _mesh()
        cfg = tiny_cfg("granite-8b", n_layers=8, pipe=4)
        m, state = _state(cfg)
        rules = sh.logical_rules(cfg, mesh)
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        shards = sh.stream_state_shardings(m, sds, mesh, rules)
        for s in jax.tree.leaves(
                shards, is_leaf=lambda x: hasattr(x, "device_set")):
            assert s.mesh.devices.size == 4

    def test_no_pipe_axis_raises(self):
        mesh = Mesh(np.array(jax.devices()[:4]).reshape(4), ("data",))
        cfg = tiny_cfg("granite-8b", n_layers=8, pipe=4)
        m, state = _state(cfg)
        rules = sh.logical_rules(cfg, mesh)
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        with pytest.raises(ValueError, match="pipe"):
            sh.stage_placement_shardings(m, sds, mesh, rules)


# ===========================================================================
# MPMD execution backend: packed stage leaves live 1/S per device
# ===========================================================================


def _mpmd_packed_trees(state):
    """Every packed ``[v, S, Lmax, ...]`` stage tree the state carries
    (params / momentum, and the 2BW stash when present)."""
    trees = [state["params"]["stages"], state["momentum"]["stages"]]
    if "stash" in state:
        trees += [state["stash"]["params"]["stages"],
                  state["stash"]["momentum"]["stages"]]
    return trees


def _assert_chunks_stage_local(state, S):
    """Chunk q of every packed leaf is addressable ONLY on pipe device
    q % S: each device's shard covers exactly its own pipe column of
    the ``[v, S, Lmax, ...]`` layout."""
    pipe_devs = list(sh.mpmd_pipe_mesh(S).devices.reshape(-1))
    checked = 0
    for tree in _mpmd_packed_trees(state):
        for leaf in jax.tree.leaves(tree):
            assert leaf.shape[1] == S
            total = 0
            for shard in leaf.addressable_shards:
                col = shard.index[1]
                assert col.stop - col.start == 1, shard.index
                # the column holding chunks {q : q % S == j} sits on
                # pipe device j and nowhere else
                assert shard.device == pipe_devs[col.start], \
                    (col.start, shard.device)
                total += shard.data.nbytes
            assert total == leaf.nbytes     # no pipe-axis replication
            checked += 1
    assert checked >= 2


def _per_device_stage_bytes(state):
    per: dict = {}
    for tree in _mpmd_packed_trees(state):
        for leaf in jax.tree.leaves(tree):
            for shard in leaf.addressable_shards:
                per[shard.device] = \
                    per.get(shard.device, 0) + shard.data.nbytes
    return per


class TestMpmdPlacement:
    def _mpmd_state(self, schedule, S, L, v=1, partitioner="uniform",
                    mode="spectrain", M=None):
        p = plan(profile=synthetic_profile([9.0] + [1.0] * (L - 1)),
                 n_stages=S, schedule=schedule, virtual_stages=v,
                 partitioner=partitioner,
                 n_microbatches=(M or 2 * S * v))
        cfg = tiny_cfg("granite-8b", n_layers=L, pipe=S)
        m = Model(cfg)
        state = pipeline_stream.make_ir_state(
            m, m.init(jax.random.PRNGKey(0)), None, plan=p, mode=mode,
            execution="mpmd")
        return m, p, cfg, state

    def test_uniform_plan_params_one_s_th_per_device(self):
        """The §3 memory claim, measured: with a uniform split each
        device holds exactly 1/S of the stage weights (and momentum),
        every chunk addressable only on its own pipe device."""
        S = 4
        m, p, cfg, state = self._mpmd_state("1f1b", S, L=8)
        _assert_chunks_stage_local(state, S)
        per = _per_device_stage_bytes(state)
        assert len(per) == S
        total = sum(per.values())
        for d, b in per.items():
            assert b == total // S, (d, b, total)
        # vs the replicated SPMD layout: that state is fully
        # addressable per device, the packed one is 1/S of it
        m2 = Model(cfg)
        spmd = pipeline_stream.make_ir_state(
            m2, m2.init(jax.random.PRNGKey(0)), None, plan=p,
            mode="spectrain")
        spmd_stage_bytes = sum(
            leaf.nbytes for t in spmd["params"]["stages"]
            for leaf in jax.tree.leaves(t))
        mpmd_param_bytes = sum(
            leaf.nbytes for leaf in
            jax.tree.leaves(state["params"]["stages"]))
        assert mpmd_param_bytes == spmd_stage_bytes  # uniform: no padding
        dev0 = sh.mpmd_pipe_mesh(S).devices.reshape(-1)[0]
        dev0_param_bytes = sum(
            shard.data.nbytes
            for leaf in jax.tree.leaves(state["params"]["stages"])
            for shard in leaf.addressable_shards if shard.device == dev0)
        assert dev0_param_bytes * S == spmd_stage_bytes

    def test_ragged_dp_plan_2bw_stash_stage_local(self):
        """Ragged DP partition under 2BW: params, momentum AND both
        stash buffers stay stage-local (padding rows included, which is
        what keeps the layout SPMD-compilable)."""
        S = 4
        m, p, cfg, state = self._mpmd_state(
            "2bw", S, L=7, partitioner="dp", M=4)
        assert len(set(p.partition.sizes())) > 1  # genuinely ragged
        assert "stash" in state
        _assert_chunks_stage_local(state, S)

    def test_interleaved_chunk_folds_to_device_mod_s(self):
        """v=2 interleaving: chunk q sits at packed index
        [q//S, q%S], i.e. on pipe device q % S — verified against the
        unpacked chunk values."""
        from repro.models.model import unpack_chunk_params
        S, v = 2, 2
        m, p, cfg, state = self._mpmd_state("interleaved", S, L=4, v=v)
        _assert_chunks_stage_local(state, S)
        sizes = np.asarray(state["chunk_sizes"])
        chunks = unpack_chunk_params(state["params"]["stages"], sizes)
        pipe_devs = list(sh.mpmd_pipe_mesh(S).devices.reshape(-1))
        packed_leaves = jax.tree.leaves(state["params"]["stages"])
        for li, leaf in enumerate(packed_leaves):
            assert leaf.shape[:2] == (v, S)
            for q in range(v * S):
                shard = next(s for s in leaf.addressable_shards
                             if s.index[1].start == q % S)
                np.testing.assert_array_equal(
                    np.asarray(shard.data)[q // S, 0, :sizes[q]],
                    np.asarray(jax.tree.leaves(chunks[q])[li]))
                assert shard.device == pipe_devs[q % S]

    def test_placement_survives_a_jitted_step(self):
        """One jitted train step keeps every packed leaf pipe-sharded —
        the update path does not silently replicate weights back."""
        S = 4
        m, p, cfg, state = self._mpmd_state("1f1b", S, L=8)
        batch = lm_batch(jax.random.PRNGKey(1), cfg,
                         batch=2 * p.round_microbatches, seq=8)
        step = jax.jit(pipeline_stream.make_ir_train_step(
            m, plan=p, mode="spectrain", lr=0.05, execution="mpmd"))
        state, _ = step(state, batch)
        _assert_chunks_stage_local(state, S)
