"""Optimizers + gradient compression properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis
from repro.optim import adam, compression, sgd

given, settings, st = optional_hypothesis()


class TestMomentumSGD:
    def test_closed_form_eq1_eq2(self):
        w = jnp.asarray([1.0, 2.0])
        v = jnp.asarray([0.5, -0.5])
        g = jnp.asarray([1.0, 1.0])
        p2, m2 = sgd.update(w, sgd.MomentumState(v), g, lr=0.1, gamma=0.9)
        v_exp = 0.9 * v + 0.1 * g
        np.testing.assert_allclose(np.asarray(m2.v), np.asarray(v_exp))
        np.testing.assert_allclose(np.asarray(p2),
                                   np.asarray(w - 0.1 * v_exp))

    def test_momentum_fp32_under_bf16_params(self):
        w = jnp.ones((4,), jnp.bfloat16)
        state = sgd.init(w)
        assert state.v.dtype == jnp.float32
        p2, m2 = sgd.update(w, state, jnp.ones((4,), jnp.bfloat16), lr=0.1)
        assert p2.dtype == jnp.bfloat16 and m2.v.dtype == jnp.float32

    def test_clip(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, n = sgd.clip_by_global_norm(g, 1.0)
        assert float(n) == pytest.approx(20.0)
        assert float(sgd.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)

    def test_clip_noop_below_threshold(self):
        g = {"a": jnp.full((4,), 0.1)}
        clipped, _ = sgd.clip_by_global_norm(g, 10.0)
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(g["a"]))


class TestClipLayoutEquivalence:
    """Global-norm clipping is canonicalized: the ragged per-stage and
    stacked stage layouts (and any two partitions of the same layers)
    reduce the identical partial vector in the identical order, so the
    clipped gradients agree BITWISE — the one layout-sensitive numeric
    the golden cases (which don't clip) could not pin."""

    def _model_grads(self, n_layers=4, pipe=2):
        from conftest import lm_batch, tiny_cfg
        from repro.models import Model
        m = Model(tiny_cfg("granite-8b", n_layers=n_layers, pipe=pipe))
        params = m.init(jax.random.PRNGKey(0))
        batch = lm_batch(jax.random.PRNGKey(1), m.cfg, batch=2, seq=8)
        return m, jax.grad(lambda p: m.loss(p, batch))(params)

    def test_stacked_vs_ragged_bitwise(self):
        m, g = self._model_grads()
        g_stacked = {"outer": g["outer"],
                     "stages": m.stack_stage_params(g["stages"])}
        n_r = sgd.global_norm(g)
        n_s = sgd.global_norm(g_stacked)
        assert float(n_r) == float(n_s)          # bitwise, not approx
        c_r, _ = sgd.clip_by_global_norm(g, 0.05)
        c_s, _ = sgd.clip_by_global_norm(g_stacked, 0.05)
        back = m.partition_stage_params(c_s["stages"], (2, 2))
        for a, b in zip(jax.tree.leaves(c_r["stages"]),
                        jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bitwise_under_jit_and_across_partitions(self):
        """jit must not re-associate the canonical reduction, and any
        two partitions of the same 7 layers must agree."""
        m, g = self._model_grads(n_layers=7, pipe=3)   # sizes (3, 2, 2)
        g_alt = {"outer": g["outer"],
                 "stages": m.partition_stage_params(g["stages"],
                                                    (1, 3, 3))}
        n_a = jax.jit(sgd.global_norm)(g)
        n_b = jax.jit(sgd.global_norm)(g_alt)
        assert float(n_a) == float(n_b)

    def test_clip_enabled_training_step_layout_invariant(self):
        """A full clipped update agrees across layouts: clip + momentum
        SGD on stacked == on ragged, bitwise after regrouping."""
        m, g = self._model_grads()
        params = m.init(jax.random.PRNGKey(0))
        mom = sgd.init(params)
        c, _ = sgd.clip_by_global_norm(g, 0.1)
        p_r, _ = sgd.update(params, mom, c, lr=0.05)

        params_s = {"outer": params["outer"],
                    "stages": m.stack_stage_params(params["stages"])}
        g_s = {"outer": g["outer"],
               "stages": m.stack_stage_params(g["stages"])}
        c_s, _ = sgd.clip_by_global_norm(g_s, 0.1)
        p_s, _ = sgd.update(params_s, sgd.init(params_s), c_s, lr=0.05)
        back = m.partition_stage_params(p_s["stages"], (2, 2))
        for a, b in zip(jax.tree.leaves(p_r["stages"]),
                        jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestAdam:
    def test_descends_quadratic(self):
        w = jnp.asarray([5.0, -3.0])
        state = adam.init(w)
        for _ in range(200):
            g = 2 * w
            w, state = adam.update(w, state, g, lr=0.1)
        assert float(jnp.max(jnp.abs(w))) < 0.1

    def test_predict_direction(self):
        w = jnp.asarray([1.0])
        state = adam.init(w)
        for _ in range(10):
            w, state = adam.update(w, state, jnp.asarray([1.0]), lr=0.01)
        pred = adam.predict(w, state, lr=0.01, s=5)
        assert float(pred[0]) < float(w[0])  # keeps moving downhill


class TestCompression:
    def test_topk_keeps_largest(self):
        g = {"a": jnp.asarray([0.1, -5.0, 0.2, 3.0])}
        res = compression.topk_init(g)
        sent, res2, stats = compression.topk_compress(g, res, frac=0.5)
        np.testing.assert_allclose(np.asarray(sent["a"]),
                                   np.asarray([0.0, -5.0, 0.0, 3.0]))
        np.testing.assert_allclose(np.asarray(res2["a"]),
                                   np.asarray([0.1, 0.0, 0.2, 0.0]))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100), frac=st.sampled_from([0.1, 0.25, 0.5]))
    def test_error_feedback_telescopes(self, seed, frac):
        """sum(sent) + final residual == sum(grads): nothing is lost."""
        key = jax.random.PRNGKey(seed)
        res = {"a": jnp.zeros((32,))}
        total_sent = jnp.zeros((32,))
        total_g = jnp.zeros((32,))
        for i in range(5):
            key, k = jax.random.split(key)
            g = {"a": jax.random.normal(k, (32,))}
            total_g = total_g + g["a"]
            sent, res, _ = compression.topk_compress(g, res, frac=frac)
            total_sent = total_sent + sent["a"]
        np.testing.assert_allclose(np.asarray(total_sent + res["a"]),
                                   np.asarray(total_g), atol=1e-5)

    def test_int8_unbiased(self):
        key = jax.random.PRNGKey(0)
        g = {"a": jax.random.normal(key, (64,))}
        acc = jnp.zeros((64,))
        n = 200
        for i in range(n):
            out = compression.int8_roundtrip(g, jax.random.PRNGKey(i))
            acc = acc + out["a"]
        err = float(jnp.max(jnp.abs(acc / n - g["a"])))
        scale = float(jnp.max(jnp.abs(g["a"]))) / 127
        assert err < 3 * scale  # stochastic rounding is unbiased

    def test_int8_bounded_error(self):
        key = jax.random.PRNGKey(1)
        g = {"a": jax.random.normal(key, (128,))}
        out = compression.int8_roundtrip(g, key)
        scale = float(jnp.max(jnp.abs(g["a"]))) / 127
        assert float(jnp.max(jnp.abs(out["a"] - g["a"]))) <= scale + 1e-6
