"""Docs don't rot: the CI docs job's checks also run in tier-1.

``tools/check_docs.py`` verifies that intra-repo markdown links resolve
and that fenced python/bash code blocks in README/docs/EXPERIMENTS at
least parse.
"""
import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def test_docs_links_and_snippets():
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "check_docs.py")],
        capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, f"docs check failed:\n{r.stderr}{r.stdout}"


def test_required_docs_exist():
    for f in ("README.md", "docs/ARCHITECTURE.md", "docs/SCHEDULES.md",
              "docs/OBSERVABILITY.md"):
        assert os.path.exists(os.path.join(REPO, f)), f
