"""Beyond-paper performance options preserve semantics."""
import jax
import numpy as np
import pytest

from conftest import lm_batch, tiny_cfg
from repro.core import pipeline_stream
from repro.models import Model


def _setup(pipe=2):
    cfg = tiny_cfg("granite-8b", n_layers=4, pipe=pipe)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=4, seq=16)
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       batch)
    return cfg, m, params, batch, sds


class TestFusedPredict:
    def test_identical_trajectory_in_fp32(self):
        """fused_predict moves Eq. 4 into the update pass — exactly the
        same math, so in fp32 the trajectories must match."""
        cfg, m, params, batch, sds = _setup()
        s_a = pipeline_stream.make_state(m, params, sds)
        step_a = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.05))
        s_b = pipeline_stream.make_state(m, params, sds,
                                         fused_predict=True)
        step_b = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.05, fused_predict=True))
        for _ in range(6):
            s_a, met_a = step_a(s_a, batch)
            s_b, met_b = step_b(s_b, batch)
        for a, b in zip(jax.tree.leaves(s_a["params"]),
                        jax.tree.leaves(s_b["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        assert float(met_a["loss"]) == pytest.approx(float(met_b["loss"]),
                                                     rel=1e-5)

    def test_pred_state_is_prediction(self):
        cfg, m, params, batch, sds = _setup()
        from repro.core import spectrain as st
        state = pipeline_stream.make_state(m, params, sds,
                                           fused_predict=True)
        step = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.05, fused_predict=True))
        state, _ = step(state, batch)
        # stream s_fwd = 2(S-1-k) per ragged stage tree
        want = tuple(
            st.predict_weights(w, v, 0.05, s)
            for w, v, s in zip(state["params"]["stages"],
                               state["momentum"]["stages"], (2.0, 0.0)))
        for a, b in zip(jax.tree.leaves(state["pred"]["stages"]),
                        jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


class TestBwdBf16:
    def test_converges_and_tracks_fp32(self):
        cfg, m, params, batch, sds = _setup()
        losses = {}
        for bwd in (None, "bfloat16"):
            state = pipeline_stream.make_state(m, params, sds)
            step = jax.jit(pipeline_stream.make_train_step(
                m, mode="spectrain", lr=0.05, bwd_dtype=bwd))
            ls = []
            for _ in range(20):
                state, met = step(state, batch)
                if float(met["loss_valid"]):
                    ls.append(float(met["loss"]))
            losses[bwd or "fp32"] = ls
        assert np.isfinite(losses["bfloat16"]).all()
        # same descent within mixed-precision noise
        assert abs(losses["bfloat16"][-1] - losses["fp32"][-1]) < 0.15
