"""Observability subsystem: tracer, Perfetto export, drift, metrics.

The golden trace test runs the IR interpreter under a fake clock that
advances exactly one second per reading, so every measured event
duration is exactly 1.0 — the reconstruction must then reproduce the
IR's unit-cost timeline *exactly*: per-device event order equal to the
event table's, and realized bubble fraction equal to the plan's
closed-form ``bubble_frac``.
"""
import json

import jax
import pytest

from conftest import tiny_cfg
from repro.core import pipeline_stream
from repro.models import Model
from repro.obs import (MetricsRegistry, PipelineTracer, drift_report,
                       format_drift, format_step, probe_stage_costs,
                       round_event_metas, trace_events, validate_trace,
                       write_trace)
from repro.planner import plan, synthetic_profile


class FakeClock:
    """Deterministic clock: +1.0 s per reading."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


def _ir_setup(schedule="1f1b", M=4, S=2, n_layers=4, seq=16):
    cfg = tiny_cfg("granite-8b", n_layers=n_layers, pipe=S)
    model = Model(cfg)
    p = plan(profile=synthetic_profile([1.0] * cfg.n_layers),
             n_stages=S, schedule=schedule, n_microbatches=M)
    k = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(k, (M, seq), 0, cfg.vocab_size),
        "targets": jax.random.randint(k, (M, seq), 0, cfg.vocab_size),
    }
    return model, p, batch


def _run_traced(model, p, batch, backend, steps=3):
    tracer = PipelineTracer(p, clock=FakeClock())
    params = model.init(jax.random.PRNGKey(0))
    state = pipeline_stream.make_ir_state(model, params, None, plan=p)
    step = tracer.wrap_step(jax.jit(pipeline_stream.make_ir_train_step(
        model, plan=p, mode="spectrain", lr=0.05, backend=backend,
        tracer=tracer), donate_argnums=0))
    metrics = None
    for _ in range(steps):
        state, metrics = step(state, batch)
    return tracer, metrics


class TestRoundEventMetas:
    @pytest.mark.parametrize("schedule", ["gpipe", "1f1b", "2bw"])
    def test_matches_round_program(self, schedule):
        _, p, _ = _ir_setup(schedule=schedule)
        metas = round_event_metas(p)
        prog = p.round_program()
        assert len(metas) == len(prog)
        for m, (kind, mb, q, s) in zip(metas, prog):
            assert (m["kind"], m["mb"], m["chunk"], m["wv"]) == \
                (kind, mb, q, s)
        # ticks are non-decreasing nowhere required, but devices valid
        assert all(0 <= m["device"] < p.n_devices for m in metas)

    def test_interleaved_devices_fold_chunks(self):
        cfg = tiny_cfg("granite-8b", n_layers=4, pipe=2)
        model = Model(cfg)
        p = plan(profile=synthetic_profile([1.0] * 4), n_stages=2,
                 schedule="interleaved", virtual_stages=2,
                 n_microbatches=4)
        metas = round_event_metas(p)
        assert {m["device"] for m in metas} == set(range(p.n_devices))
        assert {m["chunk"] for m in metas} == set(range(p.n_chunks))
        del model


class TestGoldenTrace:
    @pytest.mark.parametrize("backend", pipeline_stream.IR_BACKENDS)
    def test_order_and_bubble_exact(self, backend):
        """Uniform S=2 1f1b: measured per-device event order equals the
        IR event table's, and the fake-clock bubble equals the plan's."""
        model, p, batch = _ir_setup(schedule="1f1b", M=4, S=2)
        tracer, _ = _run_traced(model, p, batch, backend)
        assert tracer.n_steps() == 3
        assert len(tracer.rounds) == 3
        assert tracer.dropped_rounds == 0
        # every measured duration is exactly one fake-clock second
        assert all(d == 1.0 for r in tracer.rounds for d in r)

        spans, makespan = tracer.measured_timeline()
        # per-device order of measured spans == event-table order
        metas = tracer.metas
        for d in range(p.n_devices):
            measured = [(s.args["op"], s.args["mb"], s.args["chunk"])
                        for s in sorted((s for s in spans if s.device == d),
                                        key=lambda s: s.t0)]
            predicted = [(m["kind"], m["mb"], m["chunk"])
                         for m in metas if m["device"] == d]
            assert measured == predicted
        # unit durations reproduce the IR's unit-cost bubble exactly
        from repro.obs.trace import timeline_stats
        stats = timeline_stats(spans, makespan, p.n_devices)
        assert stats["bubble_frac"] == pytest.approx(p.bubble_frac)

    def test_scan_unrolled_same_order(self):
        model, p, batch = _ir_setup(schedule="1f1b", M=4, S=2)
        orders = []
        for backend in pipeline_stream.IR_BACKENDS:
            tracer, _ = _run_traced(model, p, batch, backend, steps=2)
            spans, _ = tracer.measured_timeline()
            orders.append([(s.device, s.name) for s in spans])
        assert orders[0] == orders[1]

    @pytest.mark.parametrize("backend", pipeline_stream.IR_BACKENDS)
    def test_tracing_does_not_change_numerics(self, backend):
        """The tracer's callbacks are observation-only: traced and
        untraced runs produce bit-identical losses."""
        model, p, batch = _ir_setup(schedule="1f1b", M=4, S=2)
        _, traced = _run_traced(model, p, batch, backend, steps=2)

        params = model.init(jax.random.PRNGKey(0))
        state = pipeline_stream.make_ir_state(model, params, None, plan=p)
        step = jax.jit(pipeline_stream.make_ir_train_step(
            model, plan=p, mode="spectrain", lr=0.05, backend=backend),
            donate_argnums=0)
        for _ in range(2):
            state, plain = step(state, batch)
        assert float(traced["loss"]) == float(plain["loss"])


class TestPerfetto:
    def _tracer(self):
        model, p, batch = _ir_setup()
        tracer, _ = _run_traced(model, p, batch, "scan", steps=2)
        return tracer

    def test_trace_events_valid_and_json(self, tmp_path):
        tracer = self._tracer()
        obj = trace_events(tracer)
        assert validate_trace(obj) == []
        json.dumps(obj)     # must be JSON-serializable as-is
        # both lane groups present with one thread lane per device
        xs = [e for e in obj["traceEvents"] if e["ph"] == "X"]
        assert {e["pid"] for e in xs} == {0, 1}
        assert {e["tid"] for e in xs if e["pid"] == 0} == \
            set(range(tracer.plan.n_devices))
        path = tmp_path / "trace.json"
        write_trace(str(path), tracer)
        assert validate_trace(json.load(open(path))) == []

    def test_validate_catches_problems(self):
        assert validate_trace([]) != []
        assert validate_trace({}) != []
        assert validate_trace({"traceEvents": [{"ph": "Z"}]}) != []
        bad_ts = {"traceEvents": [
            {"ph": "X", "name": "e", "pid": 0, "tid": 0,
             "ts": float("nan"), "dur": 1.0},
            {"ph": "X", "name": "e", "pid": 1, "tid": 0,
             "ts": 0.0, "dur": 1.0}]}
        assert any("ts" in p for p in validate_trace(bad_ts))
        # a trace missing the predicted lane group is invalid
        only_measured = {"traceEvents": [
            {"ph": "X", "name": "e", "pid": 0, "tid": 0,
             "ts": 0.0, "dur": 1.0}]}
        assert any("predicted" in p for p in validate_trace(only_measured))


class TestDrift:
    def test_report_fields_and_format(self):
        model, p, batch = _ir_setup(schedule="1f1b", M=4, S=2)
        tracer, _ = _run_traced(model, p, batch, "scan", steps=2)
        rep = drift_report(tracer)
        assert rep["schedule"] == "1f1b"
        assert rep["bubble"]["measured"] == pytest.approx(p.bubble_frac)
        assert rep["bubble"]["drift"] == pytest.approx(0.0)
        sc = rep["stage_cost_model"]
        assert len(sc["rel_err"]) == p.n_chunks
        # uniform synthetic profile + uniform fake durations: shares
        # match, so per-stage relative error is ~0
        assert sc["max_abs_rel_err"] == pytest.approx(0.0, abs=1e-9)
        assert sum(rep["staleness"]["realized"]["fwd"].values()) == \
            p.round_microbatches * p.n_chunks
        txt = format_drift(rep)
        assert all(line.startswith("#") for line in txt.splitlines())
        assert "drift" in txt and "rel_err" in txt

    def test_stream_probe_path(self):
        cfg = tiny_cfg("granite-8b", n_layers=4, pipe=2)
        model = Model(cfg)
        p = plan(cfg, n_stages=2, schedule="stream", batch=4, seq=16)
        tracer = PipelineTracer(p, clock=FakeClock())
        assert not tracer.is_round
        k = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(k, (4, 16), 0,
                                              cfg.vocab_size),
                 "targets": jax.random.randint(k, (4, 16), 0,
                                               cfg.vocab_size)}
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
        state = pipeline_stream.init_state(
            model, jax.random.PRNGKey(0), sds, plan=p)
        costs = probe_stage_costs(model, state["params"]["stages"],
                                  mb=2, seq=16)
        assert len(costs) == 2 and all(c > 0 for c in costs)
        tracer.set_probed(costs)
        step = tracer.wrap_step(jax.jit(pipeline_stream.make_train_step(
            model, mode="spectrain", lr=0.05, plan=p),
            donate_argnums=0))
        for _ in range(3):
            state, _ = step(state, batch)
        rep = drift_report(tracer)
        assert rep["steps_recorded"] == 3
        assert rep["stage_cost_model"]["measured_s"] == costs
        obj = trace_events(tracer)
        assert validate_trace(obj) == []

    def test_stream_requires_probe_for_stage_costs(self):
        cfg = tiny_cfg("granite-8b", n_layers=4, pipe=2)
        p = plan(cfg, n_stages=2, schedule="stream", batch=4, seq=16)
        tracer = PipelineTracer(p, clock=FakeClock())
        tracer.step_walls.append(1.0)
        with pytest.raises(ValueError, match="probe"):
            tracer.measured_stage_costs()


class TestMetricsRegistry:
    def test_instruments(self):
        reg = MetricsRegistry()
        reg.counter("c").inc()
        reg.counter("c").inc(2)
        reg.gauge("g").set(3.5)
        h = reg.histogram("h")
        for v in (1.0, 2.0, 3.0, 4.0):
            h.observe(v)
        snap = reg.snapshot()
        assert snap["counters"]["c"] == 3.0
        assert snap["gauges"]["g"] == 3.5
        assert snap["histograms"]["h"]["count"] == 4
        assert snap["histograms"]["h"]["mean"] == pytest.approx(2.5)
        assert h.percentile(0) == 1.0 and h.percentile(100) == 4.0
        assert "# c" in reg.summary().splitlines()[1]

    def test_jsonl_flush_and_close(self, tmp_path):
        path = tmp_path / "m.jsonl"
        reg = MetricsRegistry(str(path), clock=FakeClock())
        reg.emit("heartbeat_missed", worker=3)
        # flushed immediately, before close (the crash-safety property)
        lines = open(path).read().splitlines()
        assert json.loads(lines[0]) == \
            {"event": "heartbeat_missed", "t": 1.0, "worker": 3}
        reg.close()
        reg.close()     # idempotent
        recs = [json.loads(ln) for ln in open(path)]
        assert recs[-1]["event"] == "summary"

    def test_log_step_single_code_path(self):
        reg = MetricsRegistry()
        rec = reg.log_step(step=10, loss=1.2345, tok_per_s=99.5)
        assert rec == {"step": 10, "loss": 1.2345, "tok_per_s": 99.5}
        # the human formatter renders the same record train.py prints
        assert format_step(rec) == \
            "step    10  loss 1.2345  tok/s 99.5"
        assert json.loads(json.dumps(rec))["loss"] == 1.2345
        assert reg.find("train_step")[0]["step"] == 10
        assert reg.counter("train/steps_logged").value == 1

    def test_kernel_hook(self):
        reg = MetricsRegistry()
        from repro.kernels import ops
        ops.set_timing_hook(reg.kernel_hook())
        try:
            import jax.numpy as jnp
            b, s, h, hd = 1, 4, 2, 4
            k = jax.random.PRNGKey(0)
            r = jax.random.normal(k, (b, s, h, hd))
            u = jnp.zeros((h, hd))
            S0 = jnp.zeros((b, h, hd, hd))
            ops.rwkv6_scan(r, r, r, jnp.full_like(r, -1.0), u, S0,
                           chunk=2, interpret=True)
            snap = reg.histogram("kernel/rwkv6_scan_us").snapshot()
            assert snap["count"] == 1 and snap["mean"] > 0
        finally:
            ops.set_timing_hook(None)

    def test_kernel_hook_noop_inside_jit(self):
        reg = MetricsRegistry()
        from repro.kernels import ops
        ops.set_timing_hook(reg.kernel_hook())
        try:
            import jax.numpy as jnp
            b, s, h, hd = 1, 4, 2, 4
            k = jax.random.PRNGKey(0)
            r = jax.random.normal(k, (b, s, h, hd))
            u = jnp.zeros((h, hd))
            S0 = jnp.zeros((b, h, hd, hd))
            f = jax.jit(lambda *a: ops.rwkv6_scan(*a, chunk=2,
                                                  interpret=True))
            f(r, r, r, jnp.full_like(r, -1.0), u, S0)
            # traced call must not try to block on tracers (and records
            # nothing — jit hides per-call timing)
            assert reg.histogram("kernel/rwkv6_scan_us").count == 0
        finally:
            ops.set_timing_hook(None)
