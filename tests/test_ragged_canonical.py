"""Ragged-native canonical layout: acceptance tests for the
no-divisibility-constraint world.

A model with ``n_layers=7, n_stages=3`` (and a DP plan such as sizes
``(1, 3, 3)``) must initialize, train, checkpoint and restore under
both the streaming tick path and the IR interpreter; plan-shape
violations must raise ``ValueError`` (not ``assert``, which vanishes
under ``python -O``).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import lm_batch, tiny_cfg
from repro.core import pipeline_stream
from repro.models import Model
from repro.planner import plan, synthetic_profile
from repro.runtime import checkpoint as ckpt


def _setup(n_layers=7, pipe=3, batch=6, seq=16):
    cfg = tiny_cfg("granite-8b", n_layers=n_layers, pipe=pipe)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b = lm_batch(jax.random.PRNGKey(1), cfg, batch=batch, seq=seq)
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), b)
    return cfg, m, params, b, sds


# skew whose bottleneck-minimizing 3-way split of 7 layers is uniquely
# (1, 3, 3): per-stage costs 3/3/3
_SKEW_7 = [3.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]


def _dp_plan_133(schedule="stream", **kw):
    p = plan(profile=synthetic_profile(_SKEW_7), n_stages=3,
             schedule=schedule, partitioner="dp", **kw)
    assert p.partition.sizes() == (1, 3, 3), p.partition.sizes()
    return p


class TestStream73:
    def test_default_split_trains_and_checkpoints(self, tmp_path):
        cfg, m, params, batch, sds = _setup()
        assert m.stage_sizes == (3, 2, 2)
        state = pipeline_stream.make_state(m, params, sds)
        step = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.05))
        losses = []
        for _ in range(16):
            state, met = step(state, batch)
            if float(met["loss_valid"]):
                losses.append(float(met["loss"]))
        assert np.isfinite(losses).all() and losses[-1] < losses[0]

        ckpt.save(str(tmp_path), state, 7)
        got, s = ckpt.restore(str(tmp_path), state)
        assert s == 7
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_dp_plan_133_executes(self):
        cfg, m, params, batch, sds = _setup()
        p = _dp_plan_133()
        state = pipeline_stream.make_state(m, params, sds, plan=p)
        got = tuple(jax.tree.leaves(t["layers"])[0].shape[0]
                    for t in state["params"]["stages"])
        assert got == (1, 3, 3)
        step = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.05, plan=p))
        losses = []
        for _ in range(16):
            state, met = step(state, batch)
            if float(met["loss_valid"]):
                losses.append(float(met["loss"]))
        assert np.isfinite(losses).all() and losses[-1] < losses[0]

    def test_partitions_agree_on_flat_layers_at_init(self):
        """Repartitioning canonical trees to a plan's sizes preserves
        the flat layer order bit-for-bit."""
        cfg, m, params, batch, sds = _setup()
        p = _dp_plan_133()
        ragged = m.partition_stage_params(params["stages"],
                                          p.partition.sizes())
        for a, b in zip(jax.tree.leaves(m.flat_layers(ragged)),
                        jax.tree.leaves(m.flat_layers(params["stages"]))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestIRInterpreter73:
    @pytest.mark.parametrize("schedule", ["1f1b", "2bw"])
    def test_trains_and_checkpoints(self, schedule, tmp_path):
        cfg, m, params, batch, sds = _setup()
        p = _dp_plan_133(schedule=schedule, n_microbatches=3)
        state = pipeline_stream.make_ir_state(m, params, sds, plan=p)
        step = jax.jit(pipeline_stream.make_ir_train_step(
            m, plan=p, mode="spectrain", lr=0.05))
        losses = []
        for _ in range(8):
            state, met = step(state, batch)
            losses.append(float(met["loss"]))
        assert np.isfinite(losses).all() and losses[-1] < losses[0]

        ckpt.save(str(tmp_path), state, 3)
        got, _ = ckpt.restore(str(tmp_path), state)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestCrossPartitionRestore:
    """A checkpoint written under one partition restores bit-exactly
    onto any other via the flat layer order (train.py's promise)."""

    def test_dp_checkpoint_restores_onto_uniform(self, tmp_path):
        cfg, m, params, batch, sds = _setup()
        p = _dp_plan_133()
        state_dp = pipeline_stream.make_state(m, params, sds, plan=p)
        step = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.05, plan=p))
        for _ in range(5):
            state_dp, _ = step(state_dp, batch)
        ckpt.save(str(tmp_path), state_dp, 4)

        state_uni = pipeline_stream.make_state(m, params, sds)  # (3,2,2)
        got, s = ckpt.restore(str(tmp_path), state_uni)
        assert s == 4
        got_sizes = tuple(jax.tree.leaves(t["layers"])[0].shape[0]
                          for t in got["params"]["stages"])
        assert got_sizes == (3, 2, 2)
        # flat layer order identical to the DP state's
        for a, b in zip(
                jax.tree.leaves(m.flat_layers(got["params"]["stages"])),
                jax.tree.leaves(m.flat_layers(state_dp["params"]["stages"]))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(
                jax.tree.leaves(m.flat_layers(got["momentum"]["stages"])),
                jax.tree.leaves(
                    m.flat_layers(state_dp["momentum"]["stages"]))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_shape_coincident_stage_still_repartitions(self, tmp_path):
        """Repartitioning is a group decision, not per-leaf: restoring
        (1,3,3) onto (3,3,1), the middle stage has the same shape in
        both partitions but covers flat layers 1-3 vs 3-5 — a per-leaf
        shape check would silently duplicate/drop layers."""
        cfg, m, params, batch, sds = _setup()
        p_a = _dp_plan_133()                                  # (1, 3, 3)
        p_b = plan(profile=synthetic_profile(
            [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 3.0]), n_stages=3,
            schedule="stream", partitioner="dp")
        assert p_b.partition.sizes() == (3, 3, 1)
        state_a = pipeline_stream.make_state(m, params, sds, plan=p_a)
        step = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.05, plan=p_a))
        for _ in range(3):
            state_a, _ = step(state_a, batch)
        ckpt.save(str(tmp_path), state_a, 2)
        state_b = pipeline_stream.make_state(m, params, sds, plan=p_b)
        got, _ = ckpt.restore(str(tmp_path), state_b)
        for a, b in zip(
                jax.tree.leaves(m.flat_layers(got["params"]["stages"])),
                jax.tree.leaves(m.flat_layers(state_a["params"]["stages"]))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_stacked_checkpoint_restores_onto_dp_template(self, tmp_path):
        """A pre-ragged stacked checkpoint repartitions onto a
        non-uniform template via the same flat-layer-order path."""
        cfg, m, params, batch, sds = _setup(n_layers=8, pipe=4, batch=4)
        state = pipeline_stream.make_state(m, params, sds)   # (2,2,2,2)
        old = dict(state)
        old["params"] = {
            "outer": state["params"]["outer"],
            "stages": m.stack_stage_params(state["params"]["stages"])}
        old["momentum"] = {
            "outer": state["momentum"]["outer"],
            "stages": m.stack_stage_params(state["momentum"]["stages"])}
        ckpt.save(str(tmp_path), old, 1)

        p = plan(profile=synthetic_profile(
            [5.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 2.0]), n_stages=4,
            schedule="stream", partitioner="dp")
        assert len(set(p.partition.sizes())) > 1   # genuinely ragged
        state_dp = pipeline_stream.make_state(m, params, sds, plan=p)
        got, _ = ckpt.restore(str(tmp_path), state_dp)
        for a, b in zip(
                jax.tree.leaves(m.flat_layers(got["params"]["stages"])),
                jax.tree.leaves(m.flat_layers(state["params"]["stages"]))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_ring_state_refuses_cross_partition(self, tmp_path):
        """pipedream's in-flight weight ring has no flat layer order —
        restoring it across partitions must raise, not corrupt."""
        cfg, m, params, batch, sds = _setup()
        p = _dp_plan_133()
        state_dp = pipeline_stream.make_state(m, params, sds, plan=p,
                                              mode="pipedream")
        ckpt.save(str(tmp_path), state_dp, 1)
        state_uni = pipeline_stream.make_state(m, params, sds,
                                               mode="pipedream")
        with pytest.raises(ValueError, match="repartition"):
            ckpt.restore(str(tmp_path), state_uni)


class TestHybridRaggedDecode:
    def test_plan_partitioned_hybrid_decodes_like_its_forward(self):
        """Hybrid (shared-attn) models segment shared blocks by the
        param tree's ACTUAL partition: a non-default split must decode
        consistently with its own teacher-forced forward (cache built
        via init_cache(stage_sizes=...))."""
        cfg = tiny_cfg("zamba2-1.2b", n_layers=4, pipe=2)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        sizes = (1, 3)                       # != default (2, 2)
        p2 = {"outer": params["outer"],
              "stages": m.partition_stage_params(params["stages"], sizes)}
        assert m.stage_sizes_of(p2["stages"]) == sizes
        B, T = 2, 6
        batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=B, seq=T)
        full, _ = m.forward(p2, batch)
        cache = m.init_cache(B, T, stage_sizes=sizes)
        errs = []
        for t in range(T):
            lg, cache = m.decode_step(p2, cache, batch["tokens"][:, t:t + 1],
                                      jnp.asarray(t, jnp.int32))
            errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
        assert max(errs) < 2e-3, errs


class TestValueErrorsSurviveOptimizedMode:
    """Plan/shape invariants raise ValueError, never bare assert."""

    def test_ir_round_size_mismatch_is_value_error(self):
        cfg, m, params, batch, sds = _setup(batch=6)
        p = _dp_plan_133(schedule="1f1b", n_microbatches=4)
        state = pipeline_stream.make_ir_state(m, params, sds, plan=p)
        step = pipeline_stream.make_ir_train_step(
            m, plan=p, mode="spectrain", lr=0.05)
        with pytest.raises(ValueError, match="round size"):
            step(state, batch)   # 6 % 4 != 0

    def test_ticks_per_step_mismatch_is_value_error(self):
        cfg, m, params, batch, sds = _setup(batch=6)
        with pytest.raises(ValueError, match="ticks_per_step"):
            pipeline_stream.make_state(m, params, sds, ticks_per_step=4)

    def test_empty_stage_still_rejected(self):
        with pytest.raises(ValueError, match="empty"):
            Model(tiny_cfg("granite-8b", n_layers=2, pipe=3))
