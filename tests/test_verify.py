"""Static schedule verifier (``planner/verify.py``).

Three layers of evidence that the verifier earns its place as a
default-on construction gate:

  * **Clean grid** — every plan the runtimes execute ({1f1b, 2bw,
    interleaved, gpipe} x S x partitions) verifies with zero
    violations, for both compiled artifacts.
  * **Power** — the mutation harness: every catalogued single-row
    corruption of a valid artifact is flagged with the *named* check
    class, with at least three distinct corruptions per acceptance
    class (slot hazard, comm mismatch, wv-lag, double-contribution,
    completeness).
  * **Generality** — randomized plans (seeded fallback always; a
    hypothesis property when installed) compile and verify clean, so
    the invariants hold beyond the enumerated grid.
"""
import numpy as np
import pytest

from conftest import optional_hypothesis
from repro.planner import plan, synthetic_profile
from repro.planner import schedule_ir as sir
from repro.planner import verify as pv

given, settings, st = optional_hypothesis()

SCHEDULES = ("1f1b", "2bw", "interleaved", "gpipe")


def _plan(schedule, S, v=1, M=None, ragged=False):
    C = S * v
    L = 2 * C
    costs = [1.0 + 0.5 * (i % 3) for i in range(L)] if ragged \
        else [1.0] * L
    kw = {"n_microbatches": M} if M else {}
    return plan(profile=synthetic_profile(costs), n_stages=S,
                schedule=schedule, virtual_stages=v,
                partitioner="dp" if ragged else "uniform", **kw)


_CANON = _plan("1f1b", 3)
_MUTS = list(pv.mutation_catalog(_CANON.event_table(),
                                 _CANON.device_streams()))
_KW = dict(schedule=_CANON.schedule, act_stash=_CANON.act_stash,
           w_stash_depth=_CANON.w_stash_depth)


def _verify_artifact(artifact, kw=_KW):
    if isinstance(artifact, sir.EventTable):
        return pv.verify_event_table(artifact, **kw)
    return pv.verify_device_streams(artifact, **kw)


# ===========================================================================
# clean grid
# ===========================================================================


class TestCleanGrid:
    @pytest.mark.parametrize("schedule", SCHEDULES)
    @pytest.mark.parametrize("S", [2, 3, 4])
    @pytest.mark.parametrize("ragged", [False, True])
    def test_grid_plans_verify_clean(self, schedule, S, ragged):
        v = 2 if schedule == "interleaved" else 1
        p = _plan(schedule, S, v=v, ragged=ragged)
        reports = pv.verify_plan(p)
        assert len(reports) == 2
        for r in reports:
            assert r.ok, [str(x) for x in r.violations]
        C, M = p.n_chunks, p.round_microbatches
        assert all(r.n_events == 2 * M * C for r in reports)

    def test_resource_stats_match_allocators(self):
        p = _plan("interleaved", 2, v=2)
        table = p.event_table()
        streams = p.device_streams()
        rt, rs = pv.verify_plan(p)
        assert rt.stats["peak_val"] == table.n_val_slots
        assert rt.stats["peak_cot"] == table.n_cot_slots
        assert rt.stats["stash_peak"] == p.act_stash
        assert rs.stats["peak_val"] == streams.n_val_slots
        assert rs.stats["peak_cot"] == streams.n_cot_slots

    def test_single_device_ring_verifies(self):
        # S=1 collapses the ppermute ring to self-receives
        for schedule in SCHEDULES:
            v = 2 if schedule == "interleaved" else 1
            pv.check_plan(_plan(schedule, 1, v=v))

    def test_closed_form_lags(self):
        assert pv.expected_lag("gpipe", 0, 4, "forward") == 0
        assert pv.expected_lag("1f1b", 2, 4, "backward") == 0
        assert pv.expected_lag("2bw", 1, 4, "forward") == 1
        with pytest.raises(KeyError, match="stream"):
            pv.expected_lag("stream", 0, 4, "forward")


# ===========================================================================
# mutation harness: the checks have power
# ===========================================================================


class TestMutationHarness:
    @pytest.mark.parametrize(
        "name,check,artifact", _MUTS, ids=[m[0] for m in _MUTS])
    def test_single_row_corruption_is_flagged(self, name, check, artifact):
        report = _verify_artifact(artifact)
        got = {v.check for v in report.violations}
        assert check in got, (
            f"{name}: expected a {check!r} violation, got "
            f"{sorted(got) or 'a clean report'}")
        for v in report.violations:
            assert v.check in pv.CHECKS
            assert v.site and v.message

    def test_at_least_three_corruptions_per_acceptance_class(self):
        by_class = {}
        for name, check, _ in _MUTS:
            by_class.setdefault(check, []).append(name)
        for cls in ("slot-hazard", "comm-mismatch", "wv-lag",
                    "double-contribution", "completeness"):
            assert len(by_class.get(cls, [])) >= 3, (cls, by_class)

    @pytest.mark.parametrize("schedule,S,v", [
        ("2bw", 4, 1), ("interleaved", 2, 2), ("gpipe", 2, 1)])
    def test_harness_holds_across_schedules(self, schedule, S, v):
        n, failures = pv.self_test(_plan(schedule, S, v=v))
        assert not failures, failures
        assert n >= 15

    def test_diagnostics_are_specific(self):
        # the clobber mutation must name both values and the slot
        name, check, bad = next(
            m for m in _MUTS if m[0] == "table/fwd-write-clobbers-stash")
        report = _verify_artifact(bad)
        msgs = [v.message for v in report.violations
                if v.check == "slot-hazard"]
        assert any("clobbers live" in m and "slot" in m for m in msgs)

    def test_raise_on_violation(self):
        _, _, bad = next(m for m in _MUTS if m[1] == "slot-hazard")
        report = _verify_artifact(bad)
        with pytest.raises(pv.VerificationError, match="slot-hazard"):
            report.raise_on_violation()


# ===========================================================================
# plan-level integration
# ===========================================================================


class TestPlanIntegration:
    def test_plan_verify_is_default_on_in_step_construction(self):
        import jax
        from conftest import tiny_cfg
        from repro.core import pipeline_stream
        from repro.models import Model
        p = _plan("1f1b", 2)
        m = Model(tiny_cfg("granite-8b", n_layers=4, pipe=2))
        # verify=True (default) and verify=False must both construct
        for verify in (True, False):
            step = pipeline_stream.make_ir_train_step(
                m, plan=p, mode="spectrain", lr=0.05, verify=verify)
            assert callable(step)
        state = pipeline_stream.make_ir_state(
            m, m.init(jax.random.PRNGKey(0)), None, plan=p)
        assert "params" in state

    def test_non_round_schedules_validate_timeline_only(self):
        p = plan(profile=synthetic_profile([1.0] * 4), n_stages=2,
                 schedule="stream")
        (report,) = pv.verify_plan(p)
        assert report.artifact == "schedule" and report.ok
        p.verify()   # must not raise

    def test_check_plan_clean(self):
        pv.check_plan(_CANON)
        _CANON.verify()

    def test_cli_self_test(self):
        rc = pv.main(["--schedule", "2bw", "--stages", "2",
                      "--self-test", "-q"])
        assert rc == 0

    def test_cli_ragged(self):
        rc = pv.main(["--schedule", "interleaved", "--stages", "2",
                      "--virtual-stages", "2", "--ragged", "-q"])
        assert rc == 0


# ===========================================================================
# fuzz: random plans -> compile -> verify
# ===========================================================================


def _fuzz_one(schedule, S, v, k, extra_layers):
    v = v if schedule == "interleaved" else 1
    C = S * v
    M = k * S
    try:
        p = plan(profile=synthetic_profile(
            [1.0 + 0.25 * (i % 4) for i in range(2 * C + extra_layers)]),
            n_stages=S, schedule=schedule, virtual_stages=v,
            partitioner="dp", n_microbatches=M)
    except ValueError:
        return   # schedule-specific M/S constraint: not a compile bug
    for report in pv.verify_plan(p):
        assert report.ok, (schedule, S, v, M,
                           [str(x) for x in report.violations])


class TestFuzz:
    def test_seeded_random_plans_verify_clean(self):
        rng = np.random.default_rng(0)
        for _ in range(30):
            _fuzz_one(SCHEDULES[rng.integers(len(SCHEDULES))],
                      int(rng.integers(1, 5)), int(rng.integers(1, 4)),
                      int(rng.integers(1, 4)), int(rng.integers(0, 5)))

    @given(schedule=st.sampled_from(SCHEDULES),
           S=st.integers(min_value=1, max_value=4),
           v=st.integers(min_value=1, max_value=3),
           k=st.integers(min_value=1, max_value=3),
           extra_layers=st.integers(min_value=0, max_value=4))
    @settings(max_examples=40, deadline=None)
    def test_random_plans_verify_clean(self, schedule, S, v, k,
                                       extra_layers):
        _fuzz_one(schedule, S, v, k, extra_layers)
