"""True MPMD execution: stage-local weights, bitwise-identical training.

Three layers of evidence for ``execution="mpmd"`` in
``core/pipeline_stream.make_ir_train_step``:

  * **Device streams** — lowering the round event table to per-device
    int32 streams is structurally sound: every device runs T ticks,
    branch ids index the stream's branch set (or the NOP), receive
    slots index the pools, and the tick grouping used by the tracer
    covers every compute event exactly once.
  * **Bit identity** — the shard_map round (stage weights resident
    only on their pipe device, activations/cotangents crossing stage
    cuts via ppermute) is bitwise identical to the SPMD scan backend
    (losses and every state leaf) over {1f1b, 2bw, interleaved,
    gpipe} × ragged DP partitions in spectrain and pipedream modes.
    S = 1 cases run the same ring machinery on a single device, so the
    identity holds in plain single-device CI too.
  * **Gates** — unsupported combinations (clip, hybrid stage trees,
    meshes that do not match the plan) fail loudly, not wrongly.
"""
import numpy as np
import pytest

import jax

from conftest import lm_batch, tiny_cfg
from repro.core import pipeline_stream
from repro.models import Model
from repro.models.model import unpack_chunk_params
from repro.planner import plan, synthetic_profile
from repro.planner import schedule_ir as sir


def _skew(L):
    return [9.0] + [1.0] * (L - 1)


def _mk_plan(schedule, S, v=1, M=4, L=4, partitioner="dp"):
    return plan(profile=synthetic_profile(_skew(L)), n_stages=S,
                schedule=schedule, virtual_stages=v, n_microbatches=M,
                partitioner=partitioner)


def _run(exec_, p, mode, steps=2, lr=0.05):
    cfg = tiny_cfg("granite-8b", n_layers=p.partition.n_layers,
                   pipe=p.n_stages)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg,
                     batch=2 * p.round_microbatches, seq=8)
    state = pipeline_stream.make_ir_state(m, params, None, plan=p,
                                          mode=mode, execution=exec_)
    step = jax.jit(pipeline_stream.make_ir_train_step(
        m, plan=p, mode=mode, lr=lr, execution=exec_))
    losses = []
    for _ in range(steps):
        state, met = step(state, batch)
        losses.append(np.asarray(met["loss"]))
    return losses, state


def _assert_states_match(mpmd_state, spmd_state):
    """Unpack the packed stage leaves and require every corresponding
    leaf bit-equal to the SPMD state's ragged chunk trees."""
    sizes = np.asarray(mpmd_state["chunk_sizes"])

    def cmp_tree(pm, ps):
        chunks = unpack_chunk_params(pm["stages"], sizes)
        for q in range(len(sizes)):
            for a, b in zip(jax.tree.leaves(chunks[q]),
                            jax.tree.leaves(ps["stages"][q])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(pm["outer"]),
                        jax.tree.leaves(ps["outer"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    cmp_tree(mpmd_state["params"], spmd_state["params"])
    cmp_tree(mpmd_state["momentum"], spmd_state["momentum"])
    assert ("stash" in mpmd_state) == ("stash" in spmd_state)
    if "stash" in spmd_state:
        cmp_tree(mpmd_state["stash"]["params"],
                 spmd_state["stash"]["params"])
        cmp_tree(mpmd_state["stash"]["momentum"],
                 spmd_state["stash"]["momentum"])
    assert int(mpmd_state["step"]) == int(spmd_state["step"])


# ===========================================================================
# device-stream lowering
# ===========================================================================


class TestDeviceStreams:
    @pytest.mark.parametrize("schedule,S,v,M", [
        ("1f1b", 2, 1, 4), ("1f1b", 4, 1, 8), ("gpipe", 3, 1, 6),
        ("2bw", 3, 1, 6), ("interleaved", 2, 2, 4),
    ])
    def test_structure(self, schedule, S, v, M):
        p = _mk_plan(schedule, S, v=v, M=M, L=S * v)
        ds = p.device_streams()
        T = ds.rows.shape[0]
        assert ds.rows.shape == (T, S, sir.DN_COLS)
        assert ds.rows.dtype == np.int32
        nop = len(ds.branches)
        assert (ds.rows[:, :, sir.DCOL_BRANCH] <= nop).all()
        # every compute event of the round appears exactly once
        C = p.n_chunks
        assert (ds.rows[:, :, sir.DCOL_BRANCH] < nop).sum() == 2 * M * C
        # receive slots index the pools (or -1 = discard)
        assert (ds.rows[:, :, sir.DCOL_RECV_F] < ds.n_val_slots).all()
        assert (ds.rows[:, :, sir.DCOL_RECV_B] < ds.n_cot_slots).all()
        assert (ds.rows[:, :, sir.DCOL_RECV_F] >= -1).all()
        assert (ds.rows[:, :, sir.DCOL_RECV_B] >= -1).all()
        # the head/embed first-contribution markers appear exactly once
        assert (ds.rows[:, :, sir.DCOL_FIRST_O] > 0).sum() == 1
        assert (ds.rows[:, :, sir.DCOL_FIRST_E] > 0).sum() == 1

    def test_deterministic(self):
        a = _mk_plan("1f1b", 3, M=6, L=6).device_streams()
        b = _mk_plan("1f1b", 3, M=6, L=6).device_streams()
        assert a.branches == b.branches
        np.testing.assert_array_equal(a.rows, b.rows)

    def test_tick_groups_cover_events(self):
        from repro.obs import device_stream_tick_groups, round_event_metas
        for schedule, S, v in (("1f1b", 2, 1), ("2bw", 3, 1),
                               ("interleaved", 2, 2)):
            p = _mk_plan(schedule, S, v=v, M=2 * S, L=2 * S * v)
            groups = device_stream_tick_groups(p)
            assert len(groups) == p.device_streams().rows.shape[0]
            flat = sorted(i for g in groups for i in g)
            assert flat == list(range(len(round_event_metas(p))))


# ===========================================================================
# bit identity vs the SPMD scan backend
# ===========================================================================


class TestMpmdBitIdentity:
    @pytest.mark.parametrize("schedule,S,v,M,L", [
        ("1f1b", 2, 1, 4, 4),
        ("1f1b", 3, 1, 3, 5),
        ("2bw", 2, 1, 4, 4),
        ("2bw", 3, 1, 3, 5),
        ("interleaved", 2, 2, 4, 4),
        ("interleaved", 3, 2, 3, 6),
        ("gpipe", 2, 1, 4, 4),
    ])
    @pytest.mark.parametrize("mode", ["spectrain", "pipedream"])
    def test_mpmd_matches_scan_bitwise(self, schedule, S, v, M, L, mode):
        """The acceptance criterion: stage-local MPMD execution is
        bit-for-bit the same training as the replicated SPMD scan on
        ragged DP-partitioned plans."""
        if jax.device_count() < S:
            pytest.skip(f"needs >= {S} devices "
                        f"(XLA_FLAGS=--xla_force_host_platform_"
                        f"device_count={S})")
        p = _mk_plan(schedule, S, v=v, M=M, L=L)
        if v == 1 and schedule != "gpipe":
            assert len(set(p.partition.sizes())) > 1, \
                "sweep must exercise a ragged partition"
        ls, ss = _run("spmd", p, mode)
        lm, sm = _run("mpmd", p, mode)
        for a, b in zip(ls, lm):
            assert a.tobytes() == b.tobytes(), (a, b)
        _assert_states_match(sm, ss)

    @pytest.mark.parametrize("schedule,v", [
        ("1f1b", 1), ("2bw", 1), ("interleaved", 2), ("gpipe", 1),
    ])
    def test_single_device_ring_bitwise(self, schedule, v):
        """S = 1 folds every chunk onto one device: the ppermute rings
        degenerate to same-tick self-receives, and the identity must
        still hold — this is the tier-1 (single-device CI) coverage."""
        p = _mk_plan(schedule, 1, v=v, M=4, L=4, partitioner="uniform")
        ls, ss = _run("spmd", p, "spectrain")
        lm, sm = _run("mpmd", p, "spectrain")
        for a, b in zip(ls, lm):
            assert a.tobytes() == b.tobytes(), (a, b)
        _assert_states_match(sm, ss)

    def test_traced_step_matches_and_guards(self):
        """The per-tick traced variant (tracer set) trains bitwise the
        same as the untraced mpmd step, records every round, and
        refuses an outer jit."""
        from repro.obs import PipelineTracer, device_stream_tick_groups
        p = _mk_plan("1f1b", 1, M=4, L=4, partitioner="uniform")
        cfg = tiny_cfg("granite-8b", n_layers=4, pipe=1)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = lm_batch(jax.random.PRNGKey(1), cfg,
                         batch=2 * p.round_microbatches, seq=8)
        tracer = PipelineTracer(p)
        tracer.set_tick_groups(device_stream_tick_groups(p))
        state = pipeline_stream.make_ir_state(m, params, None, plan=p,
                                              mode="spectrain",
                                              execution="mpmd")
        step = tracer.wrap_step(pipeline_stream.make_ir_train_step(
            m, plan=p, mode="spectrain", lr=0.05, execution="mpmd",
            tracer=tracer))
        losses = []
        for _ in range(2):
            state, met = step(state, batch)
            losses.append(np.asarray(met["loss"]))
        assert tracer.dropped_rounds == 0 and len(tracer.rounds) == 2
        lm, _sm = _run("mpmd", p, "spectrain")
        for a, b in zip(losses, lm):
            assert a.tobytes() == b.tobytes(), (a, b)
        bad = jax.jit(pipeline_stream.make_ir_train_step(
            m, plan=p, mode="spectrain", lr=0.05, execution="mpmd",
            tracer=tracer))
        with pytest.raises(ValueError, match="outer jax.jit"):
            bad(state, batch)


# ===========================================================================
# gates
# ===========================================================================


class TestMpmdGates:
    def _model(self, L=4, pipe=1):
        cfg = tiny_cfg("granite-8b", n_layers=L, pipe=pipe)
        return Model(cfg)

    def test_unknown_exec_rejected(self):
        p = _mk_plan("1f1b", 1, partitioner="uniform")
        with pytest.raises(ValueError, match="execution"):
            pipeline_stream.make_ir_train_step(
                self._model(), plan=p, mode="spectrain", lr=0.05,
                execution="simd")

    def test_clip_not_supported(self):
        p = _mk_plan("1f1b", 1, partitioner="uniform")
        with pytest.raises(NotImplementedError, match="clip"):
            pipeline_stream.make_ir_train_step(
                self._model(), plan=p, mode="spectrain", lr=0.05,
                execution="mpmd", clip=1.0)

    def test_mesh_must_match_plan(self):
        from jax.sharding import Mesh
        p = _mk_plan("1f1b", 1, partitioner="uniform")
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
        with pytest.raises(ValueError, match="pipe"):
            pipeline_stream.make_ir_train_step(
                self._model(), plan=p, mode="spectrain", lr=0.05,
                execution="mpmd", mesh=mesh)

    def test_stage_submeshes_raises_without_pipe(self):
        from jax.sharding import Mesh
        from repro.runtime import sharding as sh
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
        with pytest.raises(ValueError, match="pipe"):
            sh.stage_submeshes(mesh, 2)


# ===========================================================================
# CLI
# ===========================================================================


class TestCLIExecFlag:
    def test_mpmd_backend_trains(self):
        from repro.launch import train
        rc = train.main([
            "--arch", "granite-8b", "--smoke", "--pipe", "1",
            "--layers", "4", "--steps", "2", "--batch", "8",
            "--seq", "16", "--log-every", "1",
            "--schedule", "1f1b", "--execution", "mpmd"])
        assert rc == 0

    def test_mpmd_rejects_stream_and_clip(self):
        from repro.launch import train
        with pytest.raises(SystemExit):
            train.main(["--smoke", "--schedule", "stream",
                        "--execution", "mpmd"])
        with pytest.raises(SystemExit):
            train.main(["--smoke", "--schedule", "1f1b", "--pipe", "1",
                        "--execution", "mpmd", "--clip", "1.0"])
