"""Profile-guided pipeline planner: DP partitioner, schedule IR, and the
acceptance property — IR-derived staleness == the closed forms trusted by
``core/spectrain.py``, and plans round-trip through both runtimes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import lm_batch, tiny_cfg
from repro.core import pipeline_stream
from repro.core import spectrain as st
from repro.core.simulator import Simulator, make_mlp_staged
from repro.models import Model
from repro.planner import (PipelinePlan, Schedule, check_against_closed_forms,
                           dp_split, plan, profile_model, synthetic_profile,
                           uniform)
from repro.planner import schedule_ir as ir
from repro.planner.partition import bottleneck, partition_profile

NS = (2, 3, 4, 8)


# ===========================================================================
# partition
# ===========================================================================


class TestPartition:
    def test_uniform_split(self):
        assert uniform(8, 4).boundaries == (0, 2, 4, 6, 8)
        assert uniform(10, 4).sizes() == (3, 3, 2, 2)
        assert uniform(4, 4).sizes() == (1, 1, 1, 1)
        with pytest.raises(ValueError):
            uniform(3, 4)

    def test_dp_on_balanced_profile_matches_uniform(self):
        comp, cut = [1.0] * 8, [0.0] * 8
        part = dp_split(comp, cut, 4)
        assert part.sizes() == (2, 2, 2, 2)

    @pytest.mark.parametrize("n_stages", NS)
    def test_dp_beats_uniform_on_skewed_profiles(self, n_stages):
        """The PipeDream claim: profiling + DP strictly beats the
        equal-layer-count split when the stack is imbalanced."""
        rng = np.random.default_rng(n_stages)
        L = 4 * n_stages
        comp = [1.0] * L
        # heavy run sitting (mostly) inside the first uniform stage —
        # the equal-count split eats it whole, DP spreads it out
        for j in range(L // n_stages):
            comp[1 + j] = 6.0
        cut = list(rng.uniform(0.0, 0.2, L))
        part = dp_split(comp, cut, n_stages)
        dp_cost = bottleneck(comp, cut, part)
        u_cost = bottleneck(comp, cut, uniform(L, n_stages))
        assert dp_cost < u_cost, (n_stages, dp_cost, u_cost)

    def test_dp_is_optimal_vs_bruteforce(self):
        """Exact bottleneck optimality on small instances."""
        import itertools
        rng = np.random.default_rng(0)
        for trial in range(10):
            L, S = 7, 3
            comp = list(rng.uniform(0.5, 4.0, L))
            cut = list(rng.uniform(0.0, 1.0, L))
            part = dp_split(comp, cut, S)
            got = bottleneck(comp, cut, part)
            best = min(
                bottleneck(comp, cut,
                           ir_part := type(part)((0,) + b + (L,)))
                for b in itertools.combinations(range(1, L), S - 1))
            assert got == pytest.approx(best), (trial, got, best)

    def test_dp_respects_cut_cost(self):
        """Huge transfer cost at one boundary: DP must avoid cutting
        there even at some compute-imbalance price."""
        comp = [1.0] * 6
        cut = [0.0, 100.0, 0.0, 0.0, 0.0, 0.0]
        part = dp_split(comp, cut, 2)
        assert 2 not in part.boundaries

    def test_partition_profile_roundtrip(self):
        prof = synthetic_profile([1, 1, 8, 8, 1, 1])
        assert partition_profile(prof, 3, method="dp").n_stages == 3
        assert partition_profile(prof, 3, method="uniform").sizes() == \
            (2, 2, 2)
        with pytest.raises(ValueError):
            partition_profile(prof, 3, method="nope")


# ===========================================================================
# schedule IR
# ===========================================================================


class TestScheduleIR:
    @pytest.mark.parametrize("n", NS)
    def test_paper_schedule_staleness_matches_eq5_eq6(self, n):
        """Acceptance criterion: IR-derived (s_fwd, s_bwd) of the
        round-robin emitter equal version_difference_paper for every
        stage at N in {2,3,4,8}."""
        sched = ir.round_robin_1f1b(n)
        for k in range(n):
            for phase in ("forward", "backward"):
                assert sched.staleness(k, phase) == \
                    st.version_difference_paper(k, n, phase), (n, k, phase)

    @pytest.mark.parametrize("n", NS)
    def test_stream_schedule_staleness_matches_closed_form(self, n):
        sched = ir.streaming(n)
        for k in range(n):
            for phase in ("forward", "backward"):
                assert sched.staleness(k, phase) == \
                    st.version_difference_stream(k, n, phase), (n, k, phase)

    @pytest.mark.parametrize("n", NS)
    def test_gpipe_is_staleness_free(self, n):
        sched = ir.gpipe(n)
        for k in range(n):
            assert sched.staleness(k, "forward") == 0
            assert sched.staleness(k, "backward") == 0

    @pytest.mark.parametrize("name", sorted(ir.EMITTERS))
    @pytest.mark.parametrize("n", (1, 2, 4))
    def test_dataflow_valid(self, name, n):
        """Activations/cotangents always produced before consumed, and
        every gradient applies after its own backward completes."""
        ir.emit(name, n).validate()

    @pytest.mark.parametrize("n", NS)
    def test_stream_lags_match_runtime_constants(self, n):
        """Injection→backward distance is 2(N−1)−k (warm-up gating and
        batch-ring reads) and the same-stage fwd→bwd gap is 2(N−1−k)
        (stash-ring gather offsets) — the two constant vectors
        ``core/pipeline_stream.py`` is built around."""
        sched = ir.streaming(n)
        for k in range(n):
            assert sched.bwd_lag(k) == 2 * (n - 1) - k
            assert sched.fwd_bwd_gap(k) == 2 * (n - 1 - k)

    def test_staleness_is_warmup_dependent(self):
        """Early minibatches read the initial weights — the closed forms
        only hold in steady state, which is exactly why the IR picks a
        steady minibatch."""
        sched = ir.round_robin_1f1b(4)
        assert sched.staleness(0, "forward", mb=0) == 0
        assert sched.staleness(0, "forward") == 3

    def test_render_and_queries(self):
        sched = ir.streaming(2, n_ticks=20)
        out = sched.render(max_ticks=6)
        assert out.count("\n") == 1 and "f0" in out
        assert sched.makespan() == 20
        bad = Schedule("bad", 2, [ir.Event(ir.FWD, 0, stage=1, mb=0),
                                  ir.Event(ir.FWD, 1, stage=0, mb=0),
                                  ir.Event(ir.BWD, 2, stage=1, mb=0),
                                  ir.Event(ir.BWD, 3, stage=0, mb=0),
                                  ir.Event(ir.UPDATE, 4, stages=(0, 1),
                                           mbs=(0,))])
        with pytest.raises(ValueError, match="timeline too short"):
            bad.steady_minibatch()


# ===========================================================================
# plan() API
# ===========================================================================


class TestPlanAPI:
    @pytest.mark.parametrize("schedule", sorted(ir.EMITTERS))
    @pytest.mark.parametrize("n", NS)
    def test_plan_matches_closed_forms(self, schedule, n):
        p = plan(n_layers=2 * n, n_stages=n, schedule=schedule)
        assert isinstance(p, PipelinePlan)
        check_against_closed_forms(p)

    def test_plan_from_config_profiles_and_partitions(self):
        cfg = tiny_cfg("granite-8b", n_layers=4, pipe=2)
        p = plan(cfg, n_stages=2, schedule="stream",
                 profile_method="analytic")
        assert p.partition.n_layers == 4
        assert p.s_fwd == (2, 0) and p.s_bwd == (0, 0)
        assert p.bwd_lag == (2, 1) and p.fb_gap == (2, 0)
        assert p.ring_slots == 3
        assert p.profile.method == "analytic"
        assert "stream" in p.summary()

    def test_plan_hlo_profile_counts_real_flops(self):
        cfg = tiny_cfg("granite-8b", n_layers=2, pipe=2)
        prof = profile_model(cfg, method="hlo", batch=1, seq=8)
        assert prof.method == "hlo"
        # at least the block's two attention projections + MLP matmuls
        assert prof.layers[0].flops > 1e4
        assert prof.n_layers == 2

    def test_plan_reports_dp_win(self):
        prof = synthetic_profile([1, 1, 1, 9, 9, 1, 1, 1])
        p = plan(profile=prof, n_stages=4, partitioner="dp")
        assert p.bottleneck_s < p.uniform_bottleneck_s

    def test_plan_errors(self):
        with pytest.raises(KeyError):
            plan(n_layers=4, n_stages=2, schedule="zigzag")
        with pytest.raises(ValueError):
            plan(n_layers=2, n_stages=4)


# ===========================================================================
# round-trip: simulator
# ===========================================================================


def _data_iter(seed, batch=16, in_dim=8, classes=4):
    k = jax.random.PRNGKey(seed)
    wtrue = jax.random.normal(jax.random.PRNGKey(99), (in_dim, classes))
    while True:
        k, k1 = jax.random.split(k)
        x = jax.random.normal(k1, (batch, in_dim))
        yield {"x": x, "y": jnp.argmax(x @ wtrue, -1)}


class TestSimulatorRoundTrip:
    @pytest.mark.parametrize("scheme", Simulator.SCHEMES)
    def test_default_plan_reproduces_planless_simulator(self, scheme):
        """Acceptance criterion: Simulator(plan=round-robin plan) must be
        step-for-step identical to the hardcoded-formula simulator."""
        n = 4
        fns, params = make_mlp_staged(
            jax.random.PRNGKey(0), in_dim=8, width=16, depth=4,
            n_classes=4, n_stages=n)
        p = plan(n_layers=n, n_stages=n, schedule="1f1b_rr")
        sim_plan = Simulator(fns, params, plan=p, scheme=scheme, lr=0.05)
        sim_ref = Simulator(fns, params, n_stages=n, scheme=scheme, lr=0.05)
        it1, it2 = _data_iter(0), _data_iter(0)
        for _ in range(12):
            m1 = sim_plan.step(next(it1))
            m2 = sim_ref.step(next(it2))
            assert m1["loss"] == m2["loss"]
        for a, b in zip(jax.tree.leaves(sim_plan.params),
                        jax.tree.leaves(sim_ref.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_stream_plan_through_simulator(self):
        """Arbitrary-schedule support: the simulator executes the
        streaming schedule's staleness structure and still converges."""
        n = 4
        fns, params = make_mlp_staged(
            jax.random.PRNGKey(0), in_dim=8, width=16, depth=4,
            n_classes=4, n_stages=n)
        p = plan(n_layers=n, n_stages=n, schedule="stream")
        sim = Simulator(fns, params, plan=p, scheme="spectrain", lr=0.05)
        it = _data_iter(0)
        losses = [sim.step(next(it))["loss"] for _ in range(60)]
        assert np.isfinite(losses).all()
        assert np.mean(losses[-10:]) < np.mean(losses[:10])

    def test_plan_stage_mismatch_raises(self):
        fns, params = make_mlp_staged(
            jax.random.PRNGKey(0), in_dim=8, width=16, depth=4,
            n_classes=4, n_stages=4)
        p = plan(n_layers=4, n_stages=2)
        with pytest.raises(ValueError):
            Simulator(fns, params, n_stages=4, plan=p)
        with pytest.raises(ValueError):
            Simulator(fns, params)  # neither n_stages nor plan


# ===========================================================================
# round-trip: streaming pipeline runtime
# ===========================================================================


class TestStreamRuntimeRoundTrip:
    def test_stream_plan_reproduces_planless_runtime(self):
        """pipeline_stream under an explicit stream plan is bit-identical
        to the closed-form constants it replaces."""
        cfg = tiny_cfg("granite-8b", n_layers=4, pipe=2)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=4, seq=16)
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
        p = plan(cfg, n_stages=2, schedule="stream",
                 profile_method="analytic")

        s1 = pipeline_stream.make_state(m, params, sds, plan=p)
        f1 = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.05, plan=p))
        s2 = pipeline_stream.make_state(m, params, sds)
        f2 = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.05))
        for _ in range(6):
            s1, m1 = f1(s1, batch)
            s2, m2 = f2(s2, batch)
            assert float(m1["loss"]) == float(m2["loss"])
        for a, b in zip(jax.tree.leaves(s1["params"]),
                        jax.tree.leaves(s2["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_non_stream_plan_rejected(self):
        """Both state construction and step construction must reject a
        non-stream plan — otherwise the plan's smaller ring sizes would
        silently corrupt the stash gathers."""
        cfg = tiny_cfg("granite-8b", n_layers=4, pipe=2)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            lm_batch(jax.random.PRNGKey(1), cfg, batch=4, seq=16))
        p = plan(cfg, n_stages=2, schedule="1f1b_rr",
                 profile_method="analytic")
        with pytest.raises(ValueError, match="stream"):
            pipeline_stream.make_train_step(m, mode="spectrain", lr=0.05,
                                            plan=p)
        with pytest.raises(ValueError, match="stream"):
            pipeline_stream.make_state(m, params, sds, plan=p)

    def test_plan_profiles_at_run_shape(self):
        """batch/seq forwarded into the profile (the printed bottleneck
        describes the shapes the run executes)."""
        cfg = tiny_cfg("granite-8b", n_layers=4, pipe=2)
        p8 = plan(cfg, n_stages=2, schedule="stream", batch=1, seq=8)
        p64 = plan(cfg, n_stages=2, schedule="stream", batch=1, seq=64)
        assert p64.profile.seq == 64 and p8.profile.seq == 8
        assert p64.bottleneck_s > p8.bottleneck_s
