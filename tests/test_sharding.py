"""Logical-axis sharding rules, HLO cost model, MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import tiny_cfg
from repro.configs import get_config, list_archs
from repro.models import Model
from repro.runtime.sharding import spec_for_leaf


SIZES = {"data": 16, "pipe": 4, "tensor": 4}
RULES = {"stage": "pipe", "embed": None, "heads": "tensor",
         "mlp": "tensor", "expert": "tensor", "vocab": "tensor",
         "act_batch": ("data",), "layer": None}


class TestSpecForLeaf:
    def test_basic_mapping(self):
        spec = spec_for_leaf(("stage", "layer", "embed", "mlp"),
                             (4, 9, 4096, 14336), RULES, SIZES)
        assert spec == P("pipe", None, None, "tensor")

    def test_divisibility_drop(self):
        # 14338 % 4 != 0 -> mlp assignment dropped
        spec = spec_for_leaf(("embed", "mlp"), (4096, 14338), RULES, SIZES)
        assert spec == P()

    def test_conflict_keeps_first(self):
        # expert and mlp both -> tensor; only the first dim gets it
        spec = spec_for_leaf(("expert", "embed", "mlp"), (8, 4096, 32768),
                             RULES, SIZES)
        assert spec == P("tensor")  # trailing Nones are trimmed

    def test_tuple_axis(self):
        spec = spec_for_leaf(("act_batch", None, None), (256, 128, 64),
                             RULES, SIZES)
        assert spec == P("data")

    def test_small_dim_replicated(self):
        spec = spec_for_leaf(("heads",), (2,), RULES, SIZES)
        assert spec == P()


@pytest.mark.parametrize("name", list(list_archs()))
def test_arch_param_specs_valid(name):
    """Every full-size param leaf gets a consistent PartitionSpec on the
    production logical mesh sizes (no axis reuse; divisibility holds)."""
    from repro.configs.base import MeshPlan
    from repro.runtime.sharding import logical_rules
    cfg = get_config(name)
    plan = cfg.mesh_plan

    class FakeMesh:
        axis_names = ("data", "pipe", "tensor")
        devices = np.empty((16, plan.pipe, plan.tensor), object)

    rules = logical_rules(cfg, FakeMesh())
    m = Model(cfg)
    axes = m.param_axes()
    sds = m.param_sds()
    sizes = {"data": 16, "pipe": plan.pipe, "tensor": plan.tensor}

    def check(ax, leaf):
        spec = spec_for_leaf(ax, leaf.shape, rules, sizes)
        used = [s for s in spec if s is not None]
        flat = []
        for s in used:
            flat.extend(s if isinstance(s, tuple) else (s,))
        assert len(flat) == len(set(flat)), (ax, spec)
        for dim, s in zip(leaf.shape, spec):
            if s is None:
                continue
            names = s if isinstance(s, tuple) else (s,)
            prod = int(np.prod([sizes[n] for n in names]))
            assert dim % prod == 0, (ax, leaf.shape, spec)

    jax.tree.map(check, axes, sds,
                 is_leaf=lambda x: isinstance(x, tuple) and all(
                     isinstance(a, (str, type(None))) for a in x))


class TestHloCost:
    def test_scan_trip_count_correction(self):
        """The whole reason hlo_cost exists: XLA's cost_analysis counts a
        4-iteration scan body once; ours multiplies by the trip count."""
        from repro.runtime.hlo_cost import analyze
        d = 128
        w = jax.ShapeDtypeStruct((4, d, d), jnp.float32)
        x = jax.ShapeDtypeStruct((8, d), jnp.float32)

        def f(w, x):
            def body(c, wi):
                return jnp.tanh(c @ wi), None
            y, _ = jax.lax.scan(body, x, w)
            return jnp.sum(y)

        comp = jax.jit(f).lower(w, x).compile()
        r = analyze(comp.as_text())
        dot_flops = 4 * 2 * 8 * d * d
        assert dot_flops <= r["flops"] <= dot_flops * 1.5
        assert r["transcendentals"] == pytest.approx(4 * 8 * d)

    def test_collective_wire_model(self):
        from repro.runtime.hlo_cost import analyze
        txt = """
HloModule m

ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  %ar = f32[64]{0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %cp = f32[64]{0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
        r = analyze(txt)
        ar = r["collectives"]["all-reduce"]
        assert ar["count"] == 1
        assert ar["wire_bytes"] == pytest.approx(2 * 256 * 3 / 4)
        cp = r["collectives"]["collective-permute"]
        assert cp["wire_bytes"] == pytest.approx(256)


class TestMoEDispatch:
    def test_capacity_bound_and_combine_weights(self):
        from repro.models import moe as moe_mod
        cfg = tiny_cfg("deepseek-moe-16b", n_layers=2, pipe=1)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda a: a[0],
                          params["stages"][0]["layers"])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        out, aux = moe_mod.moe_apply(cfg, lp["moe"], x)
        assert out.shape == x.shape
        assert np.isfinite(np.asarray(out)).all()
        assert float(aux) >= 0

    def test_grouped_equals_ungrouped_when_no_drop(self):
        from repro.models import moe as moe_mod
        import dataclasses
        cfg = tiny_cfg("grok-1-314b", n_layers=2, pipe=1)
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda a: a[0], params["stages"][0]["layers"])
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
        old = moe_mod.DISPATCH_GROUPS
        try:
            moe_mod.DISPATCH_GROUPS = 4
            o1, a1 = moe_mod.moe_apply(cfg, lp["moe"], x)
            moe_mod.DISPATCH_GROUPS = 1
            o2, a2 = moe_mod.moe_apply(cfg, lp["moe"], x)
        finally:
            moe_mod.DISPATCH_GROUPS = old
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=2e-5, rtol=1e-4)

    def test_balanced_router_aux_near_coef(self):
        """Perfectly uniform routing gives aux ~= coef (E * (1/E) * k...)"""
        from repro.models import moe as moe_mod
        cfg = tiny_cfg("grok-1-314b", n_layers=2, pipe=1)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda a: a[0], params["stages"][0]["layers"])
        # zero router -> uniform probs -> aux = coef * E * sum(1/E * k/E)
        lp["moe"]["router"] = jnp.zeros_like(lp["moe"]["router"])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        _, aux = moe_mod.moe_apply(cfg, lp["moe"], x)
        E, k = cfg.moe.num_experts, cfg.moe.top_k
        assert float(aux) == pytest.approx(cfg.moe.aux_loss_coef * k,
                                           rel=1e-3)
