"""Scan-compiled IR interpreter: O(1) trace size + bit-identity oracle.

Three layers of evidence for ``backend="scan"`` in
``core/pipeline_stream.make_ir_train_step``:

  * **Event table** — lowering one round to the dense int32
    :class:`~repro.planner.schedule_ir.EventTable` is structurally
    sound: every compute event becomes a row, the lax.switch branch set
    is bounded by 2·n_chunks, register-allocated buffer slots balance
    (every value written is read and freed), and the weight-version lag
    column reproduces the schedule family's staleness (0 for flush
    schedules, 1 for 2BW).
  * **Bit identity** — the scan backend is bitwise identical to the
    unrolled reference oracle (losses and every state leaf) over
    {1f1b, 2bw, interleaved, gpipe} × S ∈ {2, 3} × ragged DP
    partitions, in spectrain and pipedream modes.
  * **Trace size** — the scan round body's jaxpr equation count is the
    same for M = 4 and M = 32 (O(1) in the round's microbatch count),
    while the unrolled body's grows with M.
"""
import numpy as np
import pytest

import jax

from conftest import lm_batch, tiny_cfg
from repro.core import pipeline_stream
from repro.models import Model
from repro.planner import plan, synthetic_profile
from repro.planner import schedule_ir as sir


def _skew(L):
    # front-loaded cost: the DP partitioner provably deviates from the
    # uniform split, so the sweep runs ragged chunk trees
    return [9.0] + [1.0] * (L - 1)


def _mk_plan(schedule, S, v=1, M=4, L=4):
    return plan(profile=synthetic_profile(_skew(L)), n_stages=S,
                schedule=schedule, virtual_stages=v, n_microbatches=M)


# ===========================================================================
# event-table lowering
# ===========================================================================


class TestEventTable:
    @pytest.mark.parametrize("schedule,S,v,M", [
        ("1f1b", 2, 1, 4), ("1f1b", 4, 1, 8), ("gpipe", 3, 1, 6),
        ("2bw", 3, 1, 6), ("interleaved", 2, 2, 4),
    ])
    def test_structure(self, schedule, S, v, M):
        p = _mk_plan(schedule, S, v=v, M=M, L=S * v)
        t = p.event_table()
        C = p.n_chunks
        assert t.rows.shape == (2 * M * C, sir.N_COLS)
        assert t.rows.dtype == np.int32
        assert len(t.branches) <= 2 * C
        # every chunk appears as both a fwd and a bwd branch
        assert {(k, q) for k, q, _s in t.branches} == \
            {(k, q) for k in (sir.FWD, sir.BWD) for q in range(C)}
        # slot columns index into the pools the table declares
        rows = t.rows
        fwd = rows[rows[:, sir.COL_OP] == sir.OP_FWD]
        bwd = rows[rows[:, sir.COL_OP] == sir.OP_BWD]
        assert len(fwd) == len(bwd) == M * C
        assert (fwd[:, sir.COL_A] >= 0).all()
        assert (fwd[:, sir.COL_B] < t.n_val_slots).all()
        assert (fwd[:, sir.COL_C] == -1).all()
        inner_bwd = bwd[bwd[:, sir.COL_CHUNK] > 0]
        if len(inner_bwd):
            assert (inner_bwd[:, sir.COL_C] >= 0).all()
            assert (inner_bwd[:, sir.COL_C] < t.n_cot_slots).all()
        assert (bwd[bwd[:, sir.COL_CHUNK] == 0][:, sir.COL_C] == -1).all()
        # exactly one first-contribution marker per chunk, one for the
        # head outer grad (bwd of chunk C-1) and one for the embed outer
        # grad (bwd of chunk 0) — the two outer accumulators are kept
        # separate so every backend sums them in the same order
        assert bwd[:, sir.COL_FIRST_G].sum() == C
        assert rows[:, sir.COL_FIRST_O].sum() == 1
        assert rows[:, sir.COL_FIRST_E].sum() == 1
        assert (rows[rows[:, sir.COL_FIRST_O] > 0][:, sir.COL_CHUNK]
                == C - 1).all()
        assert (rows[rows[:, sir.COL_FIRST_E] > 0][:, sir.COL_CHUNK]
                == 0).all()

    def test_wv_column_matches_schedule_family(self):
        flush = _mk_plan("1f1b", 2).event_table()
        assert (flush.rows[:, sir.COL_WV] == 0).all()
        twobw = _mk_plan("2bw", 2).event_table()
        assert (twobw.rows[:, sir.COL_WV] == 1).all()

    def test_deterministic(self):
        a = _mk_plan("1f1b", 3, M=6).event_table()
        b = _mk_plan("1f1b", 3, M=6).event_table()
        assert a.branches == b.branches
        np.testing.assert_array_equal(a.rows, b.rows)

    def test_slot_pool_tracks_schedule_stash(self):
        # the value pool holds at least the schedule's peak per-stage
        # activation stash and never more than the whole round
        p = _mk_plan("1f1b", 4, M=8)
        t = p.event_table()
        assert max(p.act_stash) <= t.n_val_slots <= 2 * 8 * 4

    def test_unbalanced_program_rejected(self):
        prog = _mk_plan("1f1b", 2).round_program()
        with pytest.raises(ValueError, match="expected"):
            sir.compile_event_table(prog[:-1], 2, 4)
        # dataflow violations are caught, not silently mis-slotted
        bad = [e for e in prog if not (e[0] == sir.BWD and e[2] == 1
                                       and e[1] == 0)]
        with pytest.raises(ValueError, match="bwd"):
            sir.compile_event_table(
                bad + [(sir.BWD, 0, 1, 0)], 2, 4)


# ===========================================================================
# bit identity vs the unrolled oracle
# ===========================================================================


class TestScanBitIdentity:
    def _run(self, p, mode, steps=2, batch=8, lr=0.05):
        cfg = tiny_cfg("granite-8b", n_layers=p.partition.n_layers,
                       pipe=p.n_stages)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        data = lm_batch(jax.random.PRNGKey(1), cfg, batch=batch, seq=8)
        out = {}
        for backend in pipeline_stream.IR_BACKENDS:
            state = pipeline_stream.make_ir_state(m, params, None, plan=p,
                                                  mode=mode)
            step = jax.jit(pipeline_stream.make_ir_train_step(
                m, plan=p, mode=mode, lr=lr, backend=backend))
            losses = []
            for _ in range(steps):
                state, met = step(state, data)
                losses.append(np.asarray(met["loss"]))
            out[backend] = (losses, state)
        return out

    @pytest.mark.parametrize("schedule,S,v,M,L", [
        ("1f1b", 2, 1, 4, 4),
        ("1f1b", 3, 1, 3, 5),
        ("2bw", 2, 1, 4, 4),
        ("2bw", 3, 1, 3, 5),
        ("interleaved", 2, 2, 4, 4),
        ("interleaved", 3, 2, 3, 6),
        ("gpipe", 2, 1, 4, 4),
    ])
    def test_scan_matches_unrolled_bitwise(self, schedule, S, v, M, L):
        """The acceptance criterion: ragged DP-partitioned plans execute
        bit-for-bit identically through both round bodies."""
        p = _mk_plan(schedule, S, v=v, M=M, L=L)
        if v == 1 and schedule != "gpipe":
            assert len(set(p.partition.sizes())) > 1, \
                "sweep must exercise a ragged partition"
        out = self._run(p, "spectrain", batch=2 * M)
        (lu, su), (ls, ss) = out["unrolled"], out["scan"]
        for a, b in zip(lu, ls):
            assert a.tobytes() == b.tobytes(), (a, b)
        ju, js = jax.tree.leaves(su), jax.tree.leaves(ss)
        assert len(ju) == len(js)
        for a, b in zip(ju, js):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_2bw_pipedream_mode_bitwise(self):
        """The raw double-buffer read path (no prediction) is also
        bit-identical."""
        p = _mk_plan("2bw", 2)
        out = self._run(p, "pipedream")
        (lu, su), (ls, ss) = out["unrolled"], out["scan"]
        assert [a.tobytes() for a in lu] == [a.tobytes() for a in ls]
        for a, b in zip(jax.tree.leaves(su), jax.tree.leaves(ss)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_unknown_backend_rejected(self):
        p = _mk_plan("1f1b", 2)
        cfg = tiny_cfg("granite-8b", n_layers=4, pipe=2)
        m = Model(cfg)
        with pytest.raises(ValueError, match="backend"):
            pipeline_stream.make_ir_train_step(
                m, plan=p, mode="spectrain", lr=0.05, backend="eager")


# ===========================================================================
# trace size
# ===========================================================================


# the one recursive jaxpr-equation counter (sub-jaxprs: scan bodies,
# switch branches, custom-vjp calls, ...) — shared with the benchmark
# so EXPERIMENTS.md numbers and this test measure the same thing
try:
    from benchmarks.ir_compile import _count_eqns
except ImportError:            # bare `pytest` without repo root on sys.path
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.ir_compile import _count_eqns


class TestTraceSize:
    def _trace(self, backend, M):
        p = _mk_plan("1f1b", 2, M=M)
        cfg = tiny_cfg("granite-8b", n_layers=4, pipe=2)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=M, seq=8)
        state = pipeline_stream.make_ir_state(m, params, None, plan=p)
        step = pipeline_stream.make_ir_train_step(
            m, plan=p, mode="spectrain", lr=0.05, backend=backend)
        return _count_eqns(jax.make_jaxpr(step)(state, batch).jaxpr)

    def test_scan_trace_constant_in_microbatches(self):
        """THE property this backend exists for: the jaxpr is the same
        size no matter how many microbatches the round runs."""
        assert self._trace("scan", 4) == self._trace("scan", 32)

    def test_unrolled_trace_grows_and_scan_beats_it(self):
        small, big = self._trace("unrolled", 4), self._trace("unrolled", 32)
        assert big > 4 * small          # O(M·C) growth of the oracle
        assert self._trace("scan", 32) < small


# ===========================================================================
# CLI
# ===========================================================================


class TestCLIBackendFlag:
    def test_unrolled_backend_trains(self):
        from repro.launch import train
        rc = train.main([
            "--arch", "granite-8b", "--smoke", "--pipe", "2",
            "--layers", "4", "--steps", "2", "--batch", "8",
            "--seq", "16", "--log-every", "1",
            "--schedule", "1f1b", "--ir-backend", "unrolled"])
        assert rc == 0
