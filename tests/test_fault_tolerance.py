"""Fault tolerance: restart-equivalence, straggler drop, heartbeats,
elastic resharding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import lm_batch, tiny_cfg
from repro.core import pipeline_stream
from repro.data import DataConfig, SyntheticLM
from repro.models import Model
from repro.runtime import elastic
from repro.runtime.fault_tolerance import (HeartbeatMonitor, RestartManager,
                                           masked_gradient_mean)


def _build(pipe=2, n_layers=4):
    cfg = tiny_cfg("granite-8b", n_layers=n_layers, pipe=pipe)
    m = Model(cfg)
    data = SyntheticLM(DataConfig(cfg.vocab_size, 8, 4, seed=3))
    batch0 = data.batch_at(0)
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       batch0)
    state = pipeline_stream.init_state(m, jax.random.PRNGKey(0), sds)
    step = jax.jit(pipeline_stream.make_train_step(m, mode="spectrain",
                                                   lr=0.02))
    return cfg, m, data, state, step


class TestRestart:
    def test_crash_restart_matches_uninterrupted(self, tmp_path):
        cfg, m, data, state, step = _build()

        rm = RestartManager(str(tmp_path), save_every=1)
        s_fault, _ = rm.run(state, step, data, 0, 12)
        rm.inject_failure_at = 7
        rm2 = RestartManager(str(tmp_path) + "_b", save_every=1,
                             inject_failure_at=7)
        s_ref, _ = rm2.run(state, step, data, 0, 12)
        for a, b in zip(jax.tree.leaves(s_fault["params"]),
                        jax.tree.leaves(s_ref["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)


class TestStraggler:
    def test_masked_mean_drops_dead_replica(self):
        g = [{"w": jnp.full((3,), float(i))} for i in range(4)]
        got = masked_gradient_mean(g, [True, True, False, True])
        np.testing.assert_allclose(np.asarray(got["w"]),
                                   np.full(3, (0 + 1 + 3) / 3))

    def test_all_dead_raises(self):
        with pytest.raises(RuntimeError):
            masked_gradient_mean([{"w": jnp.ones(2)}], [False])


class TestHeartbeat:
    def test_straggler_detection(self):
        from repro.obs import MetricsRegistry
        reg = MetricsRegistry()
        hb = HeartbeatMonitor(deadline_s=10.0, registry=reg)
        hb.beat(0, 5, now=100.0)
        hb.beat(1, 5, now=100.0)
        hb.beat(2, 3, now=85.0)
        assert hb.stragglers(now=100.0) == [2]
        assert hb.alive_mask(4, now=100.0) == [True, True, False, False]
        # the miss is a structured event (once per transition, with the
        # worker's last progress), and recovery is the paired event
        missed = reg.find("heartbeat_missed")
        assert [e["worker"] for e in missed] == [2]
        assert missed[0]["last_step"] == 3
        assert missed[0]["overdue_s"] == pytest.approx(5.0)
        hb.stragglers(now=101.0)            # still overdue: no re-emit
        assert len(reg.find("heartbeat_missed")) == 1
        hb.beat(2, 4, now=101.0)
        rec = reg.find("heartbeat_recovered")
        assert [e["worker"] for e in rec] == [2]


class TestElastic:
    def test_restack_preserves_layers(self):
        x = jnp.arange(24.0).reshape(4, 2, 3)  # [S=4, Lps=2, d]
        y = elastic.restack_stages({"w": x}, 2)["w"]
        assert y.shape == (2, 4, 3)
        np.testing.assert_array_equal(np.asarray(y.reshape(8, 3)),
                                      np.asarray(x.reshape(8, 3)))

    def test_elastic_pipe_change_preserves_loss(self):
        """Repipeline 4 stages -> 2 stages: forward must be identical."""
        from repro.obs import MetricsRegistry
        cfg4 = tiny_cfg("granite-8b", n_layers=4, pipe=4)
        cfg2 = tiny_cfg("granite-8b", n_layers=4, pipe=2)
        m4, m2 = Model(cfg4), Model(cfg2)
        batch = lm_batch(jax.random.PRNGKey(1), cfg4, batch=2, seq=8)
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
        state4 = pipeline_stream.init_state(m4, jax.random.PRNGKey(0), sds)
        reg = MetricsRegistry()
        state2 = elastic.elastic_restate(m4, m2, state4, sds, registry=reg)
        l4 = m4.loss(state4["params"], batch)
        l2 = m2.loss(state2["params"], batch)
        np.testing.assert_allclose(np.asarray(l4), np.asarray(l2),
                                   rtol=1e-6)
        ev = reg.find("elastic_restate")
        assert len(ev) == 1
        assert ev[0]["old_pipe"] == 4 and ev[0]["new_pipe"] == 2
        assert ev[0]["schedule"] == "stream"

    def test_elastic_keeps_training(self):
        cfg4 = tiny_cfg("granite-8b", n_layers=4, pipe=4)
        cfg2 = tiny_cfg("granite-8b", n_layers=4, pipe=2)
        m4, m2 = Model(cfg4), Model(cfg2)
        batch = lm_batch(jax.random.PRNGKey(1), cfg4, batch=4, seq=8)
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
        state = pipeline_stream.init_state(m4, jax.random.PRNGKey(0), sds)
        step4 = jax.jit(pipeline_stream.make_train_step(
            m4, mode="spectrain", lr=0.02))
        for _ in range(8):
            state, met = step4(state, batch)
        state2 = elastic.elastic_restate(m4, m2, state, sds)
        step2 = jax.jit(pipeline_stream.make_train_step(
            m2, mode="spectrain", lr=0.02))
        losses = []
        for _ in range(8):
            state2, met = step2(state2, batch)
            if float(met["loss_valid"]):
                losses.append(float(met["loss"]))
        assert np.isfinite(losses).all()
