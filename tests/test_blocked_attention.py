"""Blocked (flash) attention in XLA vs the dense reference — fwd + grads,
GQA/MQA, causal/non-causal, ragged block edges; hypothesis sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis
from repro.kernels.ref import attention_ref
from repro.models.blocked_attention import blocked_attention

given, settings, st = optional_hypothesis()


def _ref(q, k, v, causal):
    o = attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                      jnp.moveaxis(v, 1, 2), causal=causal)
    return jnp.moveaxis(o, 1, 2)


CASES = [
    # b, H, KV, sq, sk, d, causal, bq, bk
    (2, 4, 4, 64, 64, 32, True, 16, 16),
    (1, 8, 2, 64, 64, 32, True, 32, 16),
    (2, 4, 1, 48, 80, 16, False, 16, 32),   # ragged, cross-attn
    (1, 2, 2, 100, 100, 8, True, 32, 64),   # non-divisible blocks
]


@pytest.mark.parametrize("case", CASES)
def test_forward_matches_ref(case):
    b, H, KV, sq, sk, d, causal, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, H, d))
    k = jax.random.normal(ks[1], (b, sk, KV, d))
    v = jax.random.normal(ks[2], (b, sk, KV, d))
    o = blocked_attention(q, k, v, causal, bq, bk, 0)
    o_ref = _ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("case", CASES[:2])
def test_grads_match_ref(case):
    b, H, KV, sq, sk, d, causal, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, sq, H, d))
    k = jax.random.normal(ks[1], (b, sk, KV, d))
    v = jax.random.normal(ks[2], (b, sk, KV, d))
    f1 = lambda q, k, v: jnp.sum(jnp.sin(
        blocked_attention(q, k, v, causal, bq, bk, 0)))
    f2 = lambda q, k, v: jnp.sum(jnp.sin(_ref(q, k, v, causal)))
    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b_, nm in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=5e-5, rtol=1e-3, err_msg=nm)


def test_separate_v_dim():
    """MLA path: qk head dim != v head dim."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 32, 4, 24))
    k = jax.random.normal(ks[1], (1, 32, 4, 24))
    v = jax.random.normal(ks[2], (1, 32, 4, 16))
    o = blocked_attention(q, k, v, True, 16, 16, 0)
    assert o.shape == (1, 32, 4, 16)
    o_ref = _ref(q, k, v, True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=1e-4)


def test_pos_offset_decode_window():
    """pos_offset shifts the causal frontier (continued sequence)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q_full = jax.random.normal(ks[0], (1, 32, 2, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))
    o_full = blocked_attention(q_full, k, v, True, 8, 8, 0)
    # query block [16:32) with pos offset 16 attends identically
    o_tail = blocked_attention(q_full[:, 16:], k, v, True, 8, 8, 16)
    np.testing.assert_allclose(np.asarray(o_tail),
                               np.asarray(o_full[:, 16:]),
                               atol=2e-5, rtol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 2),
    kv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    sq=st.integers(4, 48),
    d=st.sampled_from([8, 16]),
    causal=st.booleans(),
)
def test_hypothesis_shapes(b, kv, g, sq, d, causal):
    H = kv * g
    sk = sq if causal else sq + 8
    ks = jax.random.split(jax.random.PRNGKey(b * 7 + sq), 3)
    q = jax.random.normal(ks[0], (b, sq, H, d))
    k = jax.random.normal(ks[1], (b, sk, kv, d))
    v = jax.random.normal(ks[2], (b, sk, kv, d))
    o = blocked_attention(q, k, v, causal, 16, 16, 0)
    o_ref = _ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=3e-5, rtol=2e-4)
