import os
import sys

# src layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def optional_hypothesis():
    """(given, settings, st) — the real hypothesis API, or
    decoration-safe stubs that mark just the property tests as skipped
    when hypothesis isn't installed, leaving the rest of the module
    collectable (modules that are *all* property tests should use
    ``pytest.importorskip`` instead)."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        def given(*a, **k):
            return lambda f: pytest.mark.skip(
                reason="hypothesis not installed")(f)

        def settings(*a, **k):
            return lambda f: f

        class _Strategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        return given, settings, _Strategies()


def tiny_cfg(name="granite-8b", *, n_layers=4, pipe=2, tensor=1, ticks=2,
             **kw):
    """Reduced fp32 config with a real pipeline split (CPU-friendly)."""
    from repro.configs import get_config, smoke_config
    from repro.configs.base import MeshPlan
    cfg = smoke_config(get_config(name))
    return cfg.replace(
        n_layers=n_layers,
        mesh_plan=MeshPlan(pipe=pipe, tensor=tensor, num_microbatches=ticks),
        param_dtype="float32", compute_dtype="float32", **kw)


def lm_batch(key, cfg, batch=4, seq=16):
    import jax.numpy as jnp
    k1, k2 = jax.random.split(key)
    b = {"tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size),
         "targets": jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size)}
    if cfg.frontend == "audio":
        b["frames"] = jax.random.normal(k1, (batch, seq, cfg.d_model),
                                        jnp.float32)
    if cfg.frontend == "vision":
        p = min(cfg.frontend_patches, seq)
        b["patches"] = jax.random.normal(k1, (batch, p, cfg.d_model),
                                         jnp.float32)
    return b
