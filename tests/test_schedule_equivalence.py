"""Schedule-equivalence harness for the ragged streaming runtime.

Three layers of evidence that executing planner partitions did not move
the numerics:

  * **Golden trajectories** — under uniform plans the ragged per-stage
    runtime must be *bit-identical* to the pre-refactor stacked
    ``[S, Lps, ...]`` runtime (fixture recorded at commit 890b850 by
    ``tests/golden/gen_golden.py``), for every mode and S in {2, 3, 4}.
  * **Cross-runtime** — a non-uniform DP plan run end-to-end through
    ``core/pipeline_stream.py`` must track the simulator's loss
    trajectory for the same plan (XPipe's point: re-verify weight
    prediction whenever the schedule shape changes).
  * **Properties** — IR-derived staleness equals the closed forms for
    ragged partitions, and the runtime's two constant vectors (stash
    gather offsets 2(S−1−k) vs injection→bwd lag 2(S−1)−k) are never
    conflated.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import lm_batch, optional_hypothesis, tiny_cfg
from golden.gen_golden import CASES as GOLDEN_CASES
from golden.gen_golden import final_digests, run_case
from repro.core import pipeline_stream
from repro.core import spectrain as st
from repro.core.simulator import Simulator, make_mlp_staged, staged_from_model
from repro.models import Model
from repro.planner import Partition, plan, synthetic_profile, uniform
from repro.planner.partition import dp_split

given, settings, hyp_st = optional_hypothesis()

GOLDEN = "golden/stream_uniform_golden.npz"


def _golden():
    import os
    return np.load(os.path.join(os.path.dirname(__file__), GOLDEN))


# ===========================================================================
# golden: uniform-plan ragged runtime == pre-refactor stacked runtime
# ===========================================================================


class TestGoldenUniform:
    @pytest.mark.parametrize("case", GOLDEN_CASES,
                             ids=[f"{m}_p{p}_L{n}"
                                  for m, p, n, _, _ in GOLDEN_CASES])
    def test_bit_identical_to_stacked_runtime(self, case):
        """Acceptance criterion: per-tick losses and every final param
        leaf (stage layers flattened to [L, ...]) match the recorded
        stacked-runtime trajectory bit-for-bit."""
        mode, pipe, n_layers, lr, ticks = case
        name = f"{mode}_p{pipe}_L{n_layers}"
        gold = _golden()
        rec = run_case(mode, pipe, n_layers, lr, ticks)
        np.testing.assert_array_equal(gold[f"{name}/losses"], rec["losses"])
        np.testing.assert_array_equal(gold[f"{name}/valids"], rec["valids"])
        for key in gold.files:
            if key.startswith(f"{name}/final/"):
                want = str(gold[key])
                got = str(rec[key.split("/", 1)[1]])
                assert got == want, f"param leaf diverged: {key}"

    def test_explicit_uniform_plan_matches_golden(self):
        """A plan() object with the uniform partition goes through the
        same validation/regrouping path and must also hit the golden
        trajectory exactly."""
        cfg = tiny_cfg("granite-8b", n_layers=4, pipe=2)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=4, seq=16)
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
        p = plan(cfg, n_stages=2, schedule="stream", partitioner="uniform")
        assert p.partition.sizes() == (2, 2)
        state = pipeline_stream.make_state(m, params, sds, plan=p)
        step = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.05, plan=p))
        losses = []
        for _ in range(8):
            state, met = step(state, batch)
            losses.append(float(met["loss"]))
        gold = _golden()
        np.testing.assert_array_equal(gold["spectrain_p2_L4/losses"],
                                      np.asarray(losses, np.float64))
        for key, want in final_digests(state["params"]).items():
            assert str(gold[f"spectrain_p2_L4/final/{key}"]) == want, key


# ===========================================================================
# DP (non-uniform) plans execute and track the simulator
# ===========================================================================

# per-layer cost skew whose DP split is provably non-uniform
_DP_CASES = {
    2: (4, [9.0, 1.0, 1.0, 1.0]),
    3: (6, [9.0, 1.0, 1.0, 1.0, 1.0, 9.0]),
    4: (8, [9.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 9.0]),
}


def _dp_plan(S):
    L, costs = _DP_CASES[S]
    p = plan(profile=synthetic_profile(costs), n_stages=S,
             schedule="stream", partitioner="dp")
    assert p.partition.sizes() != uniform(L, S).sizes(), \
        "test profile must force a non-uniform split"
    return p


class TestDPPlanExecution:
    @pytest.mark.parametrize("S", sorted(_DP_CASES))
    def test_dp_plan_runs_and_tracks_simulator(self, S):
        """Acceptance criterion: a non-uniform plan() partition executes
        end-to-end in the streaming runtime, and its loss trajectory
        lands where the simulator's (same plan, same ragged stages, same
        data) does."""
        L, _ = _DP_CASES[S]
        p = _dp_plan(S)
        cfg = tiny_cfg("granite-8b", n_layers=L, pipe=S)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=4, seq=16)
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)

        state = pipeline_stream.make_state(m, params, sds, plan=p)
        # ragged stage trees realize the plan's layer counts
        got_sizes = tuple(
            jax.tree.leaves(t["layers"])[0].shape[0]
            for t in state["params"]["stages"])
        assert got_sizes == p.partition.sizes()
        step = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.05, plan=p))
        stream_losses = []
        ticks = 30 + 2 * S
        for _ in range(ticks):
            state, met = step(state, batch)
            if float(met["loss_valid"]):
                stream_losses.append(float(met["loss"]))

        fns, repack = staged_from_model(m, p.partition)
        sim = Simulator(fns, repack(params), plan=p, scheme="spectrain",
                        lr=0.05)
        sim_losses = [sim.step(batch)["loss"] for _ in range(ticks)]

        assert np.isfinite(stream_losses).all()
        assert np.isfinite(sim_losses).all()
        # both overfit the fixed batch; their converged levels must agree
        s_end = float(np.mean(stream_losses[-5:]))
        r_end = float(np.mean(sim_losses[-5:]))
        assert stream_losses[-1] < stream_losses[0]
        assert abs(s_end - r_end) < 0.75, (S, s_end, r_end)

    def test_dp_beats_uniform_bottleneck_in_plan(self):
        """The reason to execute DP plans at all: lower modelled
        bottleneck, now reported as realized per-stage costs."""
        p = _dp_plan(4)
        assert p.bottleneck_s < p.uniform_bottleneck_s
        assert len(p.stage_costs_s) == 4
        assert max(p.stage_costs_s) == pytest.approx(p.bottleneck_s)
        assert p.stage_ranges == p.partition.stages()


class TestPlanValidation:
    """Plans are executable artifacts — bad layer ranges must fail at
    state construction, not corrupt slicing later."""

    def _mk(self, n_layers=4, pipe=2):
        cfg = tiny_cfg("granite-8b", n_layers=n_layers, pipe=pipe)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            lm_batch(jax.random.PRNGKey(1), cfg, batch=4, seq=16))
        return m, params, sds

    def test_wrong_layer_count_rejected(self):
        m, params, sds = self._mk(n_layers=4)
        p = plan(profile=synthetic_profile([1.0] * 6), n_stages=2,
                 schedule="stream")
        with pytest.raises(ValueError, match="layers"):
            pipeline_stream.make_state(m, params, sds, plan=p)
        with pytest.raises(ValueError, match="layers"):
            pipeline_stream.make_train_step(m, mode="spectrain", lr=0.05,
                                            plan=p)

    def test_wrong_stage_count_rejected(self):
        m, params, sds = self._mk(n_layers=4, pipe=2)
        p = plan(profile=synthetic_profile([1.0] * 4), n_stages=4,
                 schedule="stream")
        with pytest.raises(ValueError, match="stages"):
            pipeline_stream.make_state(m, params, sds, plan=p)

    def test_partition_params_validates(self):
        m, params, _ = self._mk(n_layers=4)
        with pytest.raises(ValueError, match="cover"):
            m.partition_stage_params(params["stages"], (1, 2))
        with pytest.raises(ValueError, match="stage"):
            m.partition_stage_params(params["stages"], (1, 1, 2))

    def test_ragged_roundtrip_uniform(self):
        """ragged canonical -> legacy stacked -> ragged is lossless
        (stack_stage_params is the uniform-sizes inverse)."""
        m, params, _ = self._mk(n_layers=4)
        stacked = m.stack_stage_params(params["stages"])
        assert jax.tree.leaves(stacked["layers"])[0].shape[:2] == (2, 2)
        again = m.partition_stage_params(stacked, (2, 2))
        for a, b in zip(jax.tree.leaves(again),
                        jax.tree.leaves(params["stages"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        with pytest.raises(ValueError, match="ragged"):
            m.stack_stage_params(
                m.partition_stage_params(params["stages"], (1, 3)))

    def test_simulator_rejects_stage_mismatch(self):
        p = plan(n_layers=4, n_stages=4, schedule="stream")
        fns, params = make_mlp_staged(
            jax.random.PRNGKey(0), in_dim=8, width=16, depth=4,
            n_classes=4, n_stages=2)
        with pytest.raises(ValueError, match="stage"):
            Simulator(fns, params, plan=p, scheme="spectrain")


# ===========================================================================
# ragged MLP stages in the simulator
# ===========================================================================


def _data_iter(seed, batch=16, in_dim=8, classes=4):
    k = jax.random.PRNGKey(seed)
    wtrue = jax.random.normal(jax.random.PRNGKey(99), (in_dim, classes))
    while True:
        k, k1 = jax.random.split(k)
        x = jax.random.normal(k1, (batch, in_dim))
        yield {"x": x, "y": jnp.argmax(x @ wtrue, -1)}


class TestRaggedSimulator:
    def test_ragged_mlp_converges_under_stream_plan(self):
        p = plan(profile=synthetic_profile([9.0, 1.0, 1.0, 1.0]),
                 n_stages=2, schedule="stream", partitioner="dp")
        fns, params = make_mlp_staged(
            jax.random.PRNGKey(0), in_dim=8, width=16, depth=4,
            n_classes=4, n_stages=2, sizes=p.partition.sizes())
        sim = Simulator(fns, params, plan=p, scheme="spectrain", lr=0.05)
        it = _data_iter(0)
        losses = [sim.step(next(it))["loss"] for _ in range(60)]
        assert np.isfinite(losses).all()
        assert np.mean(losses[-10:]) < np.mean(losses[:10])

    def test_uniform_sizes_match_default_split(self):
        fns, pa = make_mlp_staged(jax.random.PRNGKey(0), in_dim=8,
                                  width=16, depth=4, n_classes=4,
                                  n_stages=2)
        fns2, pb = make_mlp_staged(jax.random.PRNGKey(0), in_dim=8,
                                   width=16, depth=4, n_classes=4,
                                   n_stages=2, sizes=(2, 2))
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bad_sizes_rejected(self):
        with pytest.raises(ValueError):
            make_mlp_staged(jax.random.PRNGKey(0), in_dim=8, width=16,
                            depth=4, n_classes=4, n_stages=2, sizes=(1, 2))


# ===========================================================================
# properties: staleness closed forms and the two constant vectors
# ===========================================================================


@settings(max_examples=25, deadline=None)
@given(S=hyp_st.integers(2, 6), seed=hyp_st.integers(0, 999))
def test_ragged_plan_staleness_matches_closed_forms(S, seed):
    """IR-derived s_fwd/s_bwd are schedule-shape facts: they must equal
    the core/spectrain.py closed forms for *any* partition, however
    skewed — staleness depends on S, never on where the cuts fall."""
    rng = np.random.default_rng(seed)
    L = int(rng.integers(S, 4 * S + 1))
    costs = rng.uniform(0.5, 10.0, L).tolist()
    p = plan(profile=synthetic_profile(costs), n_stages=S,
             schedule="stream", partitioner="dp")
    for k in range(S):
        assert p.s_fwd[k] == st.version_difference_stream(k, S, "forward")
        assert p.s_bwd[k] == st.version_difference_stream(k, S, "backward")


@settings(max_examples=25, deadline=None)
@given(S=hyp_st.integers(2, 6), seed=hyp_st.integers(0, 999))
def test_stash_offsets_never_conflate_constant_vectors(S, seed):
    """fb_gap (stash gather offsets, 2(S−1−k)) and bwd_lag
    (injection→bwd ticks, 2(S−1)−k) differ by exactly k; swapping them
    at any stage k ≥ 1 would corrupt the stash gather."""
    rng = np.random.default_rng(seed)
    L = int(rng.integers(S, 4 * S + 1))
    p = plan(profile=synthetic_profile(rng.uniform(0.5, 10.0, L).tolist()),
             n_stages=S, schedule="stream", partitioner="dp")
    for k in range(S):
        assert p.fb_gap[k] == 2 * (S - 1 - k)
        assert p.bwd_lag[k] == 2 * (S - 1) - k
        assert p.bwd_lag[k] - p.fb_gap[k] == k
        if k >= 1:
            assert p.fb_gap[k] != p.bwd_lag[k]
    # the forward prediction distance is the stash gap, not the lag
    assert tuple(p.s_fwd) == tuple(p.fb_gap)


@settings(max_examples=25, deadline=None)
@given(S=hyp_st.integers(2, 6), seed=hyp_st.integers(0, 999))
def test_dp_partition_is_valid_and_no_worse_than_uniform(S, seed):
    rng = np.random.default_rng(seed)
    L = int(rng.integers(S, 4 * S + 1))
    costs = rng.uniform(0.5, 10.0, L).tolist()
    cuts = rng.uniform(0.0, 1.0, L).tolist()
    part = dp_split(costs, cuts, S)
    sizes = part.sizes()
    assert sum(sizes) == L and min(sizes) >= 1 and len(sizes) == S
    from repro.planner.partition import bottleneck
    assert bottleneck(costs, cuts, part) <= \
        bottleneck(costs, cuts, uniform(L, S)) + 1e-12


def test_partition_stage_of_covers_all_layers():
    part = Partition((0, 1, 4, 6))
    assert [part.stage_of(j) for j in range(6)] == [0, 1, 1, 1, 2, 2]
    with pytest.raises(ValueError):
        part.stage_of(6)
