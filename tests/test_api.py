"""The unified Runtime facade: RuntimeConfig validation, the shared
argparse wiring, the exec= -> execution= deprecation shims, and
bit-identity of the facade against the legacy constructors."""
import argparse
import dataclasses

import jax
import numpy as np
import pytest

from conftest import lm_batch, tiny_cfg
from repro.api import (Runtime, RuntimeConfig, add_runtime_args,
                       runtime_config_from_args)
from repro.core import pipeline_stream as ps
from repro.models import Model
from repro.planner import plan, serve_plan
from repro.runtime import elastic


def _parser(serving=False):
    ap = argparse.ArgumentParser()
    add_runtime_args(ap, serving=serving)
    return ap


@pytest.fixture(scope="module")
def ir_setup():
    cfg = tiny_cfg("granite-8b", n_layers=4, pipe=2)
    m = Model(cfg)
    p = plan(None, n_stages=2, n_microbatches=4, n_layers=4,
             schedule="1f1b")
    batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=4, seq=8)
    return m, p, batch


class TestRuntimeConfig:
    def test_defaults_valid(self):
        c = RuntimeConfig()
        assert (c.mode, c.execution, c.backend) == \
            ("spectrain", "spmd", "scan")
        assert c.schedule is None

    @pytest.mark.parametrize("kw,msg", [
        (dict(mode="nope"), "unknown mode"),
        (dict(schedule="nope"), "unknown schedule"),
        (dict(backend="nope"), "unknown backend"),
        (dict(execution="simd"), "unknown execution"),
        (dict(execution="mpmd", schedule="stream"), "SPMD-only"),
        (dict(execution="mpmd", clip=1.0), "clip"),
        (dict(ticks_per_step=0), "ticks_per_step"),
    ])
    def test_post_init_rejects(self, kw, msg):
        with pytest.raises(ValueError, match=msg):
            RuntimeConfig(**kw)

    def test_replace_revalidates(self):
        c = RuntimeConfig(schedule="1f1b")
        assert c.replace(lr=0.5).lr == 0.5
        with pytest.raises(ValueError):
            c.replace(mode="nope")

    def test_frozen(self):
        with pytest.raises(dataclasses.FrozenInstanceError):
            RuntimeConfig().lr = 2.0


class TestArgparseWiring:
    def test_training_flags_roundtrip(self):
        args = _parser().parse_args(
            ["--mode", "pipedream", "--schedule", "1f1b",
             "--ir-backend", "unrolled", "--execution", "mpmd",
             "--lr", "0.05", "--gamma", "0.8", "--no-verify"])
        c = runtime_config_from_args(args)
        assert c.mode == "pipedream" and c.schedule == "1f1b"
        assert c.backend == "unrolled" and c.execution == "mpmd"
        assert c.lr == 0.05 and c.gamma == 0.8 and not c.verify

    def test_serving_parser_has_no_training_flags(self):
        ap = _parser(serving=True)
        with pytest.raises(SystemExit):
            ap.parse_args(["--mode", "spectrain"])
        c = runtime_config_from_args(ap.parse_args([]))
        assert c.execution == "spmd" and c.schedule is None

    def test_clip_zero_means_none(self):
        args = _parser().parse_args(["--schedule", "1f1b"])
        assert runtime_config_from_args(args).clip is None

    def test_legacy_exec_flag_warns(self):
        args = _parser().parse_args(
            ["--schedule", "1f1b", "--exec", "mpmd"])
        with pytest.warns(DeprecationWarning, match="--exec"):
            c = runtime_config_from_args(args)
        assert c.execution == "mpmd"

    def test_conflicting_exec_spellings_exit(self):
        args = _parser().parse_args(
            ["--schedule", "1f1b", "--execution", "spmd",
             "--exec", "mpmd"])
        with pytest.warns(DeprecationWarning):
            with pytest.raises(SystemExit, match="conflicts"):
                runtime_config_from_args(args)

    def test_overrides_win(self):
        args = _parser().parse_args(["--schedule", "1f1b"])
        c = runtime_config_from_args(args, ticks_per_step=3)
        assert c.ticks_per_step == 3


class TestKwargShims:
    """exec= stays a one-release DeprecationWarning alias for
    execution= on the legacy constructors, bit-identical."""

    def test_make_ir_state_exec_warns(self, ir_setup):
        m, p, _ = ir_setup
        params = m.init(jax.random.PRNGKey(0))
        with pytest.warns(DeprecationWarning, match="execution"):
            legacy = ps.make_ir_state(m, params, None, plan=p,
                                      exec="spmd")
        new = ps.make_ir_state(m, params, None, plan=p,
                               execution="spmd")
        jax.tree.map(np.testing.assert_array_equal,
                     legacy["params"], new["params"])

    def test_make_ir_train_step_exec_warns(self, ir_setup):
        m, p, _ = ir_setup
        with pytest.warns(DeprecationWarning, match="execution"):
            ps.make_ir_train_step(m, plan=p, mode="spectrain",
                                  lr=0.05, exec="spmd")

    def test_elastic_restate_exec_warns(self, ir_setup):
        m, _, batch = ir_setup
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
        state = ps.init_state(m, jax.random.PRNGKey(0), sds)
        with pytest.warns(DeprecationWarning, match="execution"):
            elastic.elastic_restate(m, m, state, sds, exec="spmd")

    def test_conflicting_kwargs_raise(self, ir_setup):
        m, p, _ = ir_setup
        with pytest.warns(DeprecationWarning):
            with pytest.raises(TypeError, match="both"):
                ps.make_ir_train_step(m, plan=p, mode="spectrain",
                                      lr=0.05, exec="mpmd",
                                      execution="spmd")

    def test_unknown_legacy_kwarg_raises(self, ir_setup):
        m, p, _ = ir_setup
        with pytest.raises(TypeError, match="unexpected"):
            ps.make_ir_train_step(m, plan=p, mode="spectrain",
                                  lr=0.05, excc="spmd")


class TestRuntimeFacade:
    def test_needs_a_plan(self):
        m = Model(tiny_cfg("granite-8b", n_layers=2, pipe=2))
        with pytest.raises(TypeError, match="PipelinePlan or ServePlan"):
            Runtime("1f1b", m)

    def test_schedule_cross_check(self, ir_setup):
        m, p, _ = ir_setup
        with pytest.raises(ValueError, match="does not match"):
            Runtime(p, m, RuntimeConfig(schedule="gpipe"))
        Runtime(p, m, RuntimeConfig(schedule="1f1b"))   # matching: fine
        Runtime(p, m)                                   # None adopts

    def test_tracer_requires_trace_flag(self, ir_setup):
        m, p, _ = ir_setup
        with pytest.raises(ValueError, match="trace"):
            Runtime(p, m, RuntimeConfig(), tracer=object())

    def test_workload_dispatch_is_typed(self, ir_setup):
        m, p, _ = ir_setup
        splan = serve_plan(None, n_slots=2, max_prefill=1,
                           prompt_budget=8, page_seq=32, n_layers=4)
        rt_t = Runtime(p, m)
        rt_s = Runtime(splan, m)
        with pytest.raises(TypeError, match="ServePlan"):
            rt_t.serve_engine(None)
        with pytest.raises(TypeError, match="serve_step"):
            rt_s.train_step(None, None)
        with pytest.raises(TypeError, match="serve_engine"):
            rt_s.init_state(None)

    def test_facade_bitwise_matches_legacy(self, ir_setup):
        """Runtime.train_step == hand-wired make_ir_state /
        make_ir_train_step + jit, bit for bit."""
        m, p, batch = ir_setup
        params = m.init(jax.random.PRNGKey(0))
        # both steps donate their state; fresh buffers per state so one
        # side's donation cannot delete the other's params
        fresh = lambda: jax.tree.map(lambda x: x.copy(), params)

        rt = Runtime(p, m, RuntimeConfig(mode="spectrain", lr=0.05,
                                         schedule="1f1b"))
        s_new = rt.init_state(fresh())

        s_old = ps.make_ir_state(m, fresh(), None, plan=p,
                                 mode="spectrain")
        step_old = jax.jit(ps.make_ir_train_step(
            m, plan=p, mode="spectrain", lr=0.05), donate_argnums=0)

        la, lb = [], []
        for _ in range(3):
            s_new, met_a = rt.train_step(s_new, batch)
            s_old, met_b = step_old(s_old, batch)
            la.append(float(met_a["loss"]))
            lb.append(float(met_b["loss"]))
        assert la == lb, (la, lb)
