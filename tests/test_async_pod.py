"""Cross-pod async DP with SpecTrain compensation (beyond-paper)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.async_dp import AsyncPodDP, SyncPodDP


def _problem(seed=0, dim=24, classes=6):
    wtrue = jax.random.normal(jax.random.PRNGKey(99), (dim, classes))
    w0 = {"w": jax.random.normal(jax.random.PRNGKey(seed), (dim, classes))
          * 0.01, "b": jnp.zeros((classes,))}

    def loss_fn(p, batch):
        logits = batch["x"] @ p["w"] + p["b"]
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], -1)[:, 0]
        return jnp.mean(lse - gold)

    def batches(step, n_pods=2, bs=32):
        out = []
        for p in range(n_pods):
            k = jax.random.PRNGKey(step * 17 + p)
            x = jax.random.normal(k, (bs, dim))
            out.append({"x": x, "y": (x @ wtrue).argmax(-1)})
        return out

    return loss_fn, w0, batches


def _run(maker, steps=150, **kw):
    loss_fn, w0, batches = _problem()
    algo = maker(loss_fn, w0, **kw)
    losses = [algo.step(batches(s))["loss"] for s in range(steps)]
    return np.asarray(losses)


class TestAsyncPod:
    def test_all_variants_converge(self):
        for maker, kw in [
            (SyncPodDP, {}),
            (AsyncPodDP, {"predict": True}),
            (AsyncPodDP, {"predict": False}),
        ]:
            losses = _run(maker, lr=0.3, **kw)
            assert np.isfinite(losses).all()
            assert losses[-20:].mean() < losses[:10].mean()

    def test_prediction_compensates_when_staleness_bites(self):
        """The paper's Eq. 4 applied at pod level.  In the aggressive
        regime (large lr, long DCN delay) delayed remote gradients
        destabilize training and predicted-weight gradients recover most
        of the gap — mirroring the paper's finding that prediction value
        grows with the version difference s (Fig. 8)."""
        sync = _run(SyncPodDP, lr=5.0)[-25:].mean()
        pred = _run(AsyncPodDP, lr=5.0, predict=True, delay=8)[-25:].mean()
        stale = _run(AsyncPodDP, lr=5.0, predict=False, delay=8)[-25:].mean()
        assert stale > sync + 1e-3          # staleness actually hurts here
        assert pred < stale - 1e-3          # prediction recovers
        assert abs(pred - sync) < abs(stale - sync)

    def test_benign_regime_prediction_is_neutral(self):
        """At small lr / short delay the delayed remote gradient is
        harmless and prediction costs nothing: async ~= sync, i.e. the
        cross-pod all-reduce can be hidden for free."""
        sync = _run(SyncPodDP, lr=0.5)[-25:].mean()
        pred = _run(AsyncPodDP, lr=0.5, predict=True, delay=1)[-25:].mean()
        stale = _run(AsyncPodDP, lr=0.5, predict=False, delay=1)[-25:].mean()
        assert abs(pred - sync) < 0.02
        assert abs(stale - sync) < 0.02

    def test_pods_stay_close(self):
        loss_fn, w0, batches = _problem()
        algo = AsyncPodDP(loss_fn, w0, lr=0.2, predict=True)
        for s in range(60):
            algo.step(batches(s))
        d = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            jax.tree.leaves(algo.params[0]),
            jax.tree.leaves(algo.params[1])))
        # per-pod replicas drift but stay bounded (local+delayed-remote)
        assert d < 1.0, d

    def test_staleness_aware_lr_scaling(self):
        """Zhang et al. remote down-scaling also stabilizes (option)."""
        losses = _run(AsyncPodDP, lr=0.3, predict=False, remote_scale=0.5)
        assert np.isfinite(losses).all()
