"""SpecTrain math: Eqs. (1)-(6) of the paper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spectrain as st


class TestVersionDifference:
    def test_eq5_eq6_paper_values_n4(self):
        # Eq. 5: s_fwd = floor(k/2) + N - k - 1 ; Eq. 6: s_bwd = floor(k/2)
        assert st.version_difference_paper(0, 4, "forward") == 3
        assert st.version_difference_paper(1, 4, "forward") == 2
        assert st.version_difference_paper(2, 4, "forward") == 2
        assert st.version_difference_paper(3, 4, "forward") == 1
        assert st.version_difference_paper(0, 4, "backward") == 0
        assert st.version_difference_paper(1, 4, "backward") == 0
        assert st.version_difference_paper(2, 4, "backward") == 1
        assert st.version_difference_paper(3, 4, "backward") == 1

    def test_paper_worked_example(self):
        # Fig. 7(d): N=3, minibatch at stage 0 forward, completes 2 units
        # later -> s = 2
        assert st.version_difference_paper(0, 3, "forward") == 2

    def test_fwd_minus_bwd_gap(self):
        # s_fwd - s_bwd = N - k - 1 (the 1F1B gap between fwd and bwd)
        for n in (2, 3, 4, 8):
            for k in range(n):
                gap = (st.version_difference_paper(k, n, "forward")
                       - st.version_difference_paper(k, n, "backward"))
                assert gap == n - k - 1

    def test_stream_schedule(self):
        for n in (1, 2, 4, 8):
            for k in range(n):
                assert st.version_difference_stream(k, n, "forward") == \
                    2 * (n - 1 - k)
                assert st.version_difference_stream(k, n, "backward") == 0

    def test_last_stage_fresh(self):
        # the last stage reads (nearly) fresh weights under both schedules
        assert st.version_difference_stream(7, 8, "forward") == 0
        assert st.version_difference_paper(3, 4, "forward") == 1

    def test_range_check(self):
        with pytest.raises(ValueError):
            st.version_difference_paper(4, 4, "forward")
        with pytest.raises(ValueError):
            st.version_difference_stream(-1, 4, "backward")


class TestPrediction:
    def test_eq4_formula(self):
        w = {"a": jnp.ones((3,)), "b": jnp.full((2, 2), 2.0)}
        v = {"a": jnp.full((3,), 0.5), "b": jnp.ones((2, 2))}
        got = st.predict_weights(w, v, lr=0.1, s=4)
        np.testing.assert_allclose(got["a"], 1.0 - 4 * 0.1 * 0.5, rtol=1e-6)
        np.testing.assert_allclose(got["b"], 2.0 - 4 * 0.1 * 1.0, rtol=1e-6)

    def test_s_zero_identity(self):
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (16,))
        v = jax.random.normal(key, (16,))
        got = st.predict_weights(w, v, lr=0.3, s=0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(w))

    def test_recursive_equals_closed_form(self):
        # applying Eq. 3 s times with frozen momentum == Eq. 4
        key = jax.random.PRNGKey(1)
        w = jax.random.normal(key, (8,))
        v = jax.random.normal(jax.random.PRNGKey(2), (8,))
        lr, s = 0.05, 5
        step = w
        for _ in range(s):
            step = st.predict_weights(step, v, lr=lr, s=1)
        closed = st.predict_weights(w, v, lr=lr, s=s)
        np.testing.assert_allclose(np.asarray(step), np.asarray(closed),
                                   rtol=1e-5)

    def test_stacked_matches_per_stage(self):
        key = jax.random.PRNGKey(3)
        w = jax.random.normal(key, (4, 6, 5))       # [stages, ...]
        v = jax.random.normal(jax.random.PRNGKey(4), (4, 6, 5))
        s_vec = jnp.array([6.0, 4.0, 2.0, 0.0])
        got = st.predict_weights_stacked(w, v, 0.1, s_vec)
        for k in range(4):
            exp = st.predict_weights(w[k], v[k], 0.1, float(s_vec[k]))
            np.testing.assert_allclose(np.asarray(got[k]), np.asarray(exp),
                                       rtol=1e-6)

    def test_rmse(self):
        a = {"x": jnp.zeros((4,))}
        b = {"x": jnp.full((4,), 2.0)}
        assert float(st.rmse(a, b)) == pytest.approx(2.0)
