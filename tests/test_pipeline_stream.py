"""Async streaming pipeline (the paper's runtime): warm-up, modes,
staleness behaviour, tick-scan microbatching."""
import jax
import numpy as np
import pytest

from conftest import lm_batch, tiny_cfg
from repro.core import pipeline_stream
from repro.models import Model
from repro.optim import sgd


def _setup(name="granite-8b", pipe=2, n_layers=4, batch=8, seq=16):
    cfg = tiny_cfg(name, n_layers=n_layers, pipe=pipe)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch_ = lm_batch(jax.random.PRNGKey(1), cfg, batch=batch, seq=seq)
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       batch_)
    return cfg, m, params, batch_, sds


class TestWarmup:
    def test_loss_invalid_during_fill(self):
        cfg, m, params, batch, sds = _setup(pipe=4)
        state = pipeline_stream.make_state(m, params, sds)
        step = jax.jit(pipeline_stream.make_train_step(
            m, mode="vanilla", lr=0.01))
        for t in range(10):
            state, met = step(state, batch)
            valid = float(met["loss_valid"])
            assert valid == (1.0 if t >= 3 else 0.0), (t, valid)

    def test_params_frozen_until_first_backward(self):
        cfg, m, params, batch, sds = _setup(pipe=4)
        state = pipeline_stream.make_state(m, params, sds)
        step = jax.jit(pipeline_stream.make_train_step(
            m, mode="vanilla", lr=0.05))
        # stage 3's first bwd fires at tick 3; stage 0's at tick 6.
        s0_before = np.asarray(
            jax.tree.leaves(state["params"]["stages"])[0])[0].copy()
        for _ in range(3):
            state, _ = step(state, batch)
        s0_after = np.asarray(
            jax.tree.leaves(state["params"]["stages"])[0])[0]
        np.testing.assert_array_equal(s0_before, s0_after)


class TestModes:
    @pytest.mark.parametrize("mode", pipeline_stream.MODES)
    def test_converges(self, mode):
        cfg, m, params, batch, sds = _setup(pipe=2)
        state = pipeline_stream.make_state(m, params, sds, mode=mode)
        step = jax.jit(pipeline_stream.make_train_step(
            m, mode=mode, lr=0.05))
        losses = []
        for _ in range(30):
            state, met = step(state, batch)
            if float(met["loss_valid"]):
                losses.append(float(met["loss"]))
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0]

    def test_spectrain_tracks_sync_better_than_vanilla(self):
        """The paper's central claim, on the production runtime: on a
        fixed batch, spectrain reaches lower loss than vanilla at equal
        steps (staleness costs vanilla progress)."""
        finals = {}
        for mode in ("vanilla", "spectrain"):
            cfg, m, params, batch, sds = _setup(pipe=4)
            state = pipeline_stream.make_state(m, params, sds, mode=mode)
            step = jax.jit(pipeline_stream.make_train_step(
                m, mode=mode, lr=0.08))
            last = None
            for _ in range(40):
                state, met = step(state, batch)
                if float(met["loss_valid"]):
                    last = float(met["loss"])
            finals[mode] = last
        assert finals["spectrain"] <= finals["vanilla"] + 1e-3, finals

    def test_degenerate_single_stage_equals_sgd(self):
        cfg, m, params, batch, sds = _setup(pipe=1, n_layers=2)
        state = pipeline_stream.make_state(m, params, sds)
        step = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.05))
        mom = sgd.init(params)
        ref = params
        for _ in range(3):
            state, _ = step(state, batch)
            g = jax.grad(lambda p: m.loss(p, batch))(ref)
            ref, mom = sgd.update(ref, mom, g, lr=0.05, gamma=0.9)
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


class TestTickScan:
    def test_multi_tick_equals_sequential_ticks(self):
        """ticks_per_step=T must equal calling the tick T times."""
        cfg, m, params, batch, sds = _setup(pipe=2, batch=8)
        # reference: one tick at a time with quarter batches
        state1 = pipeline_stream.make_state(m, params, sds,
                                            ticks_per_step=4)
        step4 = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.05, ticks_per_step=4))
        state1, met = step4(state1, batch)

        mb_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((s.shape[0] // 4,)
                                           + s.shape[1:], s.dtype), sds)
        state2 = pipeline_stream.make_state(m, params, mb_sds)
        step1 = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.05))
        for i in range(4):
            mb = jax.tree.map(lambda x: x[i * 2:(i + 1) * 2], batch)
            state2, _ = step1(state2, mb)
        for a, b in zip(jax.tree.leaves(state1["params"]),
                        jax.tree.leaves(state2["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-5)


class TestPipedreamStash:
    def test_stash_holds_fwd_weights(self):
        """After warm-up, pipedream backward must see the exact weights its
        forward used (weight stashing invariant): inject a large update
        between fwd and bwd and verify gradients differ from vanilla."""
        cfg, m, params, batch, sds = _setup(pipe=2)
        outs = {}
        for mode in ("vanilla", "pipedream"):
            state = pipeline_stream.make_state(m, params, sds, mode=mode)
            step = jax.jit(pipeline_stream.make_train_step(
                m, mode=mode, lr=0.3))  # big lr -> weights drift fast
            for _ in range(8):
                state, met = step(state, batch)
            outs[mode] = float(met["loss"])
        # both finite; trajectories differ because bwd weights differ
        assert np.isfinite(list(outs.values())).all()
        assert outs["vanilla"] != pytest.approx(outs["pipedream"], rel=1e-6)


class TestHybridAndMoE:
    @pytest.mark.parametrize("name", ["deepseek-moe-16b", "rwkv6-7b",
                                      "zamba2-1.2b"])
    def test_families_stream(self, name):
        cfg, m, params, batch, sds = _setup(name, pipe=2, n_layers=4)
        state = pipeline_stream.make_state(m, params, sds)
        step = jax.jit(pipeline_stream.make_train_step(
            m, mode="spectrain", lr=0.02))
        losses = []
        for _ in range(12):
            state, met = step(state, batch)
            if float(met["loss_valid"]):
                losses.append(float(met["loss"]))
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0] + 0.1


class TestUnsupportedGateMessages:
    """Every NotImplementedError gate follows one structured shape:
    the unsupported combination, the reason, and a supported
    alternative — so a user hitting a gate knows what to run instead
    without reading the source."""

    _SHAPE = (r"unsupported combination: .+ — .+; "
              r"supported alternative: .+")

    def _gate(self, which):
        import dataclasses
        from repro.planner import plan, synthetic_profile
        p = plan(profile=synthetic_profile([1.0] * 4), n_stages=2,
                 schedule="1f1b", partitioner="uniform")
        if which == "stash-depth":
            m = Model(tiny_cfg("granite-8b", n_layers=4, pipe=2))
            bad = dataclasses.replace(p, w_stash_depth=(3, 3))
            return lambda: pipeline_stream.make_ir_state(
                m, m.init(jax.random.PRNGKey(0)), None, plan=bad)
        if which == "mpmd-clip":
            m = Model(tiny_cfg("granite-8b", n_layers=4, pipe=2))
            return lambda: pipeline_stream.make_ir_train_step(
                m, plan=p, mode="spectrain", lr=0.05, execution="mpmd",
                clip=1.0)
        if which == "mpmd-hybrid-step":
            m = Model(tiny_cfg("zamba2-1.2b", n_layers=4, pipe=2))
            assert m.hybrid
            return lambda: pipeline_stream.make_ir_train_step(
                m, plan=p, mode="spectrain", lr=0.05, execution="mpmd")
        assert which == "mpmd-hybrid-state"
        m = Model(tiny_cfg("zamba2-1.2b", n_layers=4, pipe=2))
        assert m.hybrid
        return lambda: pipeline_stream.make_ir_state(
            m, m.init(jax.random.PRNGKey(0)), None, plan=p,
            execution="mpmd")

    @pytest.mark.parametrize("which,names", [
        ("stash-depth", ["weight-stash depth 3", "1f1b, gpipe"]),
        ("mpmd-clip", ["clip_by_global_norm", "execution='spmd'"]),
        ("mpmd-hybrid-step", ["hybrid SSM/attention", "execution='spmd'"]),
        ("mpmd-hybrid-state", ["hybrid SSM/attention", "execution='spmd'"]),
    ])
    def test_gate_message_is_structured(self, which, names):
        with pytest.raises(NotImplementedError) as e:
            self._gate(which)()
        msg = str(e.value)
        import re
        assert re.search(self._SHAPE, msg), msg
        for name in names:
            assert name in msg, (name, msg)
