"""End-to-end behaviour: the paper's full claim chain on real training
runs (CPU-scale), through the public driver."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core import pipeline_stream
from repro.data import DataConfig, SyntheticLM
from repro.models import Model

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _train(mode, steps=120, lr=0.08, pipe=4, seed=0):
    cfg = tiny_cfg("granite-8b", n_layers=4, pipe=pipe)
    m = Model(cfg)
    data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 8, seed=seed))
    batch0 = data.batch_at(0)
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       batch0)
    state = pipeline_stream.init_state(m, jax.random.PRNGKey(seed), sds,
                                       mode=mode)
    step = jax.jit(pipeline_stream.make_train_step(m, mode=mode, lr=lr))
    losses = []
    for s in range(steps):
        state, met = step(state, data.batch_at(s))
        if float(met["loss_valid"]):
            losses.append(float(met["loss"]))
    return np.asarray(losses), data


@pytest.mark.slow
class TestPaperClaims:
    def test_spectrain_beats_stale_modes_on_real_training(self):
        """Fig. 11 analogue on the streaming runtime with real data."""
        finals = {}
        for mode in ("vanilla", "pipedream", "spectrain"):
            losses, data = _train(mode)
            assert np.isfinite(losses).all(), mode
            finals[mode] = losses[-20:].mean()
        assert finals["spectrain"] <= finals["vanilla"] + 0.02, finals
        assert finals["spectrain"] <= finals["pipedream"] + 0.02, finals

    def test_learns_toward_bigram_floor(self):
        losses, data = _train("spectrain", steps=150, lr=0.05)
        floor = data.optimal_loss()
        start_gap = losses[0] - floor
        end_gap = losses[-10:].mean() - floor
        assert end_gap < 0.78 * start_gap, (losses[0], losses[-1], floor)


@pytest.mark.slow
class TestDrivers:
    def _run(self, mod, args):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        out = subprocess.run([sys.executable, "-m", mod, *args],
                             capture_output=True, text=True, env=env,
                             timeout=900)
        assert out.returncode == 0, out.stdout + out.stderr
        return out.stdout

    def test_train_driver_end_to_end(self, tmp_path):
        out = self._run("repro.launch.train", [
            "--arch", "granite-8b", "--smoke", "--layers", "4",
            "--pipe", "2", "--steps", "30", "--batch", "8", "--seq", "16",
            "--lr", "2e-2", "--json", "--log-every", "10",
            "--ckpt-dir", str(tmp_path)])
        recs = [json.loads(ln) for ln in out.splitlines()
                if ln.startswith("{")]
        assert recs[-1]["loss"] < recs[0]["loss"]

    def test_train_driver_resume(self, tmp_path):
        self._run("repro.launch.train", [
            "--arch", "granite-8b", "--smoke", "--layers", "2",
            "--pipe", "2", "--steps", "10", "--batch", "4", "--seq", "8",
            "--save-every", "5", "--ckpt-dir", str(tmp_path)])
        out = self._run("repro.launch.train", [
            "--arch", "granite-8b", "--smoke", "--layers", "2",
            "--pipe", "2", "--steps", "14", "--batch", "4", "--seq", "8",
            "--resume", "auto", "--ckpt-dir", str(tmp_path)])
        assert "# resumed from step" in out

    def test_serve_driver(self):
        out = self._run("repro.launch.serve", [
            "--arch", "granite-8b", "--pipe", "2", "--layers", "4",
            "--requests", "4", "--prompt-lens", "2,8",
            "--gen-lens", "1,4"])
        assert "decode:" in out
        assert "engine=pipelined" in out
