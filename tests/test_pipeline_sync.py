"""Sync circular pipeline == sequential execution, exactly."""
import jax
import numpy as np
import pytest

from conftest import lm_batch, tiny_cfg
from repro.core import pipeline_sync
from repro.models import Model


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg("granite-8b", n_layers=4, pipe=2)
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=8, seq=16)
    return cfg, m, params, batch


class TestEquivalence:
    def test_loss_equals_sequential(self, setup):
        cfg, m, params, batch = setup
        l_seq = m.loss(params, batch)
        for M in (2, 4, 8):
            l_pipe = pipeline_sync.pipeline_loss(m, params, batch, M)
            np.testing.assert_allclose(np.asarray(l_seq),
                                       np.asarray(l_pipe), rtol=2e-5)

    def test_grads_equal_sequential(self, setup):
        cfg, m, params, batch = setup
        g1 = jax.grad(lambda p: m.loss(p, batch))(params)
        g2 = jax.grad(
            lambda p: pipeline_sync.pipeline_loss(m, p, batch, 4))(params)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4, rtol=1e-3)

    def test_4_stage_pipeline(self):
        cfg = tiny_cfg("granite-8b", n_layers=4, pipe=4)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=8, seq=16)
        l_seq = m.loss(params, batch)
        l_pipe = pipeline_sync.pipeline_loss(m, params, batch, 8)
        np.testing.assert_allclose(np.asarray(l_seq), np.asarray(l_pipe),
                                   rtol=2e-5)

    def test_moe_close_to_sequential(self):
        # MoE capacity is per-microbatch-group, so equality is approximate
        cfg = tiny_cfg("deepseek-moe-16b", n_layers=2, pipe=2)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=8, seq=16)
        l_seq = m.loss(params, batch)
        l_pipe = pipeline_sync.pipeline_loss(m, params, batch, 2)
        np.testing.assert_allclose(np.asarray(l_seq), np.asarray(l_pipe),
                                   rtol=2e-2)


class TestTraining:
    def test_train_step_descends(self, setup):
        cfg, m, params, batch = setup
        state = pipeline_sync.init_state(m, jax.random.PRNGKey(0))
        step = jax.jit(pipeline_sync.make_train_step(
            m, lr=0.05, num_microbatches=4))
        losses = []
        for _ in range(15):
            state, met = step(state, batch)
            losses.append(float(met["loss"]))
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0]

    def test_clip_records_grad_norm(self, setup):
        cfg, m, params, batch = setup
        state = pipeline_sync.init_state(m, jax.random.PRNGKey(0))
        step = jax.jit(pipeline_sync.make_train_step(
            m, lr=0.05, num_microbatches=2, clip=1.0))
        state, met = step(state, batch)
        assert "grad_norm" in met and float(met["grad_norm"]) > 0
