"""Paper-exact event simulator: schemes, staleness, Fig. 8 RMSE claim."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulator import Simulator, make_mlp_staged
from repro.optim import sgd


def _data_iter(seed, batch=32, in_dim=16, classes=8):
    k = jax.random.PRNGKey(seed)
    wtrue = jax.random.normal(jax.random.PRNGKey(99), (in_dim, classes))
    while True:
        k, k1 = jax.random.split(k)
        x = jax.random.normal(k1, (batch, in_dim))
        yield {"x": x, "y": jnp.argmax(x @ wtrue, -1)}


def _make(n_stages=4, depth=4, width=32, seed=0):
    fns, params = make_mlp_staged(
        jax.random.PRNGKey(seed), in_dim=16, width=width, depth=depth,
        n_classes=8, n_stages=n_stages)
    return fns, params


def _run(scheme, steps=120, lr=0.05, n_stages=4, rmse_s=(), seed=0):
    fns, params = _make(n_stages, seed=seed)
    sim = Simulator(fns, params, n_stages=n_stages, scheme=scheme,
                    lr=lr, gamma=0.9, rmse_s=rmse_s)
    it = _data_iter(seed)
    out = [sim.step(next(it)) for _ in range(steps)]
    return sim, out


class TestSchemes:
    def test_all_schemes_converge(self):
        for scheme in Simulator.SCHEMES:
            _, ms = _run(scheme)
            losses = [m["loss"] for m in ms]
            assert np.isfinite(losses).all(), scheme
            assert np.mean(losses[-20:]) < np.mean(losses[:20]), scheme

    def test_sync_is_exact_sgd(self):
        """scheme=sync must equal a plain momentum-SGD loop exactly."""
        fns, params = _make(n_stages=2)
        sim = Simulator(fns, params, n_stages=2, scheme="sync", lr=0.05)
        it = _data_iter(0)

        # independent reference
        def loss_fn(p, batch):
            x = fns.embed(p["outer"]["in"], batch)
            for k in range(2):
                x = fns.stage(p["stages"][k], x)
            return fns.head_loss(p["outer"]["out"], x, batch)

        ref_p = params
        mom = sgd.init(ref_p)
        it2 = _data_iter(0)
        for _ in range(5):
            sim.step(next(it))
            g = jax.grad(loss_fn)(ref_p, next(it2))
            ref_p, mom = sgd.update(ref_p, mom, g, lr=0.05, gamma=0.9)
        for a, b in zip(jax.tree.leaves(sim.params), jax.tree.leaves(ref_p)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_single_stage_pipeline_equals_sync(self):
        """N=1 pipelining has no staleness: any scheme == sync."""
        for scheme in ("vanilla", "pipedream", "spectrain"):
            fns, params = _make(n_stages=1, depth=2)
            sim = Simulator(fns, params, n_stages=1, scheme=scheme, lr=0.05)
            ref = Simulator(fns, params, n_stages=1, scheme="sync", lr=0.05)
            it, it2 = _data_iter(0), _data_iter(0)
            for _ in range(5):
                sim.step(next(it))
                ref.step(next(it2))
            for a, b in zip(jax.tree.leaves(sim.params),
                            jax.tree.leaves(ref.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-6)


class TestFig8RMSE:
    """The paper's Fig. 8: prediction RMSE < stale-weight RMSE, for
    s in {1,2,3}, and stale RMSE grows with s."""

    def test_pred_beats_stale(self):
        _, ms = _run("spectrain", steps=150, rmse_s=(1, 2, 3))
        for s in (1, 2, 3):
            pred = np.mean([m[f"rmse_pred_s{s}"] for m in ms[20:]
                            if f"rmse_pred_s{s}" in m])
            stale = np.mean([m[f"rmse_stale_s{s}"] for m in ms[20:]
                             if f"rmse_stale_s{s}" in m])
            assert pred < stale, (s, pred, stale)

    def test_stale_rmse_grows_with_s(self):
        _, ms = _run("spectrain", steps=150, rmse_s=(1, 3))
        s1 = np.mean([m["rmse_stale_s1"] for m in ms[20:]])
        s3 = np.mean([m["rmse_stale_s3"] for m in ms[20:]])
        assert s3 > s1


class TestTable1Ordering:
    """Table 1 / Fig. 11: spectrain tracks the staleness-free baseline
    while vanilla/pipedream trail, at an lr where staleness bites.

    The claim is about the *typical* run, so it is asserted on the
    median over three fixed (deterministic) seeds — a single trajectory
    can land a few percent past the sync-tracking bound (seed 0 does)
    without contradicting the paper's table.
    """

    SEEDS = (0, 1, 2)

    def test_final_loss_ordering(self):
        finals = {}
        for scheme in Simulator.SCHEMES:
            per_seed = []
            for seed in self.SEEDS:
                _, ms = _run(scheme, steps=250, lr=0.12, seed=seed)
                per_seed.append(np.mean([m["loss"] for m in ms[-40:]]))
            finals[scheme] = float(np.median(per_seed))
        assert finals["spectrain"] <= finals["vanilla"] * 1.05, finals
        assert finals["spectrain"] <= finals["pipedream"] * 1.05, finals
        # spectrain within 25% of the staleness-free reference
        assert finals["spectrain"] <= finals["sync"] * 1.25 + 0.05, finals
