"""Per-arch smoke tests (reduced configs), param-count faithfulness,
decode-vs-forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import lm_batch, tiny_cfg
from repro.configs import get_config, list_archs, smoke_config
from repro.models import Model
from repro.models.layers import is_spec

ARCHS = list(list_archs())

PARAM_TARGETS = {
    "whisper-base": 74e6, "pixtral-12b": 12.4e9, "granite-8b": 8.2e9,
    "granite-20b": 20.1e9, "starcoder2-15b": 15.7e9, "minicpm3-4b": 4.1e9,
    "grok-1-314b": 314e9, "deepseek-moe-16b": 16.4e9, "rwkv6-7b": 7.6e9,
    "zamba2-1.2b": 1.2e9,
}


@pytest.mark.parametrize("name", ARCHS)
class TestSmoke:
    def _setup(self, name):
        cfg = smoke_config(get_config(name)).replace(
            param_dtype="float32", compute_dtype="float32")
        if cfg.frontend == "vision":
            cfg = cfg.replace(frontend_patches=4)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=2, seq=16)
        return cfg, m, params, batch

    def test_forward_shapes_and_finite(self, name):
        cfg, m, params, batch = self._setup(name)
        logits, aux = m.forward(params, batch)
        assert logits.shape == (2, 16, cfg.vocab_padded)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        loss = m.loss(params, batch)
        assert np.isfinite(float(loss))

    def test_one_train_step_no_nan(self, name):
        cfg, m, params, batch = self._setup(name)
        from repro.optim import sgd
        g = jax.grad(lambda p: m.loss(p, batch))(params)
        p2, _ = sgd.update(params, sgd.init(params), g, lr=1e-2)
        loss2 = m.loss(p2, batch)
        assert np.isfinite(float(loss2))

    def test_decode_step(self, name):
        cfg, m, params, batch = self._setup(name)
        cache = m.init_cache(2, 16)
        if cfg.is_encdec:
            cache = m.encdec_prefill_cache(params, batch, 16)
        lg, cache2 = m.decode_step(params, cache,
                                   jnp.zeros((2, 1), jnp.int32),
                                   jnp.asarray(3, jnp.int32))
        assert lg.shape == (2, 1, cfg.vocab_padded)
        assert np.isfinite(np.asarray(lg, np.float32)).all()


@pytest.mark.parametrize("name", ARCHS)
def test_param_count_matches_published(name):
    """Full-size spec tree within 8% of the published parameter count."""
    cfg = get_config(name)
    m = Model(cfg)
    specs = m.param_specs()
    n = sum(int(np.prod(s.shape))
            for s in jax.tree.leaves(specs, is_leaf=is_spec))
    target = PARAM_TARGETS[name]
    assert abs(n - target) / target < 0.08, (name, n, target)


@pytest.mark.parametrize("name", ARCHS)
def test_analytic_param_count_close_to_specs(name):
    cfg = get_config(name)
    m = Model(cfg)
    n = sum(int(np.prod(s.shape))
            for s in jax.tree.leaves(m.param_specs(), is_leaf=is_spec))
    a = cfg.param_count()
    assert abs(n - a) / n < 0.05, (name, n, a)


@pytest.mark.parametrize("name", ["granite-8b", "granite-20b",
                                  "minicpm3-4b", "rwkv6-7b",
                                  "zamba2-1.2b", "whisper-base"])
def test_decode_matches_forward(name):
    """Stepping the decoder token-by-token must reproduce the full
    teacher-forced forward logits at every position."""
    cfg = smoke_config(get_config(name)).replace(
        param_dtype="float32", compute_dtype="float32")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, T = 2, 8
    batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=B, seq=T)
    full_logits, _ = m.forward(params, batch)

    cache = (m.encdec_prefill_cache(params, batch, T) if cfg.is_encdec
             else m.init_cache(B, T))
    errs = []
    for t in range(T):
        tok = batch["tokens"][:, t:t + 1]
        lg, cache = m.decode_step(params, cache, tok,
                                  jnp.asarray(t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(
            lg[:, 0] - full_logits[:, t]))))
    assert max(errs) < 2e-3, (name, errs)


def test_vision_patches_change_output():
    cfg = smoke_config(get_config("pixtral-12b")).replace(
        frontend_patches=4, param_dtype="float32", compute_dtype="float32")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=2, seq=16)
    l1, _ = m.forward(params, batch)
    batch2 = dict(batch)
    batch2["patches"] = batch["patches"] + 1.0
    l2, _ = m.forward(params, batch2)
    # patch positions must differ, tail positions attend to them
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-4


def test_pipeline_stage_split_preserves_forward():
    """Model with S stages == model with 1 stage given repartitioned
    params (ragged canonical trees, flat layer order preserved)."""
    cfg2 = tiny_cfg("granite-8b", n_layers=4, pipe=2)
    cfg1 = tiny_cfg("granite-8b", n_layers=4, pipe=1)
    m2, m1 = Model(cfg2), Model(cfg1)
    params2 = m2.init(jax.random.PRNGKey(0))
    params1 = {
        "outer": params2["outer"],
        "stages": m1.partition_stage_params(params2["stages"], (4,)),
    }
    batch = lm_batch(jax.random.PRNGKey(1), cfg2, batch=2, seq=16)
    la, _ = m2.forward(params2, batch)
    lb, _ = m1.forward(params1, batch)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-5)


def test_ragged_init_no_divisibility_constraint():
    """7 layers / 3 stages initializes with sizes (3, 2, 2), matches the
    flat-layer forward of the single-stage model bit-for-bit in layer
    order, and init is RNG-compatible with a uniform split."""
    cfg = tiny_cfg("granite-8b", n_layers=7, pipe=3)
    m = Model(cfg)
    assert m.stage_sizes == (3, 2, 2)
    params = m.init(jax.random.PRNGKey(0))
    got = tuple(jax.tree.leaves(t["layers"])[0].shape[0]
                for t in params["stages"])
    assert got == (3, 2, 2)
    with pytest.raises(ValueError, match="ragged"):
        m.layers_per_stage

    cfg1 = tiny_cfg("granite-8b", n_layers=7, pipe=1)
    m1 = Model(cfg1)
    params1 = m1.init(jax.random.PRNGKey(0))
    # same key -> same flat layer values regardless of the split
    for a, b in zip(jax.tree.leaves(m.flat_layers(params["stages"])),
                    jax.tree.leaves(m1.flat_layers(params1["stages"]))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, batch=2, seq=16)
    la, _ = m.forward(params, batch)
    lb, _ = m1.forward(params1, batch)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-5)
