"""Dry-run smoke: lower+compile on a tiny forced-device mesh in a
subprocess (so the 512-device XLA flag can't leak into this process)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_cell(arch, shape, extra=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--smoke", *extra],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    recs = [json.loads(ln) for ln in out.stdout.splitlines()
            if ln.startswith("{")]
    assert recs, out.stdout
    return recs[0]


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("granite-8b", "train_4k"),
    ("deepseek-moe-16b", "train_4k"),
    ("rwkv6-7b", "decode_32k"),
])
def test_smoke_cells_compile(arch, shape):
    rec = run_cell(arch, shape)
    assert rec["status"] == "ok", rec
    assert rec["cost"]["flops"] > 0
    assert rec["terms"]["compute_s"] > 0
    assert rec["dominant"] in ("compute_s", "memory_s", "collective_s")


@pytest.mark.slow
def test_sync_runtime_compiles():
    rec = run_cell("granite-8b", "train_4k", ("--runtime", "sync"))
    assert rec["status"] == "ok", rec


@pytest.mark.slow
def test_skip_rule_applies():
    rec = run_cell("granite-8b", "long_500k")
    assert rec["status"] == "skip"
    assert "full-attention" in rec["skip_reason"]
